//! "What-if" scenario analysis (§II-C): will today's policies still hold
//! if failure rates drift, if recovery gets 50% faster, or if hardware
//! ages (bad-server regeneration)? Each scenario compares against the
//! Table-I baseline with common random numbers.
//!
//! ```bash
//! cargo run --release --example whatif_failure_rates [-- --quick]
//! ```

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::sim::rng::Rng;
use airesim::stats::Summary;

struct Scenario {
    name: &'static str,
    tweak: fn(&mut Params),
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 8 };

    let scenarios: Vec<Scenario> = vec![
        Scenario { name: "baseline (Table I)", tweak: |_| {} },
        Scenario {
            name: "failure rates double",
            tweak: |p| {
                p.random_failure_rate *= 2.0;
                p.systematic_failure_rate *= 2.0;
            },
        },
        Scenario {
            name: "recovery 50% faster",
            tweak: |p| p.recovery_time *= 0.5,
        },
        Scenario {
            name: "recovery 50% faster AND rates double",
            tweak: |p| {
                p.recovery_time *= 0.5;
                p.random_failure_rate *= 2.0;
                p.systematic_failure_rate *= 2.0;
            },
        },
        Scenario {
            name: "hardware ages: 1% regen per week",
            tweak: |p| {
                p.bad_regen_interval = 7.0 * 1440.0;
                p.bad_regen_fraction = 0.01;
            },
        },
        Scenario {
            name: "aggressive retirement (3 fails / 7 days)",
            tweak: |p| {
                p.retirement_threshold = 3;
                p.retirement_window = 7.0 * 1440.0;
            },
        },
        Scenario {
            name: "perfect diagnosis",
            tweak: |p| {
                p.diagnosis_prob = 1.0;
                p.diagnosis_uncertainty = 0.0;
            },
        },
    ];

    println!("AIReSim what-if analysis ({reps} replications each)\n");
    println!(
        "{:<42} {:>12} {:>10} {:>10} {:>9} {:>8}",
        "scenario", "makespan(h)", "±95%CI", "failures", "preempt", "retired"
    );

    let mut baseline_mean = None;
    for sc in &scenarios {
        let mut p = Params::table1_defaults();
        (sc.tweak)(&mut p);
        let mut makespans = Vec::new();
        let mut failures = Vec::new();
        let mut preempts = Vec::new();
        let mut retired = Vec::new();
        for r in 0..reps {
            // Common random numbers: same stream path across scenarios.
            let out = Simulation::with_rng(&p, Rng::derived(1234, &[r])).run();
            makespans.push(out.makespan / 60.0);
            failures.push(out.failures_total as f64);
            preempts.push(out.preemptions as f64);
            retired.push(out.retirements as f64);
        }
        let m = Summary::from_values(&makespans).unwrap();
        let f = Summary::from_values(&failures).unwrap();
        let pr = Summary::from_values(&preempts).unwrap();
        let rt = Summary::from_values(&retired).unwrap();
        let delta = baseline_mean
            .map(|b: f64| format!("{:+.1}%", (m.mean / b - 1.0) * 100.0))
            .unwrap_or_else(|| "—".into());
        if baseline_mean.is_none() {
            baseline_mean = Some(m.mean);
        }
        println!(
            "{:<42} {:>12.1} {:>10.1} {:>10.0} {:>9.0} {:>8.0}   {delta}",
            sc.name,
            m.mean,
            m.ci95_halfwidth(),
            f.mean,
            pr.mean,
            rt.mean
        );
    }

    println!(
        "\nReading: the recovery-time lever dominates (as §IV found); doubling\n\
         failure rates hurts roughly twice as much as halving recovery time helps."
    );
}
