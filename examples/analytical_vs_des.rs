//! DES vs analytical modeling (§II-C's comparison): run the AOT-compiled
//! JAX/Pallas CTMC estimator (through PJRT) and the DES over the same
//! grid, and show where the fast analytical screen agrees with — and where
//! it deviates from — the detailed simulation.
//!
//! Requires `make artifacts` (falls back to the pure-Rust mirror if the
//! HLO artifact is missing).
//!
//! ```bash
//! cargo run --release --example analytical_vs_des [-- --quick]
//! ```

use airesim::analytical;
use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::runtime::AnalyticModel;
use airesim::sim::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 6 };

    // Grid: the Fig 2(a) axes.
    let mut configs = Vec::new();
    for rec in [10.0, 20.0, 30.0] {
        for pool in [4112u32, 4160, 4192] {
            let mut p = Params::table1_defaults();
            p.recovery_time = rec;
            p.working_pool = pool;
            configs.push(p);
        }
    }

    // Analytical pass: PJRT artifact if compiled in and present,
    // pure-Rust mirror otherwise.
    let artifact = AnalyticModel::default_path();
    let (source, analytic): (&str, Vec<analytical::AnalyticOutputs>) =
        match cfg!(feature = "pjrt") && std::path::Path::new(artifact).exists() {
            true => {
                let model = AnalyticModel::load(artifact).expect("artifact load");
                let outs = model.analyze_many(&configs).expect("batch execute");
                ("PJRT artifact (JAX+Pallas AOT)", outs)
            }
            false => {
                eprintln!("note: {artifact} missing — run `make artifacts`; using Rust mirror");
                ("pure-Rust mirror", configs.iter().map(analytical::analyze).collect())
            }
        };

    println!("AIReSim: DES vs analytical baseline — source: {source}\n");
    println!(
        "{:>9} {:>6} | {:>12} {:>12} {:>7} | {:>10} {:>10} {:>7}",
        "recovery", "pool", "DES mksp(h)", "CTMC mksp(h)", "Δ%", "DES fails", "CTMC fails", "Δ%"
    );

    let mut worst: f64 = 0.0;
    for (p, a) in configs.iter().zip(&analytic) {
        let mut mksp = 0.0;
        let mut fails = 0.0;
        for r in 0..reps {
            let o = Simulation::with_rng(p, Rng::derived(77, &[r])).run();
            mksp += o.makespan / 60.0;
            fails += o.failures_total as f64;
        }
        mksp /= reps as f64;
        fails /= reps as f64;
        let am = a.makespan_est / 60.0;
        let dm = (am / mksp - 1.0) * 100.0;
        let df = (a.exp_failures / fails - 1.0) * 100.0;
        worst = worst.max(dm.abs());
        println!(
            "{:>9} {:>6} | {:>12.0} {:>12.0} {:>6.1}% | {:>10.0} {:>10.0} {:>6.1}%",
            p.recovery_time, p.working_pool, mksp, am, dm, fails, a.exp_failures, df
        );
    }

    println!(
        "\nThe CTMC screen tracks the DES within ~{worst:.0}% on makespan here, but it\n\
         cannot see queueing effects (stalls, preemption waves) — exactly the\n\
         simplification the paper cites as the reason to build a DES (§II-C).\n\
         Use the analytical pass to prune a large grid, then DES the survivors."
    );
}
