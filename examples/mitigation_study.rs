//! Mitigation study: the programmatic form of the `multi:` scenario kind
//! — labeled children as overrides on one shared base config, every
//! child's replications drained through the shared worker pool, rendered
//! as one combined comparison report with deltas against a baseline.
//!
//! The question (after Kokolis et al.'s mitigation comparisons): which
//! single intervention buys the most training goodput on a pressured
//! cluster — a priority repair queue, an SLA-aged repair queue, faster
//! recovery, or self-tuning checkpoints?
//!
//! ```bash
//! cargo run --release --example mitigation_study
//! cargo run --release --example mitigation_study -- --format csv
//! cargo run --release --example mitigation_study -- --format ndjson | head -3
//! ```

use airesim::config::Params;
use airesim::model::PolicySpec;
use airesim::report::{Format, Sink};
use airesim::scenario::study::{run_study, Study, StudyChild};
use airesim::sweep::AxisValue;

/// A cluster under enough failure pressure that mitigations matter:
/// strong systematic rates, unreliable repairs, one technician team,
/// checkpoints that cost real wall-clock to commit.
fn pressured() -> Params {
    let mut p = Params::small_test();
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 1.0 / 1440.0;
    p.systematic_failure_rate = 10.0 / 1440.0;
    p.systematic_fraction = 0.25;
    p.auto_repair_fail_prob = 0.8;
    p.manual_repair_capacity = 2;
    p.checkpoint_interval = 120.0;
    p.checkpoint_cost = 15.0;
    p.repair_sla_minutes = 360.0;
    p.max_sim_time = 1e9;
    p
}

fn child(label: &str, overrides: &[(&str, AxisValue)]) -> StudyChild {
    StudyChild {
        label: label.into(),
        overrides: overrides.iter().map(|(n, v)| (n.to_string(), v.clone())).collect(),
    }
}

fn main() {
    // `--format {text|json|csv|ndjson}` (default text).
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let format = match argv.iter().position(|a| a == "--format") {
        Some(i) => match argv.get(i + 1).map(|s| Format::parse(s)) {
            Some(Ok(f)) => f,
            _ => {
                eprintln!("usage: mitigation_study [--format text|json|csv|ndjson]");
                std::process::exit(2);
            }
        },
        None => Format::Text,
    };

    // One baseline, four single-knob mitigations — same base, same
    // master streams (CRN), deltas in every sink.
    let study = Study {
        children: vec![
            child("baseline", &[]),
            child("job_first_repair", &[("policies.repair", "job_first".into())]),
            child("sla_aged_repair", &[("policies.repair", "sla_aged".into())]),
            child("fast_recovery", &[("recovery_time", 5.0.into())]),
            child("young_daly_ckpt", &[("policies.checkpoint", "young_daly".into())]),
        ],
        baseline: Some(0),
        replications: 10,
        crn: true,
    };

    let record = run_study(&pressured(), &PolicySpec::default(), &study, 4242, 0)
        .expect("study children validated");
    print!("{}", format.sink().study(&record));
}
