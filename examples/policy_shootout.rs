//! Policy shootout: the new sweepable axes in action — compare host
//! selection, repair queue discipline, and checkpoint policies on one
//! pressured cluster, each combination under common random numbers.
//!
//! ```bash
//! cargo run --release --example policy_shootout
//! ```

use airesim::config::Params;
use airesim::model::cluster::ReplicationRunner;
use airesim::model::PolicySpec;
use airesim::sim::rng::Rng;
use airesim::stats::Summary;

/// A cluster under enough failure pressure that policy choices matter:
/// strong systematic rates, unreliable repairs, one technician team.
fn pressured() -> Params {
    let mut p = Params::small_test();
    p.job_size = 64;
    p.warm_standbys = 4;
    p.working_pool = 72;
    p.spare_pool = 16;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 1.0 / 1440.0;
    p.systematic_failure_rate = 10.0 / 1440.0;
    p.systematic_fraction = 0.25;
    p.auto_repair_fail_prob = 0.8;
    p.manual_repair_capacity = 2;
    p.checkpoint_interval = 60.0; // hourly checkpoints: failures lose work
    p.max_sim_time = 1e9;
    p
}

fn main() {
    let p = pressured();
    let reps = 10u64;

    println!("policy shootout — {} reps per combination, CRN seeds\n", reps);
    println!(
        "{:<12} {:<10} {:<11} | {:>12} {:>10} {:>10}",
        "selection", "repair", "checkpoint", "makespan(h)", "±95%CI", "lost(min)"
    );

    let mut runner = ReplicationRunner::new();
    for selection in ["first_fit", "random", "locality"] {
        for repair in ["fifo", "job_first"] {
            for checkpoint in ["continuous", "periodic"] {
                let spec = PolicySpec {
                    selection: selection.into(),
                    repair: repair.into(),
                    checkpoint: checkpoint.into(),
                    failure: "auto".into(),
                };
                let mut makespans = Vec::new();
                let mut lost = 0.0;
                for r in 0..reps {
                    // Common random numbers: the same stream for every
                    // combination at replication r isolates policy effects.
                    let out = runner.run(&p, &spec, Rng::derived(404, &[r]));
                    makespans.push(out.makespan / 60.0);
                    lost += out.work_lost / reps as f64;
                }
                let s = Summary::from_values(&makespans).unwrap();
                println!(
                    "{:<12} {:<10} {:<11} | {:>12.1} {:>10.1} {:>10.1}",
                    selection,
                    repair,
                    checkpoint,
                    s.mean,
                    s.ci95_halfwidth(),
                    lost
                );
            }
        }
    }

    println!(
        "\nReading the table: `periodic` checkpointing pays for itself in lost\n\
         work; `job_first` repair shortens stalls once the two technicians\n\
         saturate; selection policies tie until regeneration correlates\n\
         badness with placement history (see configs/aging_fleet.yaml)."
    );
}
