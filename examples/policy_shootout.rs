//! Policy shootout: sweepable *policy axes* in action — cross-product
//! host selection × repair discipline × checkpoint policy on one
//! pressured cluster, under common random numbers, and emit the results
//! through the structured record/sink API.
//!
//! ```bash
//! cargo run --release --example policy_shootout
//! cargo run --release --example policy_shootout -- --format csv
//! cargo run --release --example policy_shootout -- --format ndjson | head -3
//! ```

use airesim::config::Params;
use airesim::report::{Format, Sink, SweepRecord};
use airesim::sweep::{run_sweep, AxisValue, Sweep};

/// A cluster under enough failure pressure that policy choices matter:
/// strong systematic rates, unreliable repairs, one technician team.
fn pressured() -> Params {
    let mut p = Params::small_test();
    p.job_size = 64;
    p.warm_standbys = 4;
    p.working_pool = 72;
    p.spare_pool = 16;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 1.0 / 1440.0;
    p.systematic_failure_rate = 10.0 / 1440.0;
    p.systematic_fraction = 0.25;
    p.auto_repair_fail_prob = 0.8;
    p.manual_repair_capacity = 2;
    p.checkpoint_interval = 60.0; // hourly checkpoints: failures lose work
    p.max_sim_time = 1e9;
    p
}

fn names(xs: &[&str]) -> Vec<AxisValue> {
    xs.iter().map(|&s| s.into()).collect()
}

fn main() {
    // `--format {text|json|csv|ndjson}` (default text).
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let format = match argv.iter().position(|a| a == "--format") {
        Some(i) => match argv.get(i + 1).map(|s| Format::parse(s)) {
            Some(Ok(f)) => f,
            _ => {
                eprintln!("usage: policy_shootout [--format text|json|csv|ndjson]");
                std::process::exit(2);
            }
        },
        None => Format::Text,
    };

    let p = pressured();
    let sweep = Sweep::from_axes(
        "policy shootout (10 CRN reps per combination)",
        &[
            ("policies.selection".to_string(), names(&["first_fit", "random", "locality"])),
            ("policies.repair".to_string(), names(&["fifo", "job_first"])),
            ("policies.checkpoint".to_string(), names(&["continuous", "periodic"])),
        ],
        10,
        404,
    )
    // Common random numbers: every combination sees the same streams at
    // replication r, isolating the policy effect.
    .with_crn();
    sweep.validate(&p).expect("all combinations build");

    let result = run_sweep(&p, &sweep, 0);
    let record = SweepRecord::new(result, "makespan_hours");
    print!("{}", format.sink().sweep(&record));

    if format == Format::Text {
        println!(
            "\nReading the table: `periodic` checkpointing pays for itself in lost\n\
             work (see the work_lost metric via --format json); `job_first` repair\n\
             shortens stalls once the two technicians saturate; selection policies\n\
             tie until regeneration correlates badness with placement history\n\
             (see configs/aging_fleet.yaml)."
        );
    }
}
