//! Capacity planning for a 4096-server training job — the paper's §IV case
//! study, end to end. This is the repository's full-system driver: it
//! exercises the DES (all five modules), the sweep engine, the statistics
//! stack, and the report emitters on the paper's own parameter grid.
//!
//! Reproduces:
//!   * Figure 2(a): training time vs recovery time {10,20,30} ×
//!     working pool {4112,4128,4160,4192}
//!   * Figure 2(b): training time vs waiting time {10,20,30} × same pools
//!   * The §IV sensitivity finding (one-way sweeps over every Table I
//!     parameter, ranked by impact)
//!   * The §IV conclusion: pool sizing beyond +32 over minimum brings no
//!     further benefit.
//!
//! ```bash
//! cargo run --release --example capacity_planning            # full (~2 min)
//! cargo run --release --example capacity_planning -- --quick # reduced reps
//! ```

use airesim::config::Params;
use airesim::report;
use airesim::sweep::{run_sweep, Sweep, SweepResult};

const POOLS: [f64; 4] = [4112.0, 4128.0, 4160.0, 4192.0];

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 10 };
    let seed = 42;
    let base = Params::table1_defaults();

    println!("AIReSim capacity planning — paper §IV (replications per point: {reps})\n");

    // ---- Figure 2(a): recovery time × working pool ------------------- //
    let fig2a = Sweep::two_way(
        "Fig 2(a): total training time vs (recovery time, working pool)",
        "recovery_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &POOLS,
        reps,
        seed,
    );
    let r2a = run_sweep(&base, &fig2a, 0);
    print!("{}", report::figure_series(&r2a, "makespan_hours"));
    check_fig2a_shape(&r2a);

    // ---- Figure 2(b): waiting time × working pool -------------------- //
    let fig2b = Sweep::two_way(
        "Fig 2(b): total training time vs (waiting time, working pool)",
        "waiting_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &POOLS,
        reps,
        seed,
    );
    let r2b = run_sweep(&base, &fig2b, 0);
    println!();
    print!("{}", report::figure_series(&r2b, "makespan_hours"));

    // ---- Sensitivity: one-way sweeps over every Table I row ---------- //
    println!("\nOne-way sensitivity sweeps (Table I value ranges)…\n");
    let axes: Vec<(&str, Vec<f64>)> = vec![
        ("random_failure_rate",
         vec![0.005 / 1440.0, 0.01 / 1440.0, 0.025 / 1440.0, 0.05 / 1440.0]),
        ("systematic_rate_multiplier", vec![3.0, 5.0, 10.0]),
        ("systematic_fraction", vec![0.1, 0.15, 0.2]),
        ("recovery_time", vec![10.0, 20.0, 30.0]),
        ("warm_standbys", vec![4.0, 8.0, 16.0, 32.0]),
        ("host_selection_time", vec![1.0, 3.0, 5.0, 10.0]),
        ("waiting_time", vec![10.0, 20.0, 30.0]),
        ("auto_repair_prob", vec![0.70, 0.80, 0.90]),
        ("auto_repair_fail_prob", vec![0.2, 0.4, 0.6]),
        ("manual_repair_fail_prob", vec![0.1, 0.2, 0.3]),
        ("auto_repair_time", vec![60.0, 120.0, 180.0]),
        ("manual_repair_time", vec![1440.0, 2.0 * 1440.0, 3.0 * 1440.0]),
        ("working_pool", POOLS.to_vec()),
        ("spare_pool", vec![200.0, 300.0, 400.0]),
        ("diagnosis_prob", vec![0.6, 0.8, 1.0]),
    ];
    let mut results: Vec<(String, SweepResult)> = Vec::new();
    for (name, values) in &axes {
        let sweep = Sweep::one_way(name, name, values, reps, seed);
        results.push((name.to_string(), run_sweep(&base, &sweep, 0)));
    }
    println!("Sensitivity of mean training time (spread = (max-min)/min):\n");
    print!("{}", report::sensitivity(&results, "makespan_hours"));

    // ---- The §IV conclusion ------------------------------------------ //
    conclusion(&r2a);
}

/// Assert (and report) the Fig 2(a) shape claims from §IV.
fn check_fig2a_shape(r: &SweepResult) {
    // Points are x-major: [rec10 × 4 pools, rec20 × 4 pools, rec30 × 4].
    let mean = |i: usize| r.points[i].summary("makespan_hours").unwrap().mean;
    let rec_means: Vec<f64> =
        (0..3).map(|x| (0..4).map(|y| mean(4 * x + y)).sum::<f64>() / 4.0).collect();
    println!(
        "\n  shape check: training time rises with recovery time: {:.0} < {:.0} < {:.0} h  [{}]",
        rec_means[0],
        rec_means[1],
        rec_means[2],
        if rec_means[0] < rec_means[1] && rec_means[1] < rec_means[2] { "OK" } else { "MISMATCH" }
    );
}

fn conclusion(r2a: &SweepResult) {
    // At the default recovery time (20), compare pools.
    let mean = |i: usize| r2a.points[i].summary("makespan_hours").unwrap().mean;
    println!("\n§IV conclusion — working pool sizing at recovery_time=20:");
    for (j, pool) in POOLS.iter().enumerate() {
        println!("  pool {:>6}: {:>9.1} h", pool, mean(4 + j));
    }
    let gain_16_32 = mean(4) - mean(5);
    let gain_32_96 = mean(5) - mean(7);
    println!(
        "  +16→+32 servers saves {gain_16_32:.1} h; +32→+96 saves {gain_32_96:.1} h \
         — beyond +32 extra capacity buys little (the paper's finding)."
    );
}
