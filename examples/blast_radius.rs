//! Blast radius: how much does failure-domain *size* cost? A CRN sweep
//! over `servers_per_rack` with the per-server outage exposure held
//! constant — every server's rack still dies at the same rate, but a
//! bigger rack means one outage takes more of the job down at once.
//!
//! Replication `r` uses the same derived stream at every point (common
//! random numbers), so differences between rows are the topology's, not
//! the sampler's. Watch `domain_max_blast` scale with the rack size and
//! `makespan_hours` pay for it.
//!
//! ```bash
//! cargo run --release --example blast_radius
//! cargo run --release --example blast_radius -- --format csv
//! cargo run --release --example blast_radius -- --format ndjson | head -2
//! ```

use airesim::config::{Params, TopologyLevelSpec, TopologySpec};
use airesim::model::cluster::ReplicationRunner;
use airesim::model::PolicySpec;
use airesim::report::{Format, Sink, SweepRecord};
use airesim::sim::rng::Rng;
use airesim::stats::Collector;
use airesim::sweep::{collect_outputs, AxisValue, PointResult, SweepPoint, SweepResult};

/// A cluster where rack outages are the dominant hazard: base failure
/// rates are mild, racks die about twice a week each.
fn base() -> Params {
    let mut p = Params::small_test();
    p.job_size = 24;
    p.warm_standbys = 12;
    p.working_pool = 96;
    p.spare_pool = 16;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 0.1 / 1440.0;
    p.systematic_failure_rate = 0.5 / 1440.0;
    p.auto_repair_time = 60.0;
    p.max_sim_time = 1e9;
    p
}

fn main() {
    // `--format {text|json|csv|ndjson}` (default text).
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let format = match argv.iter().position(|a| a == "--format") {
        Some(i) => match argv.get(i + 1).map(|s| Format::parse(s)) {
            Some(Ok(f)) => f,
            _ => {
                eprintln!("usage: blast_radius [--format text|json|csv|ndjson]");
                std::process::exit(2);
            }
        },
        None => Format::Text,
    };

    const RACK_OUTAGE_RATE: f64 = 0.3 / 1440.0; // per rack, ~1 per 3.3 days
    let reps = 8usize;
    let spec = PolicySpec { selection: "locality".into(), ..PolicySpec::default() };
    let mut runner = ReplicationRunner::new();

    let mut points = Vec::new();
    for &servers_per_rack in &[2u32, 4, 8, 16] {
        let mut p = base();
        p.topology = Some(TopologySpec {
            levels: vec![TopologyLevelSpec {
                name: "rack".into(),
                size: servers_per_rack,
                outage_rate: RACK_OUTAGE_RATE,
            }],
        });
        let mut collector = Collector::new();
        for r in 0..reps {
            // CRN: the stream depends on the replication only, never the
            // point — every rack size faces the same draws.
            let out = runner.run(&p, &spec, Rng::derived(4242, &[r as u64]));
            collect_outputs(&mut collector, &p, &out);
        }
        points.push(PointResult {
            point: SweepPoint {
                overrides: vec![(
                    "servers_per_rack".to_string(),
                    AxisValue::Num(servers_per_rack as f64),
                )],
            },
            collector,
        });
    }

    let result = SweepResult {
        title: format!("blast radius: rack size, {reps} CRN reps, locality packing"),
        points,
    };
    let record = SweepRecord::new(result, "makespan_hours");
    print!("{}", format.sink().sweep(&record));

    if format == Format::Text {
        println!(
            "\nReading the table: every server's rack dies at the same rate, so the\n\
             expected number of server-downings is constant across rows — only the\n\
             *correlation* grows. Bigger racks concentrate the damage (see\n\
             domain_max_blast and domain_job_interruptions via --format json):\n\
             once one outage exceeds the 12 warm standbys, the job pays a full\n\
             host selection instead of a swap, and makespan_hours climbs."
        );
    }
}
