//! Quickstart: run one Table-I-default simulation, print its outputs, and
//! show a minimal one-way sweep.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::report;
use airesim::sweep::{run_sweep, Sweep};

fn main() {
    // --- One simulation run ------------------------------------------ //
    // 4096-server job, 16 warm standbys, 256-day length, Table I rates.
    let params = Params::table1_defaults();
    let out = Simulation::new(&params, 42).run();

    println!("AIReSim quickstart — one run at Table I defaults (seed 42)\n");
    println!(
        "  makespan      : {:.1} days ({:.0} hours)",
        out.makespan / 1440.0,
        out.makespan / 60.0
    );
    println!(
        "  failures      : {} ({} random, {} systematic)",
        out.failures_total, out.failures_random, out.failures_systematic
    );
    println!(
        "  repairs       : {} automated, {} manual",
        out.repairs_auto, out.repairs_manual
    );
    println!("  preemptions   : {}", out.preemptions);
    println!("  avg run burst : {:.1} min", out.avg_run_duration);
    println!("  utilization   : {:.1}%", out.utilization(params.job_len) * 100.0);

    // --- A small one-way sweep --------------------------------------- //
    // How does recovery time shape total training time? (Fig 2a's x-axis.)
    println!("\nSweeping recovery_time (5 replications per point)…\n");
    let sweep = Sweep::one_way(
        "Recovery time sensitivity",
        "recovery_time",
        &[10.0, 20.0, 30.0],
        5,
        42,
    );
    let result = run_sweep(&params, &sweep, 0);
    print!("{}", report::text_table(&result, "makespan_hours"));
    println!("\nNext: examples/capacity_planning.rs reproduces the paper's §IV study.");
}
