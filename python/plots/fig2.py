"""Render the paper's Figure 2 from the bench harnesses' CSV output.

Build-time tooling only (matplotlib); never on the simulation path.

Usage:
    cargo bench --bench fig2a | python python/plots/fig2.py --out fig2a.png \
        --xlabel "Recovery time (mins)"
    # or from a saved CSV:
    python python/plots/fig2.py --csv fig2a.csv --out fig2a.png
"""

from __future__ import annotations

import argparse
import csv
import io
import sys
from collections import defaultdict

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402


def read_rows(text: str):
    """Extract the CSV block (header starts with a param name and ends with
    ',max') from mixed bench output."""
    lines = [l for l in text.splitlines() if l.strip()]
    start = next(
        i for i, l in enumerate(lines) if l.endswith(",max") and ",metric," in l
    )
    block = [lines[start]]
    for l in lines[start + 1 :]:
        if l.count(",") >= block[0].count(","):
            block.append(l)
        else:
            break
    return list(csv.DictReader(io.StringIO("\n".join(block))))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--csv", help="CSV file (default: read stdin)")
    ap.add_argument("--out", default="fig2.png")
    ap.add_argument("--xlabel", default="Parameter value")
    ap.add_argument("--ylabel", default="Total training time (hours)")
    args = ap.parse_args()

    text = open(args.csv).read() if args.csv else sys.stdin.read()
    rows = read_rows(text)
    if not rows:
        sys.exit("no CSV rows found")

    # First column = x parameter, second = group (pool size).
    cols = list(rows[0].keys())
    xname, gname = cols[0], cols[1]
    groups = defaultdict(list)  # pool -> [(x, mean, std)]
    for r in rows:
        groups[r[gname]].append((float(r[xname]), float(r["mean"]), float(r["std"])))

    fig, ax = plt.subplots(figsize=(7, 4))
    xs = sorted({float(r[xname]) for r in rows})
    n_groups = len(groups)
    width = 0.8 / n_groups
    for gi, (pool, pts) in enumerate(sorted(groups.items(), key=lambda kv: float(kv[0]))):
        pts.sort()
        offs = [xs.index(x) + (gi - n_groups / 2 + 0.5) * width for x, _, _ in pts]
        ax.bar(
            offs,
            [m for _, m, _ in pts],
            width=width,
            yerr=[s for _, _, s in pts],
            capsize=2,
            label=f"{gname}={pool}",
        )
    ax.set_xticks(range(len(xs)))
    ax.set_xticklabels([f"{x:g}" for x in xs])
    ax.set_xlabel(args.xlabel)
    ax.set_ylabel(args.ylabel)
    ax.legend(fontsize=8)
    ax.set_title(f"Training time vs ({xname}, {gname})")
    fig.tight_layout()
    fig.savefig(args.out, dpi=150)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
