"""L2 analytical-model sanity: generator structure, limit behaviours,
monotonicity in the paper's two sensitive knobs (recovery, waiting)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

MIN_PER_DAY = 24.0 * 60.0


def table1_defaults(**overrides) -> np.ndarray:
    """One Table-I default parameter vector (times in minutes)."""
    p = {
        "lambda_r": 0.01 / MIN_PER_DAY,
        "lambda_s": 5 * 0.01 / MIN_PER_DAY,
        "frac_bad": 0.15,
        "recovery_time": 20.0,
        "job_size": 4096.0,
        "job_len": 256.0 * MIN_PER_DAY,
        "warm_standbys": 16.0,
        "p_auto": 0.80,
        "p_auto_fail": 0.40,
        "p_man_fail": 0.20,
        "auto_time": 120.0,
        "man_time": 2.0 * MIN_PER_DAY,
        "host_selection_time": 3.0,
        "waiting_time": 20.0,
        "working_pool": 4160.0,
        "p_retire": 0.0,
    }
    p.update(overrides)
    return np.array([p[n] for n in model.PARAM_NAMES], dtype=np.float32)


def batch_of(vectors) -> jnp.ndarray:
    """Pad a list of param vectors to the static artifact batch."""
    arr = np.stack(vectors)
    pad = model.BATCH - arr.shape[0]
    assert pad >= 0
    if pad:
        arr = np.concatenate([arr, np.repeat(arr[-1:], pad, axis=0)])
    return jnp.asarray(arr)


def run(vectors) -> np.ndarray:
    out = model.analytic_metrics(batch_of(vectors))
    return np.asarray(out)[: len(vectors)]


def test_generator_rows_sum_to_zero():
    q, pi0 = model.build_generator(batch_of([table1_defaults()]))
    np.testing.assert_allclose(np.asarray(q).sum(axis=2), 0.0, atol=1e-9)
    np.testing.assert_allclose(np.asarray(pi0).sum(axis=1), 1.0, rtol=1e-6)


def test_generator_respects_frac_bad():
    q, pi0 = model.build_generator(batch_of([table1_defaults(frac_bad=0.25)]))
    assert np.isclose(pi0[0, 1], 0.25, rtol=1e-6)
    assert np.isclose(pi0[0, 0], 0.75, rtol=1e-6)


def test_pad_lane_unreachable():
    q, pi0 = model.build_generator(batch_of([table1_defaults()]))
    q = np.asarray(q)
    assert np.all(q[:, :, 7] == 0.0) and np.all(q[:, 7, :] == 0.0)


def test_zero_failure_rate_is_failure_free():
    out = run([table1_defaults(lambda_r=0.0, lambda_s=0.0)])
    idx = {n: i for i, n in enumerate(model.OUTPUT_NAMES)}
    assert np.isclose(out[0, idx["avail_T"]], 1.0, atol=1e-5)
    assert np.isclose(out[0, idx["exp_failures"]], 0.0, atol=1e-3)
    # Makespan == failure-free job length.
    assert np.isclose(out[0, idx["makespan_est"]], 256.0 * MIN_PER_DAY, rtol=1e-5)


def test_makespan_increases_with_recovery_time():
    """Paper Fig 2(a): training time grows with recovery time."""
    outs = run([table1_defaults(recovery_time=r) for r in (10.0, 20.0, 30.0)])
    makespans = outs[:, list(model.OUTPUT_NAMES).index("makespan_est")]
    assert makespans[0] < makespans[1] < makespans[2]


def test_makespan_increases_with_failure_rate():
    outs = run(
        [table1_defaults(lambda_r=f / MIN_PER_DAY, lambda_s=5 * f / MIN_PER_DAY)
         for f in (0.001, 0.002, 0.005, 0.01)]
    )
    m = outs[:, list(model.OUTPUT_NAMES).index("makespan_est")]
    assert np.all(np.diff(m) > 0)


def test_makespan_identity():
    """makespan = job_len * (1 + overhead) exactly (failures accrue only
    during the L compute minutes, assumption 7)."""
    out = run([table1_defaults(), table1_defaults(recovery_time=30.0)])
    idx = {n: i for i, n in enumerate(model.OUTPUT_NAMES)}
    for row in out:
        want = 256.0 * MIN_PER_DAY * (1.0 + row[idx["overhead_frac"]])
        assert np.isclose(row[idx["makespan_est"]], want, rtol=1e-5)


def test_waiting_time_effect_strongest_at_min_slack():
    """Paper Fig 2(b): waiting-time sensitivity concentrates where the
    working pool has no slack beyond the warm standbys."""
    idx = list(model.OUTPUT_NAMES).index("makespan_est")
    tight = run([table1_defaults(working_pool=4112.0, waiting_time=w)
                 for w in (10.0, 30.0)])
    loose = run([table1_defaults(working_pool=4192.0, waiting_time=w)
                 for w in (10.0, 30.0)])
    d_tight = tight[1, idx] - tight[0, idx]
    d_loose = loose[1, idx] - loose[0, idx]
    assert d_tight >= d_loose - 1e-3


def test_transients_are_distributions():
    q, pi0 = model.build_generator(batch_of([table1_defaults()]))
    horizon = 256.0 * MIN_PER_DAY
    delta = jnp.full((model.BATCH,), horizon / 2.0**16, dtype=jnp.float32)
    a0 = ref.expm_series_ref(q, delta, 30)
    # Row-stochastic base matrix.
    np.testing.assert_allclose(np.asarray(a0).sum(axis=2)[:, :7], 1.0, rtol=1e-4)


def test_retirement_drains_mass():
    out = run([table1_defaults(p_retire=0.5, p_man_fail=0.5,
                               lambda_s=50 * 0.01 / MIN_PER_DAY)])
    idx = {n: i for i, n in enumerate(model.OUTPUT_NAMES)}
    assert out[0, idx["pi_retired"]] > 0.01
    out0 = run([table1_defaults(p_retire=0.0)])
    assert out0[0, idx["pi_retired"]] < 1e-6


def test_avail_avg_below_one_with_failures():
    out = run([table1_defaults()])
    idx = {n: i for i, n in enumerate(model.OUTPUT_NAMES)}
    assert 0.9 < out[0, idx["avail_avg"]] < 1.0
    assert 0.0 < out[0, idx["rbar"]] < 1e-3


def test_param_names_match_columns():
    assert len(model.PARAM_NAMES) == model.N_PARAMS
    assert len(model.OUTPUT_NAMES) == model.N_OUTPUTS
