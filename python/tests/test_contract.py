"""Cross-language interface contract: the constants the Rust side mirrors
(`rust/src/analytical/mod.rs`) must match the Python definitions, and the
in-graph special functions must match their SciPy references."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


import pathlib

RUST_ANALYTICAL = str(
    pathlib.Path(__file__).resolve().parents[2] / "rust" / "src" / "analytical" / "mod.rs"
)


def test_param_names_mirrored_in_rust():
    src = open(RUST_ANALYTICAL).read()
    for name in model.PARAM_NAMES:
        assert f'"{name}"' in src, f"param {name} missing from Rust mirror"
    for name in model.OUTPUT_NAMES:
        assert f'"{name}"' in src, f"output {name} missing from Rust mirror"


def test_rust_mirror_constants():
    src = open(RUST_ANALYTICAL).read()
    assert f"STATES: usize = {model.analytic_metrics.__globals__['STATES']}" in src
    assert "M_STEPS: usize = 16" in src
    assert f"K_TERMS: usize = {model.K_TERMS}" in src


def test_norm_sf_matches_scipy():
    import scipy.stats

    z = jnp.asarray(np.linspace(-6, 6, 101, dtype=np.float32))
    got = np.asarray(model._norm_sf(z))
    want = scipy.stats.norm.sf(np.asarray(z, dtype=np.float64))
    np.testing.assert_allclose(got, want, atol=2e-7)


def test_batch_padding_constants():
    assert model.BATCH % 8 == 0, "batch must tile by BLOCK_B"
    assert model.N_PARAMS == len(model.PARAM_NAMES)
    assert model.N_OUTPUTS == len(model.OUTPUT_NAMES)
