"""Pallas kernel vs pure-jnp oracle -- the core L1 correctness signal.

Hypothesis sweeps batch sizes, tile sizes, squaring depths, and matrix
contents (stochastic matrices as the kernel sees in production, plus
general small matrices) and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.uniformization import STATES, dyadic_transients

jax.config.update("jax_platform_name", "cpu")


def random_stochastic(rng: np.random.Generator, b: int) -> np.ndarray:
    """Random row-stochastic [b, S, S] matrices (what production feeds)."""
    raw = rng.exponential(1.0, size=(b, STATES, STATES)).astype(np.float32)
    return raw / raw.sum(axis=2, keepdims=True)


def random_dist(rng: np.random.Generator, b: int) -> np.ndarray:
    raw = rng.exponential(1.0, size=(b, STATES)).astype(np.float32)
    return raw / raw.sum(axis=1, keepdims=True)


def test_kernel_matches_ref_defaults():
    rng = np.random.default_rng(0)
    a0 = jnp.asarray(random_stochastic(rng, 64))
    pi0 = jnp.asarray(random_dist(rng, 64))
    got = dyadic_transients(a0, pi0)
    want = ref.dyadic_transients_ref(a0, pi0, 16)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(min_value=1, max_value=6),
    block_b=st.sampled_from([1, 2, 4, 8]),
    m_steps=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_swept(b_tiles, block_b, m_steps, seed):
    b = b_tiles * block_b
    rng = np.random.default_rng(seed)
    a0 = jnp.asarray(random_stochastic(rng, b))
    pi0 = jnp.asarray(random_dist(rng, b))
    got = dyadic_transients(a0, pi0, m_steps=m_steps, block_b=block_b)
    want = ref.dyadic_transients_ref(a0, pi0, m_steps)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-6)


def test_kernel_identity_matrix_fixed_point():
    """pi0 @ I^(2^i) == pi0 at every capture."""
    b = 8
    a0 = jnp.broadcast_to(jnp.eye(STATES, dtype=jnp.float32)[None], (b, STATES, STATES))
    pi0 = jnp.asarray(random_dist(np.random.default_rng(1), b))
    caps = dyadic_transients(a0, pi0, m_steps=8, block_b=4)
    for i in range(9):
        np.testing.assert_allclose(caps[:, i, :], pi0, rtol=1e-6)


def test_kernel_preserves_probability_mass():
    """Row-stochastic A0 => every capture is a distribution."""
    rng = np.random.default_rng(2)
    a0 = jnp.asarray(random_stochastic(rng, 16))
    pi0 = jnp.asarray(random_dist(rng, 16))
    caps = dyadic_transients(a0, pi0, m_steps=10, block_b=8)
    np.testing.assert_allclose(np.sum(np.asarray(caps), axis=2), 1.0, rtol=1e-4)
    assert np.all(np.asarray(caps) >= -1e-6)


def test_kernel_permutation_matrix_cycles():
    """A cyclic permutation of period 2 alternates under squaring: every
    capture after the first squaring is the identity action."""
    perm = np.eye(STATES, dtype=np.float32)
    # Swap lanes 0 and 1 -> period-2 permutation.
    perm[[0, 1]] = perm[[1, 0]]
    a0 = jnp.broadcast_to(jnp.asarray(perm)[None], (8, STATES, STATES))
    pi0 = jnp.zeros((8, STATES), dtype=jnp.float32).at[:, 0].set(1.0)
    caps = dyadic_transients(a0, pi0, m_steps=6, block_b=8)
    # capture 0 = pi0 @ P (swapped); captures i>=1 use P^(2^i) = I.
    assert np.allclose(caps[:, 0, 1], 1.0)
    for i in range(1, 7):
        np.testing.assert_allclose(caps[:, i, 0], 1.0, rtol=1e-6)


def test_kernel_rejects_bad_batch():
    a0 = jnp.zeros((6, STATES, STATES), dtype=jnp.float32)
    pi0 = jnp.zeros((6, STATES), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        dyadic_transients(a0, pi0, block_b=4)


def test_expm_series_matches_scipy():
    """Uniformized series (the jnp path the model uses) vs dense expm."""
    import scipy.linalg

    rng = np.random.default_rng(3)
    b = 8
    q = rng.exponential(0.3, size=(b, STATES, STATES)).astype(np.float32)
    for i in range(b):
        np.fill_diagonal(q[i], 0.0)
        q[i] -= np.diag(q[i].sum(axis=1))
    delta = jnp.asarray(rng.uniform(0.05, 1.5, size=b).astype(np.float32))
    got = np.asarray(ref.expm_series_ref(jnp.asarray(q), delta, 40))
    for i in range(b):
        want = scipy.linalg.expm(q[i].astype(np.float64) * float(delta[i]))
        np.testing.assert_allclose(got[i], want, rtol=1e-3, atol=1e-5)
