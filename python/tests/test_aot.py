"""AOT lowering smoke: the artifact must be parseable HLO text with the
contracted interface (one f32[64,16] param, a 1-tuple f32[64,8] result)."""

import jax

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_lowering_produces_hlo_text():
    text = aot.lower_analytic()
    assert "HloModule" in text
    # Entry signature carries the contracted shapes.
    assert f"f32[{model.BATCH},{model.N_PARAMS}]" in text
    assert f"f32[{model.BATCH},{model.N_OUTPUTS}]" in text
    # Pallas must have lowered via interpret=True: no Mosaic custom-calls.
    assert "mosaic" not in text.lower()


def test_lowering_is_deterministic():
    assert aot.lower_analytic() == aot.lower_analytic()
