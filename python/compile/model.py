"""Layer-2 JAX model: batched analytical CTMC reliability estimator.

This is the paper's *analytical comparator* (AIReSim SS II-C contrasts DES
against Markov-model analysis [Trivedi 2001]) implemented as a real
component: the Rust coordinator uses the AOT-compiled artifact both for
sweep pre-screening and as a cross-check on DES means.

Per-server CTMC over STATES = 8 padded lanes (7 live states):

    0 GoodRun    : running, no latent systematic defect
    1 BadRun     : running, latent systematic defect (elevated rate)
    2 AutoRepG   : automated repair, server is good
    3 AutoRepB   : automated repair, server is bad
    4 ManRepG    : manual repair, server is good
    5 ManRepB    : manual repair, server is bad
    6 Retired    : absorbing (retirement policy; rate 0 at Table-I defaults)
    7 (pad lane) : unreachable, kept for MXU-friendly 8x8 tiles

Transitions (rates per minute). Every failure goes to automated repair
first; with probability (1 - p_auto) the automated stage cannot resolve it
and the server escalates to manual repair (serial pipeline, matching the
Rust DES `model::repair`):

    GoodRun  -> AutoRepG          lambda_r
    BadRun   -> AutoRepB          lambda_r + lambda_s
    AutoRepG -> GoodRun           mu_a * p_auto        (resolved by auto)
    AutoRepG -> ManRepG           mu_a * (1 - p_auto)  (escalated)
    AutoRepB -> GoodRun           mu_a * p_auto * (1 - p_auto_fail)
    AutoRepB -> BadRun            mu_a * p_auto * p_auto_fail  (silent fail)
    AutoRepB -> ManRepB           mu_a * (1 - p_auto)  (escalated)
    ManRepG  -> GoodRun           mu_m
    ManRepB  -> GoodRun           mu_m * (1 - p_man_fail)
    ManRepB  -> BadRun            mu_m * p_man_fail * (1 - p_retire)
    ManRepB  -> Retired           mu_m * p_man_fail * p_retire

The transient distribution pi(t) is computed by scaling-and-squaring:
a short uniformized Taylor series builds A0 = expm(Q * T / 2^m) (here, in
jnp), then the Layer-1 Pallas kernel runs the m-step squaring chain with
dyadic captures pi(T/2^m * 2^i).  From the dyadic trajectory we derive the
time-averaged availability, the expected per-server failure rate, the
expected number of job interruptions, and a makespan estimate

    M ~= L / (1 - R*C),   R = N * rbar (job interruption rate),
                          C = recovery + stall expectation per failure.

Parameter-vector column layout (all float32; times in MINUTES, rates in
1/minute) -- the Rust side (`analytical::columns`) mirrors this exactly:

    0  lambda_r            random failure rate
    1  lambda_s            additional systematic rate on bad servers
    2  frac_bad            fraction of bad servers
    3  recovery_time       job recovery time after a failure
    4  job_size            servers required by the job (N)
    5  job_len             failure-free job length (L)
    6  warm_standbys       extra servers allocated to the job
    7  p_auto              P(failure handled by automated repair)
    8  p_auto_fail         P(automated repair fails to fix a bad server)
    9  p_man_fail          P(manual repair fails to fix a bad server)
    10 auto_time           mean automated repair time (1/mu_a)
    11 man_time            mean manual repair time (1/mu_m)
    12 host_selection_time host-selection + restart time
    13 waiting_time        spare-pool preemption wait
    14 working_pool        working-pool size
    15 p_retire            P(retire | manual repair failed)   (0 at defaults)

Outputs, [B, 8] float32 (`analytical::outputs` on the Rust side):

    0 avail_T        P(running) at t = L
    1 avail_avg      time-averaged P(running) over [0, L]
    2 frac_bad_T     P(BadRun | running) at t = L
    3 rbar           time-averaged per-server failure rate (1/min)
    4 exp_failures   expected job interruptions over the makespan
    5 makespan_est   estimated wall-clock job time (minutes)
    6 overhead_frac  R*C, fraction of time lost to failures
    7 pi_retired     P(Retired) at t = L
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.uniformization import M_STEPS, STATES, dyadic_transients

# Static batch size of the AOT artifact; the Rust sweep pre-screener pads
# its config batches to this.
BATCH = 64
N_PARAMS = 16
N_OUTPUTS = 8
# Taylor terms for the A0 series.  With m=16 squarings, q*Delta stays well
# below 1 for every Table-I configuration, so 24 terms is beyond f32
# precision.
K_TERMS = 24

PARAM_NAMES = (
    "lambda_r", "lambda_s", "frac_bad", "recovery_time",
    "job_size", "job_len", "warm_standbys", "p_auto",
    "p_auto_fail", "p_man_fail", "auto_time", "man_time",
    "host_selection_time", "waiting_time", "working_pool", "p_retire",
)

OUTPUT_NAMES = (
    "avail_T", "avail_avg", "frac_bad_T", "rbar",
    "exp_failures", "makespan_est", "overhead_frac", "pi_retired",
)


def build_generator(params: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Build the batched CTMC generator Q [B,S,S] and pi0 [B,S]."""
    b = params.shape[0]
    lam_r = params[:, 0]
    lam_s = params[:, 1]
    frac_bad = params[:, 2]
    p_auto = params[:, 7]
    p_auto_fail = params[:, 8]
    p_man_fail = params[:, 9]
    mu_a = 1.0 / jnp.maximum(params[:, 10], 1e-6)
    mu_m = 1.0 / jnp.maximum(params[:, 11], 1e-6)
    p_retire = params[:, 15]

    lam_bad = lam_r + lam_s
    q = jnp.zeros((b, STATES, STATES), dtype=jnp.float32)
    # Off-diagonal rates (serial auto-then-manual pipeline; see docstring).
    q = q.at[:, 0, 2].set(lam_r)
    q = q.at[:, 1, 3].set(lam_bad)
    q = q.at[:, 2, 0].set(mu_a * p_auto)
    q = q.at[:, 2, 4].set(mu_a * (1.0 - p_auto))
    q = q.at[:, 3, 0].set(mu_a * p_auto * (1.0 - p_auto_fail))
    q = q.at[:, 3, 1].set(mu_a * p_auto * p_auto_fail)
    q = q.at[:, 3, 5].set(mu_a * (1.0 - p_auto))
    q = q.at[:, 4, 0].set(mu_m)
    q = q.at[:, 5, 0].set(mu_m * (1.0 - p_man_fail))
    q = q.at[:, 5, 1].set(mu_m * p_man_fail * (1.0 - p_retire))
    q = q.at[:, 5, 6].set(mu_m * p_man_fail * p_retire)
    # Diagonal = -row sum (Retired and the pad lane stay absorbing/zero).
    row_sum = jnp.sum(q, axis=2)
    q = q - row_sum[:, :, None] * jnp.eye(STATES, dtype=jnp.float32)[None]

    pi0 = jnp.zeros((b, STATES), dtype=jnp.float32)
    pi0 = pi0.at[:, 0].set(1.0 - frac_bad)
    pi0 = pi0.at[:, 1].set(frac_bad)
    return q, pi0


def _norm_sf(z: jax.Array) -> jax.Array:
    """Standard-normal survival function via the Abramowitz-Stegun 7.1.26
    erf approximation (|err| < 1.5e-7).

    Not `jax.scipy.stats.norm.sf`: that lowers to an `erf` HLO opcode that
    xla_extension 0.5.1's text parser rejects.  This polynomial matches the
    Rust mirror (`sim::dist::normal_cdf`) exactly, keeping the PJRT
    artifact and the pure-Rust fallback bit-comparable.
    """
    x = z / jnp.sqrt(2.0).astype(jnp.float32)
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736) * t + 0.254829592
    erf = sign * (1.0 - poly * t * jnp.exp(-ax * ax))
    return 1.0 - 0.5 * (1.0 + erf)


def _expm_uniformized(q: jax.Array, delta: jax.Array, k_terms: int = K_TERMS) -> jax.Array:
    """A0 = expm(Q * Delta) via the uniformized Taylor series (jnp).

    Kept in plain jnp: it is K small batched matmuls and lowers into the
    same HLO module as the kernel; the hot spot (the squaring chain) lives
    in the Pallas kernel.
    """
    s = q.shape[1]
    q_unif = jnp.max(-jnp.diagonal(q, axis1=1, axis2=2), axis=1) * 1.01 + 1e-12
    p = jnp.eye(s, dtype=q.dtype)[None] + q / q_unif[:, None, None]
    qt = q_unif * delta

    def body(k, carry):
        a, pk, w = carry
        a = a + w[:, None, None] * pk
        pk = jnp.einsum("bst,btu->bsu", pk, p, preferred_element_type=jnp.float32)
        w = w * qt / (k + 1.0)
        return a, pk, w

    a0 = jnp.zeros_like(q)
    pk0 = jnp.broadcast_to(jnp.eye(s, dtype=q.dtype)[None], q.shape)
    w0 = jnp.exp(-qt)
    a, pk, w = jax.lax.fori_loop(0, k_terms, body, (a0, pk0, w0))
    return a + w[:, None, None] * pk


def analytic_metrics(params: jax.Array) -> jax.Array:
    """The full batched analytical estimator.  params [B,16] -> [B,8]."""
    lam_r = params[:, 0]
    lam_s = params[:, 1]
    recovery = params[:, 3]
    job_size = params[:, 4]
    job_len = params[:, 5]
    warm = params[:, 6]
    host_sel = params[:, 12]
    waiting = params[:, 13]
    working_pool = params[:, 14]

    q, pi0 = build_generator(params)
    horizon = jnp.maximum(job_len, 1.0)
    delta = horizon / float(2**M_STEPS)
    a0 = _expm_uniformized(q, delta)

    # [B, m+1, S]; caps[:, i] = pi(delta * 2^i), caps[:, m] = pi(horizon).
    caps = dyadic_transients(a0, pi0)

    pi_t = caps[:, -1, :]
    avail_t = pi_t[:, 0] + pi_t[:, 1]
    frac_bad_t = pi_t[:, 1] / jnp.maximum(avail_t, 1e-9)
    pi_retired = pi_t[:, 6]

    # Time-average over [0, horizon] by trapezoid on the dyadic grid
    # {0, d, 2d, 4d, ..., 2^m d}.  Segment widths: d, d, 2d, 4d, ...
    m = M_STEPS
    times = jnp.concatenate(
        [jnp.zeros((1,)), 2.0 ** jnp.arange(m + 1, dtype=jnp.float32)]
    )  # in units of delta, length m+2
    widths = times[1:] - times[:-1]  # [m+1]
    traj = jnp.concatenate([pi0[:, None, :], caps], axis=1)  # [B, m+2, S]
    seg_avg = 0.5 * (traj[:, 1:, :] + traj[:, :-1, :])  # [B, m+1, S]
    pi_avg = jnp.einsum("k,bks->bs", widths, seg_avg) / float(2**m)

    avail_avg = pi_avg[:, 0] + pi_avg[:, 1]
    # Time-averaged per-server failure (job-interruption) rate.
    rbar = pi_avg[:, 0] * lam_r + pi_avg[:, 1] * (lam_r + lam_s)

    # Job-level interruption rate: every active server's failure kills the
    # job (SS II-A: gang semantics).
    big_r = job_size * rbar
    # Cost per interruption: recovery, plus host-selection when the warm
    # standbys are exhausted, plus spare-pool waiting when the working
    # pool's slack is exhausted.  Both exhaustion probabilities are
    # approximated from the expected number of concurrently-unavailable
    # servers U (M/G/inf heuristic: Poisson tail mass above the slack).
    unavail_frac = 1.0 - avail_avg
    u = working_pool * unavail_frac
    slack_ws = jnp.maximum(warm, 1.0)
    slack_wp = jnp.maximum(working_pool - job_size, 1.0)
    # Normal approximation to the Poisson tail P(U' > slack).
    p_hs = _norm_sf((slack_ws - u) / jnp.sqrt(jnp.maximum(u, 1e-6)))
    p_wait = _norm_sf((slack_wp - u) / jnp.sqrt(jnp.maximum(u, 1e-6)))
    cost = recovery + p_hs * host_sel + p_wait * waiting

    # Failures only accrue while the job computes (assumption 7), and the
    # job computes for exactly L minutes in total, so E[failures] = R*L and
    # the makespan is L plus the per-failure costs: M = L * (1 + R*C).
    overhead = big_r * cost
    makespan = job_len * (1.0 + overhead)
    exp_failures = big_r * job_len

    return jnp.stack(
        [avail_t, avail_avg, frac_bad_t, rbar,
         exp_failures, makespan, overhead, pi_retired],
        axis=1,
    )


def analytic_fn(params: jax.Array) -> tuple[jax.Array]:
    """AOT entry point: 1-tuple so the Rust side unwraps with to_tuple1."""
    return (analytic_metrics(params),)
