"""AOT lowering: JAX analytical model -> HLO *text* artifact.

HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

    python -m compile.aot --out ../artifacts/analytic.hlo.txt
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_analytic() -> str:
    spec = jax.ShapeDtypeStruct((model.BATCH, model.N_PARAMS), jnp.float32)
    lowered = jax.jit(model.analytic_fn).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/analytic.hlo.txt")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)

    text = lower_analytic()
    out.write_text(text)
    print(f"wrote {len(text)} chars to {out}")

    # Machine-readable interface contract next to the artifact, so the Rust
    # runtime can validate its column layout at load time.
    meta = {
        "batch": model.BATCH,
        "n_params": model.N_PARAMS,
        "n_outputs": model.N_OUTPUTS,
        "param_names": list(model.PARAM_NAMES),
        "output_names": list(model.OUTPUT_NAMES),
        "m_steps": __import__("compile.kernels.uniformization", fromlist=["M_STEPS"]).M_STEPS,
    }
    meta_path = out.with_suffix(".json")
    meta_path.write_text(json.dumps(meta, indent=2) + "\n")
    print(f"wrote interface contract to {meta_path}")


if __name__ == "__main__":
    main()
