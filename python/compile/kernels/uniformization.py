"""Layer-1 Pallas kernel: batched matrix-power (squaring) chain.

The analytical CTMC baseline computes the transient distribution pi(T) of a
per-server reliability Markov chain by scaling-and-squaring:

    A_0   = expm(Q * Delta)          (short uniformized Taylor series, L2)
    A_i+1 = A_i @ A_i                (this kernel, m static steps)
    pi(2^i * Delta) = pi0 @ A_i      (dyadic capture, this kernel)

so that with Delta = T / 2^m the final capture is exactly pi(T).  The
batched [B, S, S] squaring chain is the compute hot spot of the analytical
sweep pre-screener; it is expressed here as a Pallas kernel so the whole
estimator lowers into one HLO module.

TPU adaptation (DESIGN.md SS Hardware-Adaptation): the chain is rank-S
matmuls with S padded from 7 live states to 8 lanes; the grid partitions the
batch dimension so each step holds one [BT, 8, 8] tile set in VMEM
(~BT*576 B -- VMEM-resident trivially; the roofline is MXU-rank-bound and
documented rather than inflated).  interpret=True everywhere: the CPU PJRT
client cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of padded CTMC states (7 live + 1 pad lane).
STATES = 8
# Default number of squaring steps: T = Delta * 2**M_STEPS.
M_STEPS = 16
# Default batch tile for the Pallas grid.
BLOCK_B = 8


def _squaring_kernel(m_steps: int, a_ref, v0_ref, caps_ref):
    """One grid step: squaring chain with dyadic captures for a batch tile.

    a_ref    : [BT, S, S]  base matrix A_0 = expm(Q Delta)
    v0_ref   : [BT, S]     initial distribution pi0
    caps_ref : [BT, m+1, S] output; caps[:, i] = pi0 @ A_0^(2^i)
    """
    a = a_ref[...]
    v0 = v0_ref[...]
    for i in range(m_steps):
        # pi(Delta * 2^i) = pi0 @ A_i
        caps_ref[:, i, :] = jnp.einsum(
            "bs,bst->bt", v0, a, preferred_element_type=jnp.float32
        )
        # A_{i+1} = A_i @ A_i  (batched 8x8 matmul -- the MXU hot spot)
        a = jnp.einsum("bst,btu->bsu", a, a, preferred_element_type=jnp.float32)
    # Final capture: pi(Delta * 2^m) = pi(T).
    caps_ref[:, m_steps, :] = jnp.einsum(
        "bs,bst->bt", v0, a, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("m_steps", "block_b"))
def dyadic_transients(
    a0: jax.Array,
    pi0: jax.Array,
    *,
    m_steps: int = M_STEPS,
    block_b: int = BLOCK_B,
) -> jax.Array:
    """Batched dyadic transient distributions via the Pallas squaring kernel.

    Args:
      a0:  [B, S, S] float32, one-step transition matrix expm(Q Delta).
      pi0: [B, S]    float32, initial distribution.
      m_steps: number of squarings (static).
      block_b: batch tile size for the grid (static; must divide B).

    Returns:
      caps [B, m_steps + 1, S]: caps[:, i] = pi0 @ a0^(2^i); the last entry
      is pi at the full horizon T = Delta * 2^m_steps.
    """
    b, s, s2 = a0.shape
    assert s == s2 == STATES, f"expected padded S={STATES}, got {a0.shape}"
    assert pi0.shape == (b, s)
    assert b % block_b == 0, f"batch {b} not a multiple of tile {block_b}"

    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_squaring_kernel, m_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, s, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m_steps + 1, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m_steps + 1, s), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(a0, pi0)
