"""Pure-jnp correctness oracles for the Layer-1 Pallas kernel.

Everything here is straight-line jax.numpy with no Pallas -- the reference
the kernel must match (pytest + hypothesis drive assert_allclose between
the two implementations across shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dyadic_transients_ref(a0: jax.Array, pi0: jax.Array, m_steps: int) -> jax.Array:
    """Reference for kernels.uniformization.dyadic_transients.

    caps[:, i] = pi0 @ a0^(2^i) computed with a plain Python loop over
    batched jnp einsums.
    """
    a = a0
    caps = []
    for _ in range(m_steps):
        caps.append(jnp.einsum("bs,bst->bt", pi0, a))
        a = jnp.einsum("bst,btu->bsu", a, a)
    caps.append(jnp.einsum("bs,bst->bt", pi0, a))
    return jnp.stack(caps, axis=1)


def expm_series_ref(q: jax.Array, delta: jax.Array, k_terms: int) -> jax.Array:
    """Reference uniformized Taylor series for A_0 = expm(Q * Delta).

    Uses the uniformization form A = sum_k Poisson(q_unif*Delta, k) P^k with
    P = I + Q/q_unif, which keeps every intermediate non-negative (a proper
    stochastic matrix at every truncation).  q: [B, S, S] generator
    matrices; delta: [B] time steps.  Matches model._expm_uniformized.
    """
    b, s, _ = q.shape
    # Uniformization rate: strictly larger than the max outflow rate.
    q_unif = jnp.max(-jnp.diagonal(q, axis1=1, axis2=2), axis=1) * 1.01 + 1e-12
    p = jnp.eye(s, dtype=q.dtype)[None] + q / q_unif[:, None, None]
    qt = q_unif * delta  # [B]
    # w_k = e^{-qt} (qt)^k / k!, accumulated iteratively for stability.
    a = jnp.zeros_like(q)
    pk = jnp.broadcast_to(jnp.eye(s, dtype=q.dtype)[None], q.shape)
    w = jnp.exp(-qt)  # w_0
    for k in range(k_terms):
        a = a + w[:, None, None] * pk
        pk = jnp.einsum("bst,btu->bsu", pk, p)
        w = w * qt / (k + 1)
    return a + w[:, None, None] * pk
