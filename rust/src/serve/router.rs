//! The prescreen fast-path router: answer analytically when the CTMC
//! screen is valid, fall through to the DES otherwise.
//!
//! The analytical layer models the paper's base dynamics — one
//! gang-scheduled job under exponential failure clocks with the default
//! policies, no checkpointing, no topology/workload extensions, no
//! repair-capacity queueing. Inside that envelope `analyze` is the same
//! estimate `airesim prescreen` ranks with, so a `route: auto` serve
//! request can skip the DES entirely (and, warm, skip even the analysis
//! via the prescreen cache). Outside the envelope the screen would be
//! silently wrong, so [`routable`] is a strict whitelist: any knob the
//! CTMC cannot see routes to the DES.

use crate::analytical::AnalyticOutputs;
use crate::config::DistKind;
use crate::model::PolicySpec;
use crate::report::json::Json;
use crate::report::Format;
use crate::scenario::{Scenario, ScenarioKind};

/// Whether the analytical screen models this scenario exactly: a plain
/// untraced single run, default policies, exponential clocks, and none
/// of the DES-only subsystems armed.
pub fn routable(sc: &Scenario) -> bool {
    let p = &sc.params;
    matches!(sc.kind, ScenarioKind::Single { trace: false })
        && sc.policies == PolicySpec::default()
        && p.failure_dist == DistKind::Exponential
        && p.topology.is_none()
        && p.workload.is_none()
        && p.num_jobs == 1
        && p.retirement_threshold == 0
        && p.bad_regen_interval == 0.0
        && p.auto_repair_capacity == 0
        && p.manual_repair_capacity == 0
        && p.preemption_cost == 0.0
        && p.diagnosis_uncertainty == 0.0
        && p.checkpoint_interval == 0.0
        && p.checkpoint_cost == 0.0
        && p.checkpoint_cost_per_server == 0.0
}

/// Field table shared by the json/csv renderings (name, value).
fn fields(o: &AnalyticOutputs) -> [(&'static str, f64); 8] {
    [
        ("avail_t", o.avail_t),
        ("avail_avg", o.avail_avg),
        ("frac_bad_t", o.frac_bad_t),
        ("rbar", o.rbar),
        ("exp_failures", o.exp_failures),
        ("makespan_est", o.makespan_est),
        ("overhead_frac", o.overhead_frac),
        ("pi_retired", o.pi_retired),
    ]
}

/// The analytic block exactly as `airesim analytic` prints it (the CLI
/// prints this string, so the two stay byte-identical by construction).
pub fn analytic_text(o: &AnalyticOutputs) -> String {
    format!(
        "avail_T        {:>14.6}\n\
         avail_avg      {:>14.6}\n\
         frac_bad_T     {:>14.6}\n\
         rbar           {:>14.3e} /min\n\
         exp_failures   {:>14.2}\n\
         makespan_est   {:>14.2} min ({:.2} days)\n\
         overhead_frac  {:>14.4}\n\
         pi_retired     {:>14.6}\n",
        o.avail_t,
        o.avail_avg,
        o.frac_bad_t,
        o.rbar,
        o.exp_failures,
        o.makespan_est,
        o.makespan_est / 1440.0,
        o.overhead_frac,
        o.pi_retired
    )
}

/// The routed answer as one JSON object (`kind: "analytic"` marks it as
/// the screen's estimate, not a DES record).
pub fn analytic_json(o: &AnalyticOutputs) -> Json {
    let mut obj = vec![("kind".to_string(), Json::str("analytic"))];
    for (name, v) in fields(o) {
        obj.push((name.to_string(), Json::Num(v)));
    }
    Json::Obj(obj)
}

/// Render a routed answer in any `--format`.
pub fn render(format: Format, o: &AnalyticOutputs) -> String {
    match format {
        Format::Text => analytic_text(o),
        Format::Json | Format::Ndjson => analytic_json(o).render() + "\n",
        Format::Csv => {
            let mut s = String::from("quantity,value\n");
            for (name, v) in fields(o) {
                s.push_str(&format!("{name},{v}\n"));
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Params;
    use crate::testkit::parse_json;

    fn base() -> Scenario {
        Scenario::single(Params::small_test())
    }

    #[test]
    fn base_single_runs_are_routable() {
        assert!(routable(&base()));
    }

    #[test]
    fn any_des_only_knob_falls_through() {
        let mut traced = base();
        traced.kind = ScenarioKind::Single { trace: true };
        assert!(!routable(&traced), "traces need the DES timeline");

        let mut sweep_doc = base();
        sweep_doc.kind = ScenarioKind::Compare { replications: 3 };
        assert!(!routable(&sweep_doc), "only single runs route");

        let mut pol = base();
        pol.policies.selection = "locality".into();
        assert!(!routable(&pol), "non-default policies are CTMC-blind");

        for (set, msg) in [
            (
                Box::new(|p: &mut Params| p.failure_dist = DistKind::Weibull { shape: 1.5 })
                    as Box<dyn Fn(&mut Params)>,
                "non-exponential clocks",
            ),
            (Box::new(|p: &mut Params| p.num_jobs = 2), "multi-job"),
            (Box::new(|p: &mut Params| p.retirement_threshold = 3), "retirement"),
            (Box::new(|p: &mut Params| p.auto_repair_capacity = 2), "repair queueing"),
            (Box::new(|p: &mut Params| p.checkpoint_interval = 60.0), "checkpointing"),
            (
                Box::new(|p: &mut Params| p.checkpoint_cost_per_server = 0.01),
                "per-server commit cost",
            ),
            (Box::new(|p: &mut Params| p.diagnosis_uncertainty = 0.1), "diagnosis noise"),
        ] {
            let mut sc = base();
            set(&mut sc.params);
            assert!(!routable(&sc), "{msg} must fall through to the DES");
        }
    }

    #[test]
    fn renderings_carry_every_field() {
        let o = crate::analytical::analyze(&Params::small_test());
        let text = analytic_text(&o);
        for label in ["avail_T", "makespan_est", "pi_retired", "days"] {
            assert!(text.contains(label), "text missing {label}");
        }
        let j = parse_json(render(Format::Json, &o).trim_end()).unwrap();
        let Json::Obj(obj) = j else { panic!("object expected") };
        assert_eq!(obj.len(), 9, "kind + 8 metrics");
        let csv = render(Format::Csv, &o);
        assert_eq!(csv.lines().count(), 9, "header + 8 rows: {csv}");
    }
}
