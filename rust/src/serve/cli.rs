//! The CLI command implementations: a thin adapter from argv onto the
//! serving pipeline (and, for the non-scenario subcommands, onto the
//! library layers directly). `src/main.rs` is nothing but a dispatch
//! table over these functions, so the binary and any embedder share one
//! execution path — and `airesim scenario` is the degenerate serve
//! request: one [`pipeline::ExecRequest`], run cold (default
//! [`ExecCtrl`]: no gate, no cancel flag, no warm caches), rendered
//! buffered. Output stays byte-identical to the pre-refactor monolith.

use crate::analytical;
use crate::config::{validate, yaml, Params};
use crate::model::cluster::Simulation;
use crate::model::policy::{
    PolicySpec, CHECKPOINT_NAMES, FAILURE_NAMES, REPAIR_NAMES, SELECTION_NAMES,
};
use crate::report::{self, Format, RunRecord, Sink, SweepRecord, WhatIfRecord};
use crate::runtime::AnalyticModel;
use crate::scenario::{ScenarioKind, ScenarioOutcome};
use crate::serve::{daemon, pipeline, router};
use crate::stats::metrics;
use crate::sweep::ctrl::ExecCtrl;
use crate::sweep::{run_sweep, Sweep};
use crate::trace::{Shared, Trace};
use crate::util::cli::{render_help, Args, OptSpec};
use crate::util::err::{Context, Result};
use crate::{anyhow, bail};
use std::cell::RefCell;
use std::rc::Rc;

pub fn print_usage() {
    println!(
        "AIReSim — discrete event simulator for AI cluster reliability\n\n\
         Subcommands:\n\
         \x20 run            run one simulation and print its outputs\n\
         \x20 sweep          one- or two-way parameter sweep with replications\n\
         \x20 scenario       run a declarative scenario file (single/sweep/\n\
         \x20                whatif/inject/compare/multi/optimize, policies by\n\
         \x20                name; `multi:` runs a labeled study with a combined\n\
         \x20                comparison report, `optimize:` screens knob\n\
         \x20                importance or auto-tunes over a knob grid)\n\
         \x20 serve          daemon: NDJSON scenario requests on stdin, streamed\n\
         \x20                responses on stdout, warm plan caches across requests\n\
         \x20 analytic       run the AOT analytical baseline (PJRT artifact)\n\
         \x20 prescreen      analytically rank a sweep grid, DES the top-k\n\
         \x20 whatif         scale one parameter by a factor, compare outputs\n\
         \x20 list-params    show every sweepable parameter name\n\
         \x20 list-policies  show every named policy per subsystem\n\
         \x20 list-metrics   show every reported output metric (name, unit)\n\n\
         run, sweep, whatif, and scenario accept `--format {{text|json|csv|ndjson}}`;\n\
         prescreen accepts `--format {{text|json}}`.\n\
         Run `airesim <cmd> --help` for per-command options."
    );
}

/// A `--config` file, read and parsed exactly once per invocation
/// (params, policies, and the sweep section all come from this one doc).
struct ConfigDoc {
    path: String,
    doc: yaml::Value,
}

fn load_doc(args: &Args) -> Result<Option<ConfigDoc>> {
    match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            let doc = yaml::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            Ok(Some(ConfigDoc { path: path.to_string(), doc }))
        }
        None => Ok(None),
    }
}

/// Shared option handling: config `params:` + --set name=value[,...].
fn load_params(doc: Option<&ConfigDoc>, args: &Args) -> Result<Params> {
    let mut p = match doc {
        Some(c) => validate::params_from_config(&c.doc)
            .map_err(|e| anyhow!("{}: {e}", c.path))?,
        None => Params::table1_defaults(),
    };
    if let Some(sets) = args.get("set") {
        pipeline::apply_set_clauses(&mut p, sets).map_err(|e| anyhow!("{e}"))?;
    }
    validate::validate(&p)?;
    Ok(p)
}

/// Config `policies:` section + `--policy` overrides, names validated
/// but NOT built against any params — the sweep path checks every point
/// with its overrides applied (`Sweep::validate`), where a point may
/// supply the knob a policy needs (e.g. sweeping `checkpoint_interval`
/// under `checkpoint: periodic`).
fn load_policy_names(doc: Option<&ConfigDoc>, args: &Args) -> Result<PolicySpec> {
    let mut spec = match doc {
        Some(c) => crate::sweep::policies_from_doc(&c.doc)
            .map_err(|e| anyhow!("{}: {e}", c.path))?,
        None => PolicySpec::default(),
    };
    if let Some(clauses) = args.get("policy") {
        pipeline::apply_policy_clauses(&mut spec, clauses).map_err(|e| anyhow!("{e}"))?;
    }
    Ok(spec)
}

/// Config `policies:` section + `--policy` overrides, validated to build
/// against `p` (so an incompatible combo — e.g. `failure=gang` with
/// Weibull clocks — is a clean CLI error, not a worker-thread panic).
fn load_policies(doc: Option<&ConfigDoc>, args: &Args, p: &Params) -> Result<PolicySpec> {
    let spec = load_policy_names(doc, args)?;
    spec.build(p).map_err(|e| anyhow!("{e}"))?;
    Ok(spec)
}

fn common_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", takes_value: true, help: "YAML config file" },
        OptSpec {
            name: "set",
            takes_value: true,
            help: "comma-separated name=value overrides (exprs ok: 2*1440)",
        },
        OptSpec {
            name: "policy",
            takes_value: true,
            help: "policy overrides: axis=name,... (see list-policies)",
        },
        OptSpec { name: "help", takes_value: false, help: "show help" },
    ]
}

fn format_opt() -> OptSpec {
    OptSpec {
        name: "format",
        takes_value: true,
        help: "output format: text|json|csv|ndjson (default text)",
    }
}

fn trace_out_opt() -> OptSpec {
    OptSpec {
        name: "trace-out",
        takes_value: true,
        help: "write the event timeline as NDJSON to a file (- = stdout)",
    }
}

/// Resolve `--format` (default: the legacy text tables).
fn parse_format(args: &Args) -> Result<Format> {
    match args.get("format") {
        Some(s) => Format::parse(s).map_err(|e| anyhow!("{e}")),
        None => Ok(Format::Text),
    }
}

/// Resolve `--metric` against the registry (typos become a clean error
/// naming every valid metric instead of an empty table).
fn parse_metric(args: &Args) -> Result<&str> {
    let name = args.get("metric").unwrap_or(metrics::DEFAULT_METRIC);
    metrics::resolve(name).map_err(|e| anyhow!("{e}"))?;
    Ok(name)
}

/// Dump an NDJSON event timeline to `path` (`-` = stdout).
fn write_trace_out(path: &str, ndjson: &str) -> Result<()> {
    if path == "-" {
        print!("{ndjson}");
        Ok(())
    } else {
        std::fs::write(path, ndjson).with_context(|| format!("writing trace to {path}"))
    }
}

pub fn cmd_run(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "seed", takes_value: true, help: "rng seed (default 42)" },
        OptSpec { name: "trace", takes_value: false, help: "print the event trace" },
        trace_out_opt(),
        format_opt(),
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!("{}", render_help("airesim run", "run one simulation", &spec));
        return Ok(());
    }
    let format = parse_format(&args)?;
    // `--trace-out -` shares stdout with the report: fine for text (the
    // legacy --trace shape) and ndjson (one object per line), but it
    // would corrupt a json document or csv table.
    if args.get("trace-out") == Some("-") && matches!(format, Format::Json | Format::Csv) {
        bail!(
            "--trace-out - mixes event lines into --format {} output; \
             write the trace to a file instead",
            format.name()
        );
    }
    let doc = load_doc(&args)?;
    let p = load_params(doc.as_ref(), &args)?;
    let policies = load_policies(doc.as_ref(), &args, &p)?;
    let seed = args.get_u64("seed")?.unwrap_or(42);

    let mut sim = Simulation::from_spec(&p, &policies, crate::sim::rng::Rng::new(seed))
        .map_err(|e| anyhow!("{e}"))?;
    if args.flag("trace") {
        sim = sim.with_trace();
    }
    // `--trace-out` goes through the Observer API: an event log shared
    // with the simulation streams the timeline regardless of `--trace`.
    let event_log = if args.get("trace-out").is_some() {
        let log = Rc::new(RefCell::new(Trace::default()));
        sim = sim.with_observer(Box::new(Shared(log.clone())));
        Some(log)
    } else {
        None
    };
    let (out, mut trace) = sim.run_traced();

    if let (Some(path), Some(log)) = (args.get("trace-out"), event_log) {
        write_trace_out(path, &log.borrow().to_ndjson())?;
        if path == "-" && format == Format::Ndjson {
            // The timeline is already on stdout in the sink's own event
            // schema; emitting it again from the record would double
            // every event for downstream `jq` aggregations.
            trace = Trace::default();
        }
    }
    let record = RunRecord { seed, params: p, policies, outputs: out, trace };
    print!("{}", format.sink().run(&record));
    Ok(())
}

fn parse_values(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|x| yaml::eval_expr(x.trim()).map_err(|e| anyhow!("{e}")))
        .collect()
}

pub fn cmd_sweep(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "param", takes_value: true, help: "swept parameter name" },
        OptSpec { name: "values", takes_value: true, help: "comma-separated values" },
        OptSpec { name: "param2", takes_value: true, help: "second axis (two-way)" },
        OptSpec { name: "values2", takes_value: true, help: "second-axis values" },
        OptSpec { name: "reps", takes_value: true, help: "replications (default 30)" },
        OptSpec { name: "seed", takes_value: true, help: "master seed (default 42)" },
        OptSpec { name: "threads", takes_value: true, help: "worker threads (0=auto)" },
        OptSpec {
            name: "metric",
            takes_value: true,
            help: "metric to report (default makespan_hours)",
        },
        OptSpec { name: "csv", takes_value: false, help: "legacy CSV flag (equivalent: --format csv)" },
        OptSpec { name: "figure", takes_value: false, help: "emit Fig-2-style bar series" },
        format_opt(),
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!("{}", render_help("airesim sweep", "parameter sweep", &spec));
        return Ok(());
    }
    // Validate the cheap flags before any simulation work: a mistyped
    // `--format`/`--metric` must not cost a full multi-replication sweep.
    let format = match args.get("format") {
        Some(s) => Some(Format::parse(s).map_err(|e| anyhow!("{e}"))?),
        None => None,
    };
    if format.is_some() && (args.flag("figure") || args.flag("csv")) {
        bail!("--format is mutually exclusive with the legacy --csv/--figure flags");
    }
    let doc = load_doc(&args)?;
    let base = load_params(doc.as_ref(), &args)?;
    let reps = args.get_usize("reps")?.unwrap_or(30);
    let seed = args.get_u64("seed")?.unwrap_or(42);
    let threads = args.get_usize("threads")?.unwrap_or(0);
    let metric = parse_metric(&args)?;

    let sweep = match (args.get("param"), args.get("values")) {
        (Some(name), Some(values)) => {
            let xs = parse_values(values)?;
            match (args.get("param2"), args.get("values2")) {
                (Some(n2), Some(v2)) => Sweep::two_way(
                    &format!("{name} x {n2}"),
                    name,
                    &xs,
                    n2,
                    &parse_values(v2)?,
                    reps,
                    seed,
                ),
                _ => Sweep::one_way(name, name, &xs, reps, seed),
            }
        }
        _ => sweep_from_config(doc.as_ref(), reps, seed)?,
    }
    .with_policies(load_policy_names(doc.as_ref(), &args)?);
    // Policy axes (and any bad point) fail here, not in a worker thread —
    // every point is built with its overrides applied, so a swept knob
    // can satisfy a policy the bare base params would not.
    sweep.validate(&base).map_err(|e| anyhow!("{e}"))?;

    let result = run_sweep(&base, &sweep, threads);
    match format {
        Some(f) => print!("{}", f.sink().sweep(&SweepRecord::new(result, metric))),
        None if args.flag("csv") => print!("{}", report::csv(&result, metric)),
        None if args.flag("figure") => {
            print!("{}", report::figure_series(&result, metric))
        }
        None => print!("{}", report::text_table(&result, metric)),
    }
    Ok(())
}

/// Run a declarative scenario file through the serving pipeline: the
/// flags become one [`pipeline::ExecRequest`] — exactly what a serve
/// request submits — run cold and rendered buffered.
pub fn cmd_scenario(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "seed", takes_value: true, help: "override the file's seed" },
        OptSpec { name: "threads", takes_value: true, help: "worker threads (0=auto)" },
        OptSpec {
            name: "best-out",
            takes_value: true,
            help: "optimize tune: write the winner as a runnable single-scenario YAML (- = stdout)",
        },
        trace_out_opt(),
        format_opt(),
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!(
            "{}",
            render_help("airesim scenario", "run a declarative scenario file", &spec)
        );
        return Ok(());
    }
    let format = parse_format(&args)?;
    let path = args
        .get("config")
        .ok_or_else(|| anyhow!("scenario needs --config <file.yaml>"))?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading scenario {path}"))?;
    let req = pipeline::ExecRequest {
        doc: text,
        format,
        seed: args.get_u64("seed")?,
        threads: args.get_usize("threads")?,
        sets: args.get("set").map(str::to_string),
        policies: args.get("policy").map(str::to_string),
        trace: false,
        route: pipeline::Route::Des,
        origin: Some(path.to_string()),
    };
    let mut prep = pipeline::prepare(&req).map_err(|e| anyhow!("{e}"))?;

    // `--trace-out` needs the event timeline captured; remember whether
    // the file asked for a printed trace itself, so the stdout report
    // stays byte-identical when it did not.
    let mut forced_trace = false;
    if let Some(out_path) = args.get("trace-out") {
        // Same stdout-corruption guard as `airesim run`.
        if out_path == "-" && matches!(format, Format::Json | Format::Csv) {
            bail!(
                "--trace-out - mixes event lines into --format {} output; \
                 write the trace to a file instead",
                format.name()
            );
        }
        match &mut prep.scenario.kind {
            ScenarioKind::Single { trace } | ScenarioKind::Inject { trace, .. } => {
                forced_trace = !*trace;
                *trace = true;
            }
            // A study of single-style children (one replication each)
            // can dump one timeline per child; with replications > 1 a
            // single file would be a misleading sample.
            ScenarioKind::Multi(study) => {
                if study.replications != 1 {
                    bail!(
                        "--trace-out on a multi study needs `replications: 1` \
                         (single-style children; this study runs {})",
                        study.replications
                    );
                }
            }
            _ => bail!(
                "--trace-out applies to single/inject scenarios and \
                 replications-1 multi studies (event timelines)"
            ),
        }
    }

    // `--best-out` asks for the tune winner as a runnable single-run
    // YAML; validate the request before paying for the search.
    if args.get("best-out").is_some() {
        if !matches!(prep.scenario.kind, ScenarioKind::Optimize(_)) {
            bail!("--best-out applies to `scenario: optimize` (mode: tune) only");
        }
        // Same stdout-corruption guard as `--trace-out -`: YAML lines
        // would break a json document or csv table.
        if args.get("best-out") == Some("-") && !matches!(format, Format::Text) {
            bail!(
                "--best-out - mixes YAML into --format {} output; \
                 write the winner to a file instead",
                format.name()
            );
        }
        // The emitted file pins scalar params + policies; it cannot
        // express a topology: or workload: block, so a winner written
        // without them would silently run a different experiment.
        if prep.scenario.params.topology.is_some() || prep.scenario.params.workload.is_some()
        {
            bail!(
                "--best-out cannot express `topology:`/`workload:` blocks in the \
                 emitted single-run YAML; drop --best-out or the block"
            );
        }
    }

    let result =
        pipeline::run_prepared(&prep, &ExecCtrl::default()).map_err(|e| anyhow!("{e}"))?;
    let pipeline::RunResult::Des(mut outcome) = result else {
        unreachable!("route=des with no cancel flag always yields a DES outcome");
    };
    if let Some(out_path) = args.get("best-out") {
        let ScenarioOutcome::Optimize(record) = &outcome else {
            unreachable!("guarded above");
        };
        let best = record.best.as_ref().ok_or_else(|| {
            anyhow!("--best-out needs `optimize.mode: tune` (screen ranks knobs, it picks no winner)")
        })?;
        if out_path == "-" {
            print!("{}", best.yaml);
        } else {
            std::fs::write(out_path, &best.yaml)
                .with_context(|| format!("writing best config to {out_path}"))?;
        }
    }
    if let Some(out_path) = args.get("trace-out") {
        match &mut outcome {
            ScenarioOutcome::Single { trace, .. } | ScenarioOutcome::Inject { trace, .. } => {
                write_trace_out(out_path, &trace.to_ndjson())?;
                if forced_trace || (out_path == "-" && format == Format::Ndjson) {
                    // Either the trace existed only to feed the timeline
                    // file, or the timeline is already on stdout in the
                    // same schema — keep the report single-copy.
                    *trace = Trace::default();
                }
            }
            ScenarioOutcome::Study(_) => {
                // Replication 0 of every child, re-run traced (traces
                // never perturb draws — the report above is untouched).
                let ScenarioKind::Multi(study) = &prep.scenario.kind else {
                    unreachable!("outcome kind matches scenario kind");
                };
                let timelines = crate::scenario::study::child_timelines(
                    &prep.scenario.params,
                    &prep.scenario.policies,
                    study,
                    prep.scenario.seed,
                )
                .map_err(|e| anyhow!("{e}"))?;
                let mut ndjson = String::new();
                for (label, trace) in &timelines {
                    // A separator line names the child; the event lines
                    // that follow use the standard timeline schema.
                    let sep = crate::report::json::Json::obj([
                        ("type", crate::report::json::Json::str("child-timeline")),
                        ("label", crate::report::json::Json::str(label.as_str())),
                    ]);
                    ndjson.push_str(&(sep.render() + "\n"));
                    ndjson.push_str(&trace.to_ndjson());
                }
                write_trace_out(out_path, &ndjson)?;
            }
            _ => unreachable!("guarded above"),
        }
    }
    print!("{}", pipeline::render_outcome(prep.format, &prep.scenario, outcome));
    Ok(())
}

/// The serve daemon: NDJSON requests on stdin, responses on stdout (see
/// [`crate::serve::daemon`] for the protocol), or — with the `http`
/// feature — a minimal HTTP POST endpoint.
pub fn cmd_serve(argv: &[String]) -> Result<()> {
    let spec = vec![
        OptSpec {
            name: "threads",
            takes_value: true,
            help: "worker slots shared across ALL concurrent requests (0=auto)",
        },
        OptSpec {
            name: "fleet-cache",
            takes_value: true,
            help: "warm fleet-cache capacity, entries (default 256)",
        },
        OptSpec {
            name: "http",
            takes_value: true,
            help: "serve HTTP POST on addr:port instead of stdin/stdout (needs the `http` feature)",
        },
        OptSpec { name: "help", takes_value: false, help: "show help" },
    ];
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!(
            "{}",
            render_help("airesim serve", "NDJSON request daemon with warm caches", &spec)
        );
        return Ok(());
    }
    let opts = daemon::ServeOpts {
        threads: args.get_usize("threads")?.unwrap_or(0),
        fleet_cache: args.get_usize("fleet-cache")?.unwrap_or(256),
    };
    if let Some(_addr) = args.get("http") {
        #[cfg(feature = "http")]
        return crate::serve::http::serve(_addr, &opts);
        #[cfg(not(feature = "http"))]
        bail!(
            "this build lacks the `http` feature (rebuild with --features http); \
             stdin/stdout serving needs no feature"
        );
    }
    let stdin = std::io::stdin();
    daemon::serve_loop(stdin.lock(), std::io::stdout(), &opts)
        .map_err(|e| anyhow!("serve io: {e}"))
}

pub fn cmd_list_metrics() -> Result<()> {
    println!("{:<20} {:<6} {}", "metric", "unit", "description");
    for m in metrics::REGISTRY {
        println!("{:<20} {:<6} {}", m.name, m.unit, m.doc);
    }
    println!(
        "\nselect a table's metric with `--metric <name>`; the json/ndjson \
         sinks emit every metric"
    );
    Ok(())
}

pub fn cmd_list_policies() -> Result<()> {
    println!("{:<12} {}", "axis", "named policies (first is default)");
    println!("{:<12} {}", "selection", SELECTION_NAMES.join(", "));
    println!("{:<12} {}", "repair", REPAIR_NAMES.join(", "));
    println!("{:<12} {}", "checkpoint", CHECKPOINT_NAMES.join(", "));
    println!("{:<12} {}", "failure", FAILURE_NAMES.join(", "));
    println!(
        "\nselect per-axis with `--policy axis=name,...` or a config's \
         `policies:` section"
    );
    Ok(())
}

fn sweep_from_config(doc: Option<&ConfigDoc>, reps: usize, seed: u64) -> Result<Sweep> {
    let c = doc.ok_or_else(|| {
        anyhow!("sweep needs --param/--values or a config with a sweep: section")
    })?;
    crate::sweep::sweep_from_doc(&c.doc, reps, seed)
        .map_err(|e| anyhow!("{}: {e}", c.path))
}

pub fn cmd_analytic(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "artifact", takes_value: true, help: "HLO artifact path" },
        OptSpec {
            name: "rust-only",
            takes_value: false,
            help: "skip PJRT, use the pure-Rust mirror",
        },
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!(
            "{}",
            render_help("airesim analytic", "analytical CTMC baseline", &spec)
        );
        return Ok(());
    }
    let doc = load_doc(&args)?;
    let p = load_params(doc.as_ref(), &args)?;
    let rust_out = analytical::analyze(&p);
    println!("== analytical baseline (pure rust) ==");
    // The router's rendering IS the legacy block (one format string for
    // both the CLI and routed serve answers keeps them byte-identical).
    print!("{}", router::analytic_text(&rust_out));

    if !args.flag("rust-only") {
        let path = args.get("artifact").unwrap_or(AnalyticModel::default_path());
        // Degrade, don't die: without the `pjrt` feature (or artifact)
        // the pure-Rust mirror above is the answer.
        match AnalyticModel::load(path) {
            Ok(model) => {
                println!(
                    "\n== analytical baseline (PJRT artifact, platform {}) ==",
                    model.platform()
                );
                let pjrt_out = model.analyze_many(std::slice::from_ref(&p))?[0];
                print!("{}", router::analytic_text(&pjrt_out));
                let rel = (pjrt_out.makespan_est - rust_out.makespan_est).abs()
                    / rust_out.makespan_est.max(1.0);
                println!("\nmakespan_est rust-vs-pjrt relative delta: {rel:.2e}");
            }
            Err(e) => {
                eprintln!("note: PJRT path unavailable ({e:#}); the pure-Rust mirror above stands");
            }
        }
    }
    Ok(())
}

/// The three-layer workflow in one command: the AOT CTMC artifact screens
/// the whole sweep grid in one PJRT batch pass, then the DES validates
/// only the most promising configurations (§II-C: analytical for breadth,
/// DES for fidelity).
pub fn cmd_prescreen(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "param", takes_value: true, help: "swept parameter name" },
        OptSpec { name: "values", takes_value: true, help: "comma-separated values" },
        OptSpec { name: "param2", takes_value: true, help: "second axis (two-way)" },
        OptSpec { name: "values2", takes_value: true, help: "second-axis values" },
        OptSpec { name: "top", takes_value: true, help: "DES-validate the best k (default 3)" },
        OptSpec { name: "reps", takes_value: true, help: "DES replications for the top-k (default 10)" },
        OptSpec { name: "seed", takes_value: true, help: "master seed (default 42)" },
        OptSpec { name: "artifact", takes_value: true, help: "HLO artifact path" },
        OptSpec {
            name: "format",
            takes_value: true,
            help: "output format: text|json (default text)",
        },
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!(
            "{}",
            render_help("airesim prescreen", "analytical screen + DES top-k", &spec)
        );
        return Ok(());
    }
    // Validate before any simulation work (as the other commands do).
    let format = parse_format(&args)?;
    if !matches!(format, Format::Text | Format::Json) {
        bail!("prescreen supports --format text or json");
    }
    // In json mode every progress/diagnostic line moves to stderr so
    // stdout stays one parseable document; text output is unchanged.
    let note = |line: &str| {
        if format == Format::Json {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    let doc = load_doc(&args)?;
    let base = load_params(doc.as_ref(), &args)?;
    let policies = load_policies(doc.as_ref(), &args, &base)?;
    let top = args.get_usize("top")?.unwrap_or(3);
    let reps = args.get_usize("reps")?.unwrap_or(10);
    let seed = args.get_u64("seed")?.unwrap_or(42);

    // Build the grid (CLI axes or config sweep section).
    let sweep = match (args.get("param"), args.get("values")) {
        (Some(name), Some(values)) => {
            let xs = parse_values(values)?;
            match (args.get("param2"), args.get("values2")) {
                (Some(n2), Some(v2)) => Sweep::two_way(
                    &format!("{name} x {n2}"),
                    name,
                    &xs,
                    n2,
                    &parse_values(v2)?,
                    reps,
                    seed,
                ),
                _ => Sweep::one_way(name, name, &xs, reps, seed),
            }
        }
        _ => sweep_from_config(doc.as_ref(), reps, seed)?,
    };
    // The CTMC screen cannot see policies: a `policies.*` axis would
    // rank identically-parameterized points under distinct policy labels
    // — silently wrong. Refuse instead of misinforming.
    if sweep
        .points
        .iter()
        .any(|pt| pt.overrides.iter().any(|(name, _)| name.starts_with("policies.")))
    {
        bail!(
            "prescreen's analytical screen is policy-blind and cannot rank \
             `policies.*` sweep axes; run them through `airesim sweep` or \
             `airesim scenario` instead"
        );
    }
    let configs: Vec<Params> = sweep.points.iter().map(|pt| pt.apply(&base)).collect();
    if policies != PolicySpec::default() {
        note(
            "note: the CTMC screen is policy-blind; the selected policies apply \
             to the DES validation only",
        );
    }

    // Layer 2/1 via PJRT: one batched pass over the whole grid.
    let path = args.get("artifact").unwrap_or(AnalyticModel::default_path());
    let screened: Vec<crate::analytical::AnalyticOutputs> =
        match AnalyticModel::load(path) {
            Ok(model) => {
                note(&format!(
                    "screening {} configurations through the PJRT artifact ({})…",
                    configs.len(),
                    model.platform()
                ));
                model.analyze_many(&configs)?
            }
            Err(e) => {
                eprintln!("note: PJRT artifact unavailable ({e:#}); using the Rust mirror");
                configs.iter().map(crate::analytical::analyze).collect()
            }
        };

    let mut order: Vec<usize> = (0..configs.len()).collect();
    order.sort_by(|&a, &b| {
        screened[a].makespan_est.partial_cmp(&screened[b].makespan_est).unwrap()
    });

    // Stream the ranking before the DES stage (text mode): a failing
    // replication must not discard the screening work already done.
    let ranking: Vec<(String, crate::analytical::AnalyticOutputs)> =
        order.iter().map(|&i| (sweep.points[i].label(), screened[i])).collect();
    if format == Format::Text {
        print!("{}", report::PrescreenRecord::ranking_text(&ranking));
    }

    // Layer 3: DES-validate the survivors, then render the rest (text =
    // the legacy tables, byte-identical).
    let k = top.min(order.len());
    let mut validated = Vec::with_capacity(k);
    for &i in order.iter().take(k) {
        let p = &configs[i];
        let mut vals = Vec::with_capacity(reps);
        for r in 0..reps {
            let out = Simulation::from_spec(
                p,
                &policies,
                crate::sim::rng::Rng::derived(seed, &[i as u64, r as u64]),
            )
            .map_err(|e| anyhow!("{e}"))?
            .run();
            vals.push(out.makespan / 60.0);
        }
        let s = crate::stats::Summary::from_values(&vals).unwrap();
        validated.push((sweep.points[i].label(), s));
    }
    let record = report::PrescreenRecord { ranking, validated, reps };
    match format {
        Format::Json => print!("{}", record.to_json().render() + "\n"),
        _ => print!("{}", record.validation_text()),
    }
    Ok(())
}

pub fn cmd_whatif(argv: &[String]) -> Result<()> {
    let mut spec = common_spec();
    spec.extend([
        OptSpec { name: "param", takes_value: true, help: "parameter to scale" },
        OptSpec { name: "factor", takes_value: true, help: "multiplier (e.g. 0.5, 2)" },
        OptSpec { name: "reps", takes_value: true, help: "replications (default 30)" },
        OptSpec { name: "seed", takes_value: true, help: "master seed" },
        format_opt(),
    ]);
    let args = Args::parse(argv, &spec)?;
    if args.flag("help") {
        print!("{}", render_help("airesim whatif", "what-if scenario", &spec));
        return Ok(());
    }
    let format = parse_format(&args)?;
    let doc = load_doc(&args)?;
    let base = load_params(doc.as_ref(), &args)?;
    let name = args.get("param").ok_or_else(|| anyhow!("--param required"))?;
    let factor = args
        .get_f64("factor")?
        .ok_or_else(|| anyhow!("--factor required"))?;
    let reps = args.get_usize("reps")?.unwrap_or(30);
    let seed = args.get_u64("seed")?.unwrap_or(42);

    let current = base
        .get_by_name(name)
        .ok_or_else(|| anyhow!("unknown parameter `{name}`"))?;
    let scaled = current * factor;
    let sweep = Sweep::one_way(
        &format!("what-if: {name} x{factor}"),
        name,
        &[current, scaled],
        reps,
        seed,
    )
    .with_policies(load_policies(doc.as_ref(), &args, &base)?);
    let result = run_sweep(&base, &sweep, 0);
    let record = WhatIfRecord {
        result,
        param: name.to_string(),
        factor,
        metric: metrics::DEFAULT_METRIC.to_string(),
    };
    print!("{}", format.sink().whatif(&record));
    Ok(())
}

pub fn cmd_list_params() -> Result<()> {
    let p = Params::table1_defaults();
    println!("{:<28} {:>16}", "parameter", "Table-I default");
    for name in Params::sweepable_names() {
        println!("{:<28} {:>16.6}", name, p.get_by_name(name).unwrap());
    }
    Ok(())
}
