//! The serving layer: one execution path from the CLI to `airesim
//! serve`.
//!
//! Every way of running an experiment funnels through the same shape:
//!
//! ```text
//! ExecRequest { scenario doc, format, seed, … }
//!     │  pipeline::prepare      — parse, overrides, validate, fingerprint
//!     ▼
//! Prepared { Scenario, Format, fingerprint, route }
//!     │  pipeline::run_prepared — router fast path or the DES, under an
//!     ▼                           ambient ExecCtrl (gate/cancel/warm)
//! RunResult ── pipeline::render ─▶ the output text (a stream of records)
//! ```
//!
//! - [`cli`] is the thin adapter the `airesim` binary dispatches to: the
//!   `scenario` subcommand builds one [`pipeline::ExecRequest`] and runs
//!   it cold (no gate, no cancel, no warm cache), byte-identical to the
//!   pre-refactor CLI.
//! - [`daemon`] is `airesim serve`: NDJSON requests on stdin, streamed
//!   NDJSON responses per request id, per-request cancellation, fair
//!   multiplexing of concurrent requests over one shared worker budget.
//! - [`cache`] holds the warm plan caches (fleets, topologies, CTMC
//!   prescreen answers) keyed by a canonical config fingerprint.
//! - [`router`] answers prescreen-routable requests analytically without
//!   touching the DES.
//! - [`http`] (feature `http`) exposes the same pipeline over a minimal
//!   HTTP/1.0 POST endpoint; the default build has no network surface.

pub mod cache;
pub mod cli;
pub mod daemon;
#[cfg(feature = "http")]
pub mod http;
pub mod pipeline;
pub mod router;
