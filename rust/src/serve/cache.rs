//! Warm plan caches keyed by a canonical config fingerprint.
//!
//! The serving access pattern (ROADMAP item 4, Kokolis et al.'s
//! operator loop) is many near-identical what-ifs over one shared base
//! cluster. Three artifacts of a run are pure functions of the config
//! (and, for fleets, the RNG stream position) and dominate setup cost at
//! scale, so the daemon keeps them warm across requests:
//!
//! - **Topology** — [`Topology::build`] is RNG-free and deterministic in
//!   the spec, keyed by fingerprint alone.
//! - **Fleets** — [`build_fleet_into`] is deterministic in `(params,
//!   rng state)`: the cache key is `(fingerprint, state before)` and the
//!   value carries the state *after*, so a hit restores both the fleet
//!   and the stream position and the run continues byte-identically to a
//!   cold build.
//! - **CTMC prescreen results** — [`crate::analytical::analyze`] is a
//!   pure function of the config, keyed by fingerprint alone (the
//!   prescreen fast-path router's answer store).
//!
//! The fingerprint is an FNV-1a hash over every sweepable parameter by
//! name plus the non-numeric config (failure distribution, topology
//! levels, workload spec), so any knob change — including params added
//! in future PRs, which join `sweepable_names` — lands in a different
//! cache line. Collisions are the usual 64-bit-hash risk, accepted as
//! such (a collision serves a wrong-but-valid cached artifact; at
//! interactive request volumes the probability is negligible).

use crate::config::{DistKind, Params};
use crate::model::server::{build_fleet_into, Server};
use crate::model::topology::Topology;
use crate::sim::rng::Rng;
// lint:allow(hash-container) keyed lookup only; LRU eviction picks the unique
// min stamp, so iteration order never reaches an observable result.
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(FNV_BASIS)
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
        self.bytes(&[0xff]); // field separator: "ab"+"c" != "a"+"bc"
    }

    fn f64(&mut self, v: f64) {
        self.bytes(&v.to_bits().to_le_bytes());
    }
}

/// Canonical fingerprint of a parameter set: equal configs hash equal,
/// and any knob the simulator reads lands in the hash (numeric params
/// via the [`Params::sweepable_names`] registry, so future params are
/// covered automatically; distribution/topology/workload explicitly).
pub fn fingerprint(p: &Params) -> u64 {
    let mut h = Fnv::new();
    for &name in Params::sweepable_names() {
        h.str(name);
        h.f64(p.get_by_name(name).expect("registry names always resolve"));
    }
    h.str(p.failure_dist.name());
    match p.failure_dist {
        DistKind::Exponential => {}
        DistKind::Weibull { shape } => h.f64(shape),
        DistKind::LogNormal { sigma } => h.f64(sigma),
    }
    if let Some(t) = &p.topology {
        h.str("topology");
        for l in &t.levels {
            h.str(&l.name);
            h.f64(l.size as f64);
            h.f64(l.outage_rate);
        }
    }
    if let Some(w) = &p.workload {
        h.str("workload");
        // The spec is plain data (arrival process + classes); its Debug
        // form is a canonical rendering of every field.
        h.str(&format!("{w:?}"));
    }
    h.0
}

/// Cache-traffic counters, cumulative over the cache's lifetime. The
/// serve protocol reports them per `done` response so tests (and
/// operators) can observe that a repeated request skipped rebuilds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub fleet_hits: u64,
    pub fleet_misses: u64,
    pub topo_hits: u64,
    pub topo_misses: u64,
    pub prescreen_hits: u64,
    pub prescreen_misses: u64,
}

struct FleetEntry {
    fleet: Vec<Server>,
    rng_after: [u64; 4],
    /// Logical timestamp of the last hit (or the insert), from
    /// [`WarmCache::clock`]. Strictly increasing, hence unique — the LRU
    /// victim (minimum stamp) is well-defined regardless of map order.
    last_used: u64,
}

/// The warm store behind one daemon: fleets, topologies, and prescreen
/// answers, plus the traffic counters. One instance is shared (via
/// [`WarmHandle`]) across every request and worker thread.
#[derive(Default)]
pub struct WarmCache {
    // lint:allow(hash-container) keyed lookup only; eviction selects the
    // unique min last_used stamp, independent of iteration order.
    fleets: HashMap<(u64, [u64; 4]), FleetEntry>,
    // lint:allow(hash-container) keyed lookup only, never iterated.
    topos: HashMap<u64, Topology>,
    // lint:allow(hash-container) keyed lookup only, never iterated.
    prescreen: HashMap<u64, crate::analytical::AnalyticOutputs>,
    stats: CacheStats,
    /// Max fleet entries retained; at the cap the least-recently-used
    /// entry is evicted (entries are per-(config, stream-position), so an
    /// unbounded sweep would otherwise hold one fleet clone per
    /// replication, while the sweep's *base* config stays hot). The
    /// topology/prescreen maps are per-config and tiny.
    fleet_cap: usize,
    /// Logical LRU clock: bumped on every fleet hit and insert.
    clock: u64,
}

impl WarmCache {
    pub fn new(fleet_cap: usize) -> WarmCache {
        WarmCache { fleet_cap: fleet_cap.max(1), ..WarmCache::default() }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// A cheaply-cloneable handle on a shared [`WarmCache`]. The model layer
/// consults it through `Option<&WarmHandle>` parameters: `None`
/// everywhere on the CLI path, so cold runs never touch a lock.
#[derive(Clone)]
pub struct WarmHandle {
    cache: Arc<Mutex<WarmCache>>,
}

impl WarmHandle {
    pub fn new(fleet_cap: usize) -> WarmHandle {
        WarmHandle { cache: Arc::new(Mutex::new(WarmCache::new(fleet_cap))) }
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.lock().expect("warm cache lock").stats()
    }

    /// Fleet build through the cache: byte-identical to a cold
    /// [`build_fleet_into`] call. On a hit the cached fleet is copied
    /// into `fleet` (reusing its allocations) and `rng` jumps to the
    /// position the cold build would have left it at; on a miss the cold
    /// build runs and its result is remembered.
    pub fn fetch_fleet(
        &self,
        p: &Params,
        rng: &mut Rng,
        fleet: &mut Vec<Server>,
        scratch: &mut Vec<u32>,
    ) {
        let key = (fingerprint(p), rng.state());
        let mut cache = self.cache.lock().expect("warm cache lock");
        cache.clock += 1;
        let now = cache.clock;
        if let Some(e) = cache.fleets.get_mut(&key) {
            e.last_used = now;
            fleet.clone_from(&e.fleet);
            rng.set_state(e.rng_after);
            cache.stats.fleet_hits += 1;
            return;
        }
        cache.stats.fleet_misses += 1;
        drop(cache); // build outside the lock: misses run concurrently
        build_fleet_into(p, rng, fleet, scratch);
        let mut cache = self.cache.lock().expect("warm cache lock");
        while cache.fleets.len() >= cache.fleet_cap {
            // Evict the least-recently-used entry. Stamps are unique
            // (strictly increasing clock), so the minimum is the same
            // whatever order the map yields entries in.
            let oldest = cache
                .fleets
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    cache.fleets.remove(&k);
                }
                None => break,
            }
        }
        cache.clock += 1;
        let now = cache.clock;
        cache.fleets.insert(
            key,
            FleetEntry { fleet: fleet.clone(), rng_after: rng.state(), last_used: now },
        );
    }

    /// Topology build through the cache ([`Topology::build`] is RNG-free
    /// and deterministic, so the fingerprint alone keys it).
    pub fn fetch_topology(&self, p: &Params) -> Option<Topology> {
        let spec = p.topology.as_ref()?;
        let key = fingerprint(p);
        let mut cache = self.cache.lock().expect("warm cache lock");
        if let Some(t) = cache.topos.get(&key) {
            cache.stats.topo_hits += 1;
            return Some(t.clone());
        }
        cache.stats.topo_misses += 1;
        drop(cache);
        let t = Topology::build(spec, p.total_servers());
        let mut cache = self.cache.lock().expect("warm cache lock");
        cache.topos.insert(key, t.clone());
        Some(t)
    }

    /// CTMC analysis through the cache (`analyze` is a pure function of
    /// the config). Feeds both `analytic`/`compare` runs and the
    /// prescreen fast-path router.
    pub fn fetch_analysis(&self, p: &Params) -> crate::analytical::AnalyticOutputs {
        let key = fingerprint(p);
        let mut cache = self.cache.lock().expect("warm cache lock");
        if let Some(&o) = cache.prescreen.get(&key) {
            cache.stats.prescreen_hits += 1;
            return o;
        }
        cache.stats.prescreen_misses += 1;
        drop(cache);
        let o = crate::analytical::analyze(p);
        let mut cache = self.cache.lock().expect("warm cache lock");
        cache.prescreen.insert(key, o);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_knob_sensitive() {
        let p = Params::small_test();
        assert_eq!(fingerprint(&p), fingerprint(&Params::small_test()));
        // Every registered numeric knob perturbs the hash.
        for &name in Params::sweepable_names() {
            let mut q = Params::small_test();
            let v = q.get_by_name(name).unwrap();
            q.set_by_name(name, v + 1.0);
            assert_ne!(fingerprint(&p), fingerprint(&q), "insensitive to {name}");
        }
        // Non-numeric config too.
        let mut q = Params::small_test();
        q.failure_dist = DistKind::Weibull { shape: 1.5 };
        assert_ne!(fingerprint(&p), fingerprint(&q));
        let mut r = Params::small_test();
        r.failure_dist = DistKind::Weibull { shape: 2.0 };
        assert_ne!(fingerprint(&q), fingerprint(&r), "insensitive to dist shape");
        let mut t = Params::small_test();
        t.topology = Some(crate::config::TopologySpec {
            levels: vec![crate::config::TopologyLevelSpec {
                name: "rack".into(),
                size: 8,
                outage_rate: 0.0,
            }],
        });
        assert_ne!(fingerprint(&p), fingerprint(&t));
    }

    #[test]
    fn fleet_cache_hit_is_byte_identical_to_cold_build() {
        let p = Params::small_test();
        let h = WarmHandle::new(64);

        // Cold reference.
        let mut cold_rng = Rng::new(7);
        let mut cold_fleet = Vec::new();
        let mut scratch = Vec::new();
        build_fleet_into(&p, &mut cold_rng, &mut cold_fleet, &mut scratch);

        // Miss, then hit, from the same stream position.
        let same = |fleet: &Vec<Server>, rng: &Rng| {
            assert_eq!(fleet.len(), cold_fleet.len());
            for (a, b) in fleet.iter().zip(&cold_fleet) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.is_bad, b.is_bad);
                assert_eq!(a.state, b.state);
            }
            assert_eq!(rng.state(), cold_rng.state(), "stream position restored");
        };
        for pass in 0..2 {
            let mut rng = Rng::new(7);
            let mut fleet = Vec::new();
            h.fetch_fleet(&p, &mut rng, &mut fleet, &mut scratch);
            same(&fleet, &rng);
            let s = h.stats();
            assert_eq!((s.fleet_misses, s.fleet_hits), (1, pass), "pass {pass}");
        }
        // A different stream position is a different cache line.
        let mut rng = Rng::new(8);
        let mut fleet = Vec::new();
        h.fetch_fleet(&p, &mut rng, &mut fleet, &mut scratch);
        assert_eq!(h.stats().fleet_misses, 2);
    }

    #[test]
    fn lru_keeps_recently_touched_entries() {
        let p = Params::small_test();
        let h = WarmHandle::new(2);
        let mut scratch = Vec::new();
        let mut run = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut fleet = Vec::new();
            h.fetch_fleet(&p, &mut rng, &mut fleet, &mut scratch);
        };
        run(1); // A: miss
        run(2); // B: miss              cache = {A, B}
        run(1); // A: hit (A now newer than B)
        run(3); // C: miss, evicts B    cache = {A, C}
        run(1); // A: hit — survived the eviction
        run(2); // B: miss — it was the LRU victim
        let s = h.stats();
        assert_eq!((s.fleet_misses, s.fleet_hits), (4, 2));
    }

    #[test]
    fn fleet_cap_bounds_the_store() {
        let p = Params::small_test();
        let h = WarmHandle::new(2);
        let mut scratch = Vec::new();
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let mut fleet = Vec::new();
            h.fetch_fleet(&p, &mut rng, &mut fleet, &mut scratch);
        }
        assert!(h.cache.lock().unwrap().fleets.len() <= 2);
    }

    #[test]
    fn topology_and_analysis_caches_count_traffic() {
        let mut p = Params::small_test();
        p.topology = Some(crate::config::TopologySpec {
            levels: vec![crate::config::TopologyLevelSpec {
                name: "rack".into(),
                size: 8,
                outage_rate: 0.0,
            }],
        });
        let h = WarmHandle::new(4);
        let a = h.fetch_topology(&p).expect("topology configured");
        let b = h.fetch_topology(&p).expect("topology configured");
        assert_eq!(a, b);
        let s = h.stats();
        assert_eq!((s.topo_misses, s.topo_hits), (1, 1));
        assert!(h.fetch_topology(&Params::small_test()).is_none());

        let x = h.fetch_analysis(&p);
        let y = h.fetch_analysis(&p);
        assert_eq!(x, y);
        assert_eq!(x, crate::analytical::analyze(&p));
        let s = h.stats();
        assert_eq!((s.prescreen_misses, s.prescreen_hits), (1, 1));
    }
}
