//! The one execution path: `ExecRequest → Prepared → RunResult → text`.
//!
//! Both entrypoints — `airesim scenario` (one cold request, exits) and
//! `airesim serve` (many concurrent requests over shared warm state) —
//! build an [`ExecRequest`] and walk the same three stages. The CLI path
//! runs with the default (all-`None`) [`ExecCtrl`], which makes every
//! serving hook a no-op, so its output is byte-identical to the
//! pre-refactor monolithic command.

use crate::config::{validate, yaml, Params};
use crate::model::PolicySpec;
use crate::report::{Format, ScenarioRecord, Sink};
use crate::scenario::{Scenario, ScenarioKind, ScenarioOutcome};
use crate::serve::{cache, router};
use crate::sweep::ctrl::{self, ExecCtrl};

/// Whether a request may be answered analytically ([`Route::Auto`], the
/// serve default for `route: auto`) or must run the DES ([`Route::Des`],
/// the CLI's behavior and the serve default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Des,
    Auto,
}

/// One unit of work, as submitted by the CLI or a serve request: the
/// scenario document plus the overrides both front ends accept.
#[derive(Clone, Debug)]
pub struct ExecRequest {
    /// The scenario YAML text (a file's contents or a request field).
    pub doc: String,
    pub format: Format,
    /// Override the document's `seed:`.
    pub seed: Option<u64>,
    /// Override the document's `threads:`.
    pub threads: Option<usize>,
    /// `--set`-style `name=value,...` parameter overrides.
    pub sets: Option<String>,
    /// `--policy`-style `axis=name,...` overrides.
    pub policies: Option<String>,
    /// Force the event timeline into the record (serve's `trace: true`;
    /// single/inject scenarios only).
    pub trace: bool,
    pub route: Route,
    /// Label prefixed onto document parse errors (the CLI passes the
    /// file path; serve passes nothing — errors read as the doc's own).
    pub origin: Option<String>,
}

/// A validated execution plan: the scenario to run, how to render it,
/// and the canonical fingerprint of its parameter set (the warm caches'
/// key, reported in serve `done` responses).
pub struct Prepared {
    pub scenario: Scenario,
    pub format: Format,
    pub fingerprint: u64,
    pub route: Route,
}

/// Apply `name=value[,name=value...]` clauses onto params (the CLI's
/// `--set`, serve's `"set"` field).
pub fn apply_set_clauses(p: &mut Params, clauses: &str) -> Result<(), String> {
    for clause in clauses.split(',') {
        let (name, value) = clause
            .split_once('=')
            .ok_or_else(|| format!("--set expects name=value, got `{clause}`"))?;
        let v = yaml::eval_expr(value).map_err(|e| format!("{name}: {e}"))?;
        if !p.set_by_name(name.trim(), v) {
            return Err(format!("unknown parameter `{name}` in --set"));
        }
    }
    Ok(())
}

/// Apply `axis=name[,axis=name...]` clauses onto a policy spec (the
/// CLI's `--policy`, serve's `"policy"` field).
pub fn apply_policy_clauses(spec: &mut PolicySpec, clauses: &str) -> Result<(), String> {
    for clause in clauses.split(',') {
        let (axis, name) = clause
            .split_once('=')
            .ok_or_else(|| format!("--policy expects axis=name, got `{clause}`"))?;
        spec.set(axis.trim(), name.trim())?;
    }
    Ok(())
}

/// Stage 1: parse the document, layer the request's overrides on top
/// (same order and same validation points as the historical CLI), and
/// fingerprint the resulting parameter set.
pub fn prepare(req: &ExecRequest) -> Result<Prepared, String> {
    let mut scenario = Scenario::from_yaml(&req.doc).map_err(|e| match &req.origin {
        Some(origin) => format!("{origin}: {e}"),
        None => e,
    })?;

    if let Some(sets) = &req.sets {
        apply_set_clauses(&mut scenario.params, sets)?;
        validate::validate(&scenario.params).map_err(|e| e.to_string())?;
    }
    if let Some(clauses) = &req.policies {
        apply_policy_clauses(&mut scenario.policies, clauses)?;
        // Sweep scenarios validate per point (`Sweep::validate`) and
        // studies per child, both with overrides applied; optimize
        // resolves every grid point the same way. Everything else runs
        // the base params verbatim and must build against them now.
        if !matches!(
            scenario.kind,
            ScenarioKind::Sweep(_) | ScenarioKind::Multi(_) | ScenarioKind::Optimize(_)
        ) {
            scenario.policies.build(&scenario.params)?;
        }
    }
    if let Some(seed) = req.seed {
        scenario.seed = seed;
    }
    if let Some(threads) = req.threads {
        scenario.threads = threads;
    }
    if req.trace {
        match &mut scenario.kind {
            ScenarioKind::Single { trace } | ScenarioKind::Inject { trace, .. } => {
                *trace = true;
            }
            _ => {
                return Err(
                    "`trace` applies to single/inject scenarios (event timelines)".into()
                )
            }
        }
    }

    let fingerprint = cache::fingerprint(&scenario.params);
    Ok(Prepared { scenario, format: req.format, fingerprint, route: req.route })
}

/// How a prepared request resolved.
pub enum RunResult {
    /// The DES (or analytic-vs-DES compare, study, …) ran to completion.
    Des(ScenarioOutcome),
    /// The prescreen router answered analytically; the DES never ran.
    Analytic(crate::analytical::AnalyticOutputs),
    /// The request's cancel flag was set before or during the run.
    Cancelled,
}

/// Stage 2: execute the plan under `ec`. The control travels ambiently
/// (see [`crate::sweep::ctrl`]): worker pools started anywhere below
/// `Scenario::run` pick up the gate, the cancel flag, and the warm
/// caches without any signature changes on the hot path.
pub fn run_prepared(prep: &Prepared, ec: &ExecCtrl) -> Result<RunResult, String> {
    if ec.is_cancelled() {
        return Ok(RunResult::Cancelled);
    }
    if prep.route == Route::Auto && router::routable(&prep.scenario) {
        let out = match &ec.warm {
            Some(h) => h.fetch_analysis(&prep.scenario.params),
            None => crate::analytical::analyze(&prep.scenario.params),
        };
        return Ok(RunResult::Analytic(out));
    }
    let outcome = ctrl::with(ec.clone(), || prep.scenario.run())?;
    if ec.is_cancelled() {
        return Ok(RunResult::Cancelled);
    }
    Ok(RunResult::Des(outcome))
}

/// Stage 3 for buffered callers: the complete output text. (The daemon
/// streams instead, via [`Sink::scenario_stream`] — concatenation of its
/// chunks equals this string.)
pub fn render(prep: &Prepared, result: RunResult) -> String {
    match result {
        RunResult::Des(outcome) => render_outcome(prep.format, &prep.scenario, outcome),
        RunResult::Analytic(out) => router::render(prep.format, &out),
        RunResult::Cancelled => String::new(),
    }
}

/// Render a DES outcome exactly as the CLI prints it.
pub fn render_outcome(
    format: Format,
    scenario: &Scenario,
    outcome: ScenarioOutcome,
) -> String {
    format.sink().scenario(&scenario.record_owned(outcome))
}

/// The record for a DES outcome (the daemon renders it through the
/// streaming sink API).
pub fn record(scenario: &Scenario, outcome: ScenarioOutcome) -> ScenarioRecord {
    scenario.record_owned(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::cache::WarmHandle;
    use crate::sweep::ctrl::Gate;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    const DOC: &str = "scenario: single\nseed: 7\nparams:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";

    fn req(doc: &str, format: Format) -> ExecRequest {
        ExecRequest {
            doc: doc.to_string(),
            format,
            seed: None,
            threads: None,
            sets: None,
            policies: None,
            trace: false,
            route: Route::Des,
            origin: None,
        }
    }

    /// The CLI's historical path, inlined: parse → run → buffered sink.
    fn cli_reference(doc: &str, format: Format) -> String {
        let sc = Scenario::from_yaml(doc).unwrap();
        let outcome = sc.run().unwrap();
        format.sink().scenario(&sc.record_owned(outcome))
    }

    #[test]
    fn pipeline_matches_the_cli_path_in_every_format() {
        for format in [Format::Text, Format::Json, Format::Csv, Format::Ndjson] {
            let prep = prepare(&req(DOC, format)).unwrap();
            let result = run_prepared(&prep, &ExecCtrl::default()).unwrap();
            assert_eq!(
                render(&prep, result),
                cli_reference(DOC, format),
                "format {}",
                format.name()
            );
        }
    }

    #[test]
    fn overrides_apply_in_cli_order() {
        let mut r = req(DOC, Format::Text);
        r.seed = Some(99);
        r.sets = Some("recovery_time=5".into());
        r.policies = Some("selection=locality".into());
        let prep = prepare(&r).unwrap();
        assert_eq!(prep.scenario.seed, 99);
        assert_eq!(prep.scenario.params.recovery_time, 5.0);
        assert_eq!(prep.scenario.policies.selection, "locality");
        // The fingerprint sees the overridden params, not the document's.
        let base = prepare(&req(DOC, Format::Text)).unwrap();
        assert_ne!(prep.fingerprint, base.fingerprint);
    }

    #[test]
    fn origin_prefixes_parse_errors_only() {
        let mut r = req("scenario: frobnicate\n", Format::Text);
        r.origin = Some("demo.yaml".into());
        let e = prepare(&r).unwrap_err();
        assert!(e.starts_with("demo.yaml: "), "{e}");
        // Override errors are not path-prefixed (CLI parity).
        let mut r = req(DOC, Format::Text);
        r.origin = Some("demo.yaml".into());
        r.sets = Some("bogus=1".into());
        let e = prepare(&r).unwrap_err();
        assert!(e.contains("unknown parameter `bogus`") && !e.contains("demo.yaml"), "{e}");
    }

    #[test]
    fn warm_rerun_is_byte_identical_and_hits_the_fleet_cache() {
        let warm = WarmHandle::new(64);
        let ec = ExecCtrl { warm: Some(warm.clone()), ..ExecCtrl::default() };
        let run = || {
            let prep = prepare(&req(DOC, Format::Text)).unwrap();
            let result = run_prepared(&prep, &ec).unwrap();
            render(&prep, result)
        };
        let cold = run();
        let misses = warm.stats().fleet_misses;
        let hot = run();
        assert_eq!(cold, hot, "cache hits must not perturb the stream");
        let s = warm.stats();
        assert_eq!(s.fleet_misses, misses, "second run rebuilds nothing");
        assert!(s.fleet_hits > 0, "second run must hit the fleet cache");
    }

    #[test]
    fn cancelled_before_start_runs_nothing_and_holds_no_slots() {
        let gate = Gate::new(2);
        let ec = ExecCtrl {
            gate: Some(Arc::clone(&gate)),
            cancel: Some(Arc::new(AtomicBool::new(true))),
            ..ExecCtrl::default()
        };
        let prep = prepare(&req(DOC, Format::Text)).unwrap();
        assert!(matches!(run_prepared(&prep, &ec).unwrap(), RunResult::Cancelled));
        assert_eq!(gate.available(), 2, "cancellation must leave every slot free");
    }

    #[test]
    fn auto_route_answers_analytically_des_route_does_not() {
        let mut r = req(DOC, Format::Text);
        r.route = Route::Auto;
        let prep = prepare(&r).unwrap();
        assert!(matches!(
            run_prepared(&prep, &ExecCtrl::default()).unwrap(),
            RunResult::Analytic(_)
        ));
        let prep = prepare(&req(DOC, Format::Text)).unwrap();
        assert!(matches!(
            run_prepared(&prep, &ExecCtrl::default()).unwrap(),
            RunResult::Des(_)
        ));
    }
}
