//! `airesim serve`: an NDJSON request daemon over the shared pipeline.
//!
//! One JSON object per stdin line, one JSON object per stdout line:
//!
//! ```text
//! → {"id":"a","scenario":"scenario: single\n…","format":"text","seed":7}
//! ← {"id":"a","accepted":true}
//! ← {"id":"a","chunk":"== scenario: single [single] ==\n"}
//! ← …                         (chunk concatenation == the CLI's stdout)
//! ← {"id":"a","done":true,"routed":false,"cancelled":false,
//!    "fingerprint":"…","cache":{"fleet_hits":0,…}}
//! → {"cancel":"a"}            (control message: flip a's cancel flag)
//! ← {"id":"a","cancelling":true}
//! ```
//!
//! Request fields: `id` (required; string or integer), `scenario`
//! (required; the YAML document as one JSON string), and optional
//! `format`, `seed`, `threads`, `set`, `policy`, `trace`, `route`
//! (`"des"` default / `"auto"` enables the prescreen router) — the same
//! overrides `airesim scenario` accepts as flags.
//!
//! Concurrency: every request runs on its own handler thread, but all
//! requests share ONE worker-slot [`Gate`] sized to `--threads` and one
//! [`WarmHandle`] — N concurrent requests multiplex fairly over the
//! machine instead of each spawning a full-width pool, and repeated
//! configs skip fleet/topology/prescreen rebuilds. A malformed line or a
//! failed run answers with an `error` object; the loop never dies.
//! Responses from concurrent requests interleave by line — readers
//! demultiplex on `id`.

use crate::report::json::Json;
use crate::report::Format;
use crate::serve::cache::{CacheStats, WarmHandle};
use crate::serve::pipeline::{self, ExecRequest, Route, RunResult};
use crate::serve::router;
use crate::sweep::ctrl::{ExecCtrl, Gate};
use crate::testkit::parse_json;
// lint:allow(hash-container) cancel flags are looked up by request id only;
// the map is never iterated, so order cannot leak into any output.
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Daemon configuration (the `airesim serve` flags).
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Shared worker slots across ALL concurrent requests (0 = auto).
    pub threads: usize,
    /// Warm fleet-cache capacity, in entries.
    pub fleet_cache: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { threads: 0, fleet_cache: 256 }
    }
}

/// Resolve a `--threads` value the way the worker pools do.
pub(crate) fn resolve_threads(threads: usize) -> usize {
    match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// One parsed stdin line.
enum Msg {
    Run { id: String, req: ExecRequest },
    Cancel(String),
}

fn jget<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

fn jstr(j: &Json) -> Option<&str> {
    match j {
        Json::Str(s) => Some(s.as_str()),
        _ => None,
    }
}

/// Request ids may arrive as strings or integers; both address the same
/// id space (`7` and `"7"` are one request).
fn jid(j: &Json) -> Option<String> {
    match j {
        Json::Str(s) => Some(s.clone()),
        Json::Num(n) if *n == n.trunc() && n.abs() < 9e15 => Some(format!("{}", *n as i64)),
        _ => None,
    }
}

/// Decode one run request (everything but `id`/`cancel`). Shared with
/// the HTTP adapter, whose POST body is this same object minus `id`.
pub(crate) fn exec_request_from_json(j: &Json) -> Result<ExecRequest, String> {
    let doc = jget(j, "scenario")
        .and_then(jstr)
        .ok_or("request needs `scenario` (the YAML document as a JSON string)")?
        .to_string();
    let format = match jget(j, "format").and_then(jstr) {
        Some(s) => Format::parse(s)?,
        None => Format::Text,
    };
    let route = match jget(j, "route").and_then(jstr) {
        None | Some("des") => Route::Des,
        Some("auto") => Route::Auto,
        Some(other) => return Err(format!("unknown route `{other}` (expected des or auto)")),
    };
    let num = |key: &str| -> Result<Option<f64>, String> {
        match jget(j, key) {
            None => Ok(None),
            Some(Json::Num(n)) => Ok(Some(*n)),
            Some(_) => Err(format!("`{key}` must be a number")),
        }
    };
    let trace = match jget(j, "trace") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err("`trace` must be a boolean".into()),
    };
    Ok(ExecRequest {
        doc,
        format,
        seed: num("seed")?.map(|v| v as u64),
        threads: num("threads")?.map(|v| v as usize),
        sets: jget(j, "set").and_then(jstr).map(str::to_string),
        policies: jget(j, "policy").and_then(jstr).map(str::to_string),
        trace,
        route,
        origin: None,
    })
}

fn parse_line(line: &str) -> Result<Msg, String> {
    let j = parse_json(line).map_err(|e| format!("bad request JSON: {e}"))?;
    if let Some(target) = jget(&j, "cancel") {
        let id = jid(target).ok_or("`cancel` must name a request id")?;
        return Ok(Msg::Cancel(id));
    }
    let id = jget(&j, "id")
        .and_then(jid)
        .ok_or("request needs an `id` (string or integer)")?;
    let req = exec_request_from_json(&j).map_err(|e| format!("request `{id}`: {e}"))?;
    Ok(Msg::Run { id, req })
}

/// Write one response line (all responses from all handler threads
/// funnel through this lock, so lines never interleave mid-object).
fn emit<W: Write>(out: &Mutex<W>, line: &Json) -> std::io::Result<()> {
    let mut w = out.lock().expect("response writer lock");
    writeln!(w, "{}", line.render())?;
    w.flush()
}

fn error_line(id: Option<&str>, msg: &str) -> Json {
    let id_field = match id {
        Some(id) => Json::str(id),
        None => Json::Null,
    };
    Json::obj([("id", id_field), ("error", Json::str(msg))])
}

fn done_line(
    id: &str,
    cancelled: bool,
    routed: bool,
    fingerprint: u64,
    before: CacheStats,
    after: CacheStats,
) -> Json {
    // Deltas over the shared cache while this request ran; with
    // concurrent requests in flight they are attributions, not exact
    // per-request counts (the counters themselves are daemon-global).
    let cache = Json::obj([
        ("fleet_hits", (after.fleet_hits - before.fleet_hits).into()),
        ("fleet_misses", (after.fleet_misses - before.fleet_misses).into()),
        ("topo_hits", (after.topo_hits - before.topo_hits).into()),
        ("topo_misses", (after.topo_misses - before.topo_misses).into()),
        ("prescreen_hits", (after.prescreen_hits - before.prescreen_hits).into()),
        ("prescreen_misses", (after.prescreen_misses - before.prescreen_misses).into()),
    ]);
    Json::obj([
        ("id", Json::str(id)),
        ("done", true.into()),
        ("routed", routed.into()),
        ("cancelled", cancelled.into()),
        ("fingerprint", Json::str(&format!("{fingerprint:016x}"))),
        ("cache", cache),
    ])
}

/// Run one accepted request to completion and stream its responses.
fn handle<W: Write + Send>(
    id: String,
    req: ExecRequest,
    ec: ExecCtrl,
    out: &Mutex<W>,
    warm: &WarmHandle,
    // lint:allow(hash-container) keyed lookup by request id only.
    cancels: &Mutex<HashMap<String, Arc<AtomicBool>>>,
) {
    let before = warm.stats();
    let run = pipeline::prepare(&req)
        .and_then(|prep| pipeline::run_prepared(&prep, &ec).map(|r| (prep, r)));
    // Writer errors (consumer hung up) end this response quietly; the
    // accept loop keeps serving whoever is still listening.
    let _ = match run {
        Err(e) => emit(out, &error_line(Some(&id), &e)),
        Ok((prep, result)) => {
            let cancelled = matches!(result, RunResult::Cancelled);
            let routed = matches!(result, RunResult::Analytic(_));
            let mut io = Ok(());
            {
                let mut sink_chunk = |chunk: &str| {
                    if io.is_ok() {
                        io = emit(
                            out,
                            &Json::obj([("id", Json::str(&id)), ("chunk", Json::str(chunk))]),
                        );
                    }
                };
                match result {
                    RunResult::Cancelled => {}
                    RunResult::Analytic(o) => {
                        for chunk in router::render(prep.format, &o).split_inclusive('\n') {
                            sink_chunk(chunk);
                        }
                    }
                    RunResult::Des(outcome) => {
                        let record = pipeline::record(&prep.scenario, outcome);
                        prep.format.sink().scenario_stream(&record, &mut sink_chunk);
                    }
                }
            }
            io.and_then(|_| {
                let after = warm.stats();
                emit(out, &done_line(&id, cancelled, routed, prep.fingerprint, before, after))
            })
        }
    };
    cancels.lock().expect("cancel registry lock").remove(&id);
}

/// The accept loop: read NDJSON requests from `reader` until EOF,
/// streaming responses to `writer`. Generic over the streams so tests
/// drive it with in-memory buffers; `airesim serve` passes stdin/stdout.
pub fn serve_loop<R, W>(reader: R, writer: W, opts: &ServeOpts) -> std::io::Result<()>
where
    R: BufRead,
    W: Write + Send,
{
    let warm = WarmHandle::new(opts.fleet_cache);
    let gate = Gate::new(resolve_threads(opts.threads));
    let out = Mutex::new(writer);
    // lint:allow(hash-container) keyed lookup by request id only.
    let cancels: Mutex<HashMap<String, Arc<AtomicBool>>> = Mutex::new(HashMap::new());
    let (out, cancels, warm_ref, gate_ref) = (&out, &cancels, &warm, &gate);

    std::thread::scope(|s| -> std::io::Result<()> {
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(line.trim()) {
                Err(e) => emit(out, &error_line(None, &e))?,
                Ok(Msg::Cancel(id)) => {
                    let known = match cancels.lock().expect("cancel registry lock").get(&id) {
                        Some(flag) => {
                            flag.store(true, Ordering::Relaxed);
                            true
                        }
                        None => false,
                    };
                    if known {
                        emit(
                            out,
                            &Json::obj([("id", Json::str(&id)), ("cancelling", true.into())]),
                        )?;
                    } else {
                        emit(
                            out,
                            &error_line(Some(&id), "no active request with this id"),
                        )?;
                    }
                }
                Ok(Msg::Run { id, req }) => {
                    let cancel = Arc::new(AtomicBool::new(false));
                    cancels
                        .lock()
                        .expect("cancel registry lock")
                        .insert(id.clone(), Arc::clone(&cancel));
                    emit(out, &Json::obj([("id", Json::str(&id)), ("accepted", true.into())]))?;
                    let ec = ExecCtrl {
                        gate: Some(Arc::clone(gate_ref)),
                        cancel: Some(cancel),
                        warm: Some(warm_ref.clone()),
                    };
                    s.spawn(move || handle(id, req, ec, out, warm_ref, cancels));
                }
            }
        }
        Ok(())
        // The scope joins every in-flight handler before returning, so
        // EOF on stdin still flushes every response.
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_accept_strings_and_integers() {
        assert_eq!(jid(&Json::str("a7")), Some("a7".into()));
        assert_eq!(jid(&Json::Num(7.0)), Some("7".into()));
        assert_eq!(jid(&Json::Num(7.5)), None);
        assert_eq!(jid(&Json::Null), None);
    }

    #[test]
    fn parse_line_classifies_messages() {
        assert!(matches!(parse_line(r#"{"cancel":"a"}"#), Ok(Msg::Cancel(id)) if id == "a"));
        let run = parse_line(r#"{"id":1,"scenario":"scenario: single\n","route":"auto"}"#);
        match run {
            Ok(Msg::Run { id, req }) => {
                assert_eq!(id, "1");
                assert_eq!(req.route, Route::Auto);
                assert_eq!(req.format, Format::Text);
            }
            _ => panic!("expected a run message"),
        }
        assert!(parse_line("not json").is_err());
        assert!(parse_line(r#"{"scenario":"x"}"#).unwrap_err().contains("id"));
        assert!(parse_line(r#"{"id":"a"}"#).unwrap_err().contains("scenario"));
        assert!(parse_line(r#"{"id":"a","scenario":"x","route":"maybe"}"#).is_err());
    }
}
