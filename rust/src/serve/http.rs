//! Minimal HTTP adapter over the serve pipeline (feature `http`).
//!
//! `airesim serve --http 127.0.0.1:8321` accepts `POST /` with a JSON
//! body in the daemon's request schema minus `id` (one connection is one
//! request, so ids are redundant) and answers `200` with the rendered
//! output — the same bytes the stdin/stdout daemon would stream as
//! `chunk` payloads. Hand-rolled HTTP/1.0 over `std::net::TcpListener`:
//! the core build stays zero-dependency, and the default build (feature
//! off) exposes no network surface at all.

use crate::report::json::Json;
use crate::serve::cache::WarmHandle;
use crate::serve::daemon::{self, ServeOpts};
use crate::serve::pipeline::{self, RunResult};
use crate::sweep::ctrl::{ExecCtrl, Gate};
use crate::testkit::parse_json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Bind `addr` and serve until the process is killed. Connections are
/// handled on scoped threads sharing one warm cache and one worker-slot
/// gate with each other (exactly the stdin daemon's fairness model).
pub fn serve(addr: &str, opts: &ServeOpts) -> crate::util::err::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("airesim serve: listening on http://{addr}/ (POST a request object)");
    let warm = WarmHandle::new(opts.fleet_cache);
    let gate = Gate::new(daemon::resolve_threads(opts.threads));
    std::thread::scope(|s| {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let warm = warm.clone();
            let gate = Arc::clone(&gate);
            s.spawn(move || {
                let _ = handle(stream, &warm, &gate);
            });
        }
    });
    Ok(())
}

fn handle(mut stream: TcpStream, warm: &WarmHandle, gate: &Arc<Gate>) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            break;
        }
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    if !request_line.starts_with("POST ") {
        return respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "POST a JSON request object (the serve schema minus `id`)\n",
        );
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    match run_body(&String::from_utf8_lossy(&body), warm, gate) {
        Ok(payload) => respond(&mut stream, 200, "OK", &payload),
        Err(e) => respond(
            &mut stream,
            400,
            "Bad Request",
            &(Json::obj([("error", Json::str(&e))]).render() + "\n"),
        ),
    }
}

fn run_body(body: &str, warm: &WarmHandle, gate: &Arc<Gate>) -> Result<String, String> {
    let j = parse_json(body.trim()).map_err(|e| format!("bad request JSON: {e}"))?;
    let req = daemon::exec_request_from_json(&j)?;
    let prep = pipeline::prepare(&req)?;
    let ec = ExecCtrl {
        gate: Some(Arc::clone(gate)),
        cancel: None, // cancellation = closing the connection, no flag
        warm: Some(warm.clone()),
    };
    let result = pipeline::run_prepared(&prep, &ec)?;
    debug_assert!(!matches!(result, RunResult::Cancelled), "no cancel flag installed");
    Ok(pipeline::render(&prep, result))
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.0 {code} {reason}\r\nContent-Type: application/x-ndjson\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}
