//! The Scenario layer: one declarative spec for every experiment shape
//! the simulator supports — single runs, parameter sweeps, what-if
//! scalings, scripted failure injections, and analytic-vs-DES
//! comparisons — with policies selected by name.
//!
//! A scenario file is the YAML subset [`crate::config::yaml`] parses:
//!
//! ```yaml
//! scenario: sweep            # single | sweep | whatif | inject | compare | multi | optimize
//! title: recovery-time sensitivity
//! seed: 42
//! replications: 30
//! crn: true                  # sweeps & studies: common random numbers
//! params:
//!   job_size: 64
//!   working_pool: 72
//! policies:
//!   selection: locality      # first_fit | random | locality
//!   repair: job_first        # fifo | lifo | job_first | sla_aged | shortest_first
//! sweep:
//!   kind: one_way
//!   x: { name: recovery_time, values: [10, 20, 30] }
//! whatif: { param: recovery_time, factor: 2 }      # whatif only
//! inject:                                          # inject only
//!   failures: [ { at: 100, job: 0, victim: 3, kind: systematic } ]
//! children:                                        # multi (study) only
//!   - label: tuned
//!     params: { recovery_time: 10 }
//!     policies: { selection: locality }
//! ```
//!
//! `Scenario::run` executes the spec (sweeps — and every child of a
//! `multi:` study — through the shared [`crate::sweep::run_pool`] worker
//! queue over batched [`crate::model::ReplicationRunner`]s) and returns a
//! typed [`ScenarioOutcome`]; [`Scenario::record`] wraps the outcome in
//! the structured-report data model so any `--format` sink can render it
//! (`render` is the text-sink shorthand). Studies — labeled children as
//! overrides on the shared base config, with baseline deltas and CRN —
//! live in [`study`].

pub mod study;

use crate::analytical::{self, AnalyticOutputs};
use crate::config::{validate, yaml, Params};
use crate::model::cluster::{ReplicationRunner, Simulation};
use crate::model::events::FailureKind;
use crate::model::{PolicySpec, RunOutputs};
use crate::report::{
    CompareRecord, Format, OptimizeRecord, RecordBody, RunRecord, ScenarioRecord, Sink,
    StudyRecord, SweepRecord, WhatIfRecord,
};
use crate::sim::rng::Rng;
use crate::stats::{metrics, Summary};
use crate::sweep::{ctrl, policies_from_doc, run_sweep, sweep_from_doc, Sweep, SweepResult};
use crate::trace::inject::{Injection, InjectionPlan};
use crate::trace::Trace;
use study::Study;

/// What kind of experiment a scenario describes.
#[derive(Clone, Debug)]
pub enum ScenarioKind {
    /// One simulation run (optionally traced).
    Single { trace: bool },
    /// A one- or two-way parameter sweep with replications.
    Sweep(Sweep),
    /// Scale one parameter by a factor and compare against the baseline.
    WhatIf { param: String, factor: f64, replications: usize },
    /// A single run with scripted failure injections (incident replay).
    Inject { failures: Vec<Injection>, trace: bool },
    /// The analytical CTMC estimate vs the DES mean over replications.
    Compare { replications: usize },
    /// A `multi:` study: labeled children as overrides on the shared
    /// base config, all replications drained through one worker pool.
    Multi(Study),
    /// An `optimize:` block: knob-importance screening or a goodput
    /// auto-tuning search over a declared knob grid (see [`crate::optimize`]).
    Optimize(crate::optimize::Optimize),
}

/// A declarative experiment: parameters + named policies + kind.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub title: String,
    pub params: Params,
    pub policies: PolicySpec,
    pub seed: u64,
    pub threads: usize,
    pub kind: ScenarioKind,
}

/// The typed result of running a scenario.
#[derive(Clone)]
pub enum ScenarioOutcome {
    Single { outputs: RunOutputs, trace: Trace },
    Sweep(SweepResult),
    WhatIf { result: SweepResult, param: String, factor: f64 },
    Inject { outputs: RunOutputs, trace: Trace },
    Compare { analytic: AnalyticOutputs, des_makespan: Summary, replications: usize },
    /// A study's combined record (already the report data model — per-
    /// child collectors plus the derived comparison table).
    Study(StudyRecord),
    /// An optimization's combined record (ranked effects or the search
    /// trail plus the winning configuration).
    Optimize(OptimizeRecord),
}

impl Scenario {
    /// A single-run scenario at the given parameters (builder entry for
    /// programmatic use; YAML files go through [`Scenario::from_yaml`]).
    pub fn single(params: Params) -> Scenario {
        Scenario {
            title: "single run".into(),
            params,
            policies: PolicySpec::default(),
            seed: 42,
            threads: 0,
            kind: ScenarioKind::Single { trace: false },
        }
    }

    pub fn with_policies(mut self, policies: PolicySpec) -> Self {
        self.policies = policies;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_kind(mut self, kind: ScenarioKind) -> Self {
        self.kind = kind;
        self
    }

    /// Parse a scenario file (see the module docs for the format).
    pub fn from_yaml(text: &str) -> Result<Scenario, String> {
        let doc = yaml::parse(text).map_err(|e| e.to_string())?;
        Scenario::from_doc(&doc)
    }

    /// Build a scenario from a parsed config document.
    pub fn from_doc(doc: &yaml::Value) -> Result<Scenario, String> {
        let params = validate::params_from_config(doc).map_err(|e| e.to_string())?;
        let policies = policies_from_doc(doc)?;
        let seed = doc.get("seed").and_then(|v| v.as_f64()).map(|v| v as u64).unwrap_or(42);
        let reps = doc
            .get("replications")
            .and_then(|v| v.as_f64())
            .map(|v| v as usize)
            .unwrap_or(30);
        let threads = doc
            .get("threads")
            .and_then(|v| v.as_f64())
            .map(|v| v as usize)
            .unwrap_or(0);
        let trace = doc
            .get("trace")
            .and_then(|v| v.as_str())
            .map(|s| s == "true" || s == "1")
            .unwrap_or(false);

        // Back-compat inference for plain config files without a
        // `scenario:` key: a sweep section means sweep, else single run.
        let kind_name = match doc.get("scenario").and_then(|v| v.as_str()) {
            Some(k) => k,
            None if doc.get("sweep").is_some() => "sweep",
            None => "single",
        };
        let kind = match kind_name {
            "single" => ScenarioKind::Single { trace },
            "sweep" => ScenarioKind::Sweep(sweep_from_doc(doc, reps, seed)?),
            "whatif" => {
                let w = doc.get("whatif").ok_or("whatif scenario needs a `whatif:` map")?;
                let param = w
                    .get("param")
                    .and_then(|v| v.as_str())
                    .ok_or("whatif.param missing")?
                    .to_string();
                if params.get_by_name(&param).is_none() {
                    return Err(format!("whatif.param `{param}` is not a parameter"));
                }
                let factor = w
                    .get("factor")
                    .and_then(|v| v.as_f64())
                    .ok_or("whatif.factor missing")?;
                ScenarioKind::WhatIf { param, factor, replications: reps }
            }
            "inject" => {
                let section =
                    doc.get("inject").ok_or("inject scenario needs an `inject:` map")?;
                let list = section
                    .get("failures")
                    .and_then(|v| v.as_list())
                    .ok_or("inject.failures must be a list")?;
                let mut failures = Vec::with_capacity(list.len());
                for item in list {
                    failures.push(parse_injection(item)?);
                }
                ScenarioKind::Inject { failures, trace }
            }
            "compare" => ScenarioKind::Compare { replications: reps },
            "multi" => ScenarioKind::Multi(study::study_from_doc(
                doc, &params, &policies, reps,
            )?),
            "optimize" => ScenarioKind::Optimize(crate::optimize::optimize_from_doc(
                doc, &params, &policies, reps,
            )?),
            other => {
                return Err(format!(
                    "unknown scenario kind `{other}` (expected single, sweep, whatif, \
                     inject, compare, multi, or optimize)"
                ))
            }
        };

        // Non-sweep kinds run exactly these policies against exactly
        // these params: an incompatible combo (e.g. `gang` with Weibull
        // clocks) fails at parse time, not mid-run. Sweeps defer to
        // `Sweep::validate`, and studies to per-child resolution (already
        // done in `study_from_doc`) — in both, a point/child may supply
        // the very knob a policy needs (e.g. sweeping
        // `checkpoint_interval` under `checkpoint: periodic`), so the
        // bare base spec need not build. Optimize points resolve the
        // same way (each grid point validated with its overrides).
        if !matches!(
            kind,
            ScenarioKind::Sweep(_) | ScenarioKind::Multi(_) | ScenarioKind::Optimize(_)
        ) {
            policies.build(&params)?;
        }

        let title = doc
            .get("title")
            .and_then(|v| v.as_str())
            .unwrap_or(kind_name)
            .to_string();
        Ok(Scenario { title, params, policies, seed, threads, kind })
    }

    /// Execute the scenario.
    pub fn run(&self) -> Result<ScenarioOutcome, String> {
        match &self.kind {
            ScenarioKind::Single { trace } => {
                // Ambient control (see `sweep::ctrl`): the serve daemon
                // gates single runs through the shared slot budget and
                // reuses warm fleet/topology builds; the CLI's default
                // all-`None` ctrl makes both hooks no-ops.
                let ec = ctrl::current();
                let _permit = ec.gate.as_ref().map(|g| g.acquire());
                let mut sim = Simulation::from_spec_warm(
                    &self.params,
                    &self.policies,
                    Rng::new(self.seed),
                    ec.warm.as_ref(),
                )?;
                if *trace {
                    sim = sim.with_trace();
                }
                let (outputs, trace) = sim.run_traced();
                Ok(ScenarioOutcome::Single { outputs, trace })
            }
            ScenarioKind::Sweep(sweep) => {
                let mut sweep = sweep.clone().with_policies(self.policies.clone());
                // `--seed` overrides arrive after parse time; keep the
                // sweep's master seed in lockstep with the scenario's.
                sweep.master_seed = self.seed;
                // Policy axes may interact with the params (e.g. `gang`
                // needs exponential clocks): fail here, not in a worker.
                sweep.validate(&self.params)?;
                Ok(ScenarioOutcome::Sweep(run_sweep(&self.params, &sweep, self.threads)))
            }
            ScenarioKind::WhatIf { param, factor, replications } => {
                let current = self
                    .params
                    .get_by_name(param)
                    .ok_or_else(|| format!("unknown parameter `{param}`"))?;
                let sweep = Sweep::one_way(
                    &format!("what-if: {param} x{factor}"),
                    param,
                    &[current, current * factor],
                    *replications,
                    self.seed,
                )
                .with_policies(self.policies.clone());
                let result = run_sweep(&self.params, &sweep, self.threads);
                Ok(ScenarioOutcome::WhatIf {
                    result,
                    param: param.clone(),
                    factor: *factor,
                })
            }
            ScenarioKind::Inject { failures, trace } => {
                let ec = ctrl::current();
                let _permit = ec.gate.as_ref().map(|g| g.acquire());
                let mut sim = Simulation::from_spec_warm(
                    &self.params,
                    &self.policies,
                    Rng::new(self.seed),
                    ec.warm.as_ref(),
                )?
                .with_injections(InjectionPlan::new(failures.clone()));
                if *trace {
                    sim = sim.with_trace();
                }
                let (outputs, trace) = sim.run_traced();
                Ok(ScenarioOutcome::Inject { outputs, trace })
            }
            ScenarioKind::Compare { replications } => {
                let ec = ctrl::current();
                // The CTMC side goes through the prescreen cache when a
                // warm handle is ambient (repeat compares answer from the
                // same analysis the router serves).
                let analytic = match ec.warm.as_ref() {
                    Some(h) => h.fetch_analysis(&self.params),
                    None => analytical::analyze(&self.params),
                };
                let mut runner = ReplicationRunner::new();
                runner.warm = ec.warm.clone();
                runner.cancel = ec.cancel.clone();
                let makespans: Vec<f64> = (0..*replications)
                    .map(|r| {
                        let _permit = ec.gate.as_ref().map(|g| g.acquire());
                        runner
                            .run(
                                &self.params,
                                &self.policies,
                                Rng::derived(self.seed, &[r as u64]),
                            )
                            .makespan
                    })
                    .collect();
                let des_makespan = Summary::from_values(&makespans)
                    .ok_or("compare needs at least one replication")?;
                Ok(ScenarioOutcome::Compare {
                    analytic,
                    des_makespan,
                    replications: *replications,
                })
            }
            ScenarioKind::Multi(study) => Ok(ScenarioOutcome::Study(study::run_study(
                &self.params,
                &self.policies,
                study,
                self.seed,
                self.threads,
            )?)),
            ScenarioKind::Optimize(opt) => {
                Ok(ScenarioOutcome::Optimize(crate::optimize::run_optimize(
                    &self.params,
                    &self.policies,
                    opt,
                    self.seed,
                    self.threads,
                )?))
            }
        }
    }

    /// Wrap an owned outcome in the structured-report data model (no
    /// copies — a long trace moves straight into the record): any
    /// [`Sink`] renders the returned record (`--format`).
    pub fn record_owned(&self, outcome: ScenarioOutcome) -> ScenarioRecord {
        let body = match outcome {
            ScenarioOutcome::Single { outputs, trace }
            | ScenarioOutcome::Inject { outputs, trace } => RecordBody::Run(RunRecord {
                seed: self.seed,
                params: self.params.clone(),
                policies: self.policies.clone(),
                outputs,
                trace,
            }),
            ScenarioOutcome::Sweep(result) => {
                RecordBody::Sweep(SweepRecord::new(result, metrics::DEFAULT_METRIC))
            }
            ScenarioOutcome::WhatIf { result, param, factor } => {
                RecordBody::WhatIf(WhatIfRecord {
                    result,
                    param,
                    factor,
                    metric: metrics::DEFAULT_METRIC.to_string(),
                })
            }
            ScenarioOutcome::Compare { analytic, des_makespan, replications } => {
                RecordBody::Compare(CompareRecord { analytic, des_makespan, replications })
            }
            ScenarioOutcome::Study(record) => RecordBody::Study(record),
            ScenarioOutcome::Optimize(record) => RecordBody::Optimize(record),
        };
        ScenarioRecord {
            title: self.title.clone(),
            kind: kind_name(&self.kind),
            seed: self.seed,
            policies: self.policies.clone(),
            body,
        }
    }

    /// Borrowing convenience over [`Scenario::record_owned`] (clones the
    /// outcome; prefer `record_owned` when the outcome is no longer
    /// needed).
    pub fn record(&self, outcome: &ScenarioOutcome) -> ScenarioRecord {
        self.record_owned(outcome.clone())
    }

    /// Render an outcome as the CLI's text report (the text sink over
    /// [`Scenario::record`] — byte-identical to the pre-redesign report).
    pub fn render(&self, outcome: &ScenarioOutcome) -> String {
        Format::Text.sink().scenario(&self.record(outcome))
    }
}

fn kind_name(kind: &ScenarioKind) -> &'static str {
    match kind {
        ScenarioKind::Single { .. } => "single",
        ScenarioKind::Sweep(_) => "sweep",
        ScenarioKind::WhatIf { .. } => "whatif",
        ScenarioKind::Inject { .. } => "inject",
        ScenarioKind::Compare { .. } => "compare",
        ScenarioKind::Multi(_) => "multi",
        ScenarioKind::Optimize(_) => "optimize",
    }
}

/// Parse one `inject.failures` entry:
/// `{ at: 100, job: 0, victim: 3, kind: systematic }`.
fn parse_injection(item: &yaml::Value) -> Result<Injection, String> {
    let at = item
        .get("at")
        .and_then(|v| v.as_f64())
        .ok_or("injection needs `at:` (minutes)")?;
    let job = item.get("job").and_then(|v| v.as_f64()).map(|v| v as u32).unwrap_or(0);
    let victim = item
        .get("victim")
        .and_then(|v| v.as_f64())
        .map(|v| v as usize)
        .unwrap_or(0);
    let kind = match item.get("kind").and_then(|v| v.as_str()).unwrap_or("random") {
        "random" => FailureKind::Random,
        "systematic" => FailureKind::Systematic,
        other => return Err(format!("unknown failure kind `{other}`")),
    };
    Ok(Injection::for_job(job, at, victim, kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMALL: &str = "params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";

    #[test]
    fn single_scenario_runs() {
        let text = format!("scenario: single\nseed: 7\n{SMALL}");
        let sc = Scenario::from_yaml(&text).unwrap();
        match sc.run().unwrap() {
            ScenarioOutcome::Single { outputs, .. } => {
                assert!(outputs.completed);
                assert!(outputs.makespan >= 1440.0);
            }
            _ => panic!("expected Single outcome"),
        }
    }

    #[test]
    fn single_matches_direct_simulation() {
        let text = format!("scenario: single\nseed: 9\n{SMALL}");
        let sc = Scenario::from_yaml(&text).unwrap();
        let via_scenario = match sc.run().unwrap() {
            ScenarioOutcome::Single { outputs, .. } => outputs,
            _ => unreachable!(),
        };
        let direct = Simulation::new(&sc.params, 9).run();
        assert_eq!(via_scenario, direct, "scenario layer must not perturb runs");
    }

    #[test]
    fn sweep_scenario_runs_with_policies() {
        let text = format!(
            "scenario: sweep\nseed: 3\nreplications: 2\n{SMALL}\
             policies:\n  selection: random\n\
             sweep:\n  kind: one_way\n  x: {{ name: recovery_time, values: [10, 30] }}\n"
        );
        let sc = Scenario::from_yaml(&text).unwrap();
        assert_eq!(sc.policies.selection, "random");
        match sc.run().unwrap() {
            ScenarioOutcome::Sweep(result) => {
                assert_eq!(result.points.len(), 2);
                assert_eq!(result.points[0].summary("makespan").unwrap().n, 2);
            }
            _ => panic!("expected Sweep outcome"),
        }
    }

    #[test]
    fn sweep_scenario_honors_seed_override() {
        let text = format!(
            "scenario: sweep\nseed: 3\nreplications: 2\n{SMALL}\
             sweep:\n  kind: one_way\n  x: {{ name: recovery_time, values: [10] }}\n"
        );
        let mut sc = Scenario::from_yaml(&text).unwrap();
        let mean = |sc: &Scenario| match sc.run().unwrap() {
            ScenarioOutcome::Sweep(r) => r.points[0].summary("makespan").unwrap().mean,
            _ => unreachable!(),
        };
        let a = mean(&sc);
        sc.seed = 999; // post-parse override (the CLI's --seed path)
        let b = mean(&sc);
        assert_ne!(a, b, "seed override must reach the sweep's master seed");
    }

    #[test]
    fn whatif_scenario_compares_factor() {
        let text = format!(
            "scenario: whatif\nseed: 4\nreplications: 3\n{SMALL}\
             whatif: {{ param: recovery_time, factor: 4 }}\n"
        );
        let sc = Scenario::from_yaml(&text).unwrap();
        match sc.run().unwrap() {
            ScenarioOutcome::WhatIf { result, param, factor } => {
                assert_eq!(param, "recovery_time");
                assert_eq!(factor, 4.0);
                assert_eq!(result.points.len(), 2);
            }
            _ => panic!("expected WhatIf outcome"),
        }
    }

    #[test]
    fn inject_scenario_targets_any_job() {
        let text = "scenario: inject\nseed: 5\n\
                    params:\n  num_jobs: 2\n  job_size: 16\n  warm_standbys: 2\n  working_pool: 40\n  spare_pool: 4\n  job_len: 1440\n  random_failure_rate: 0\n  systematic_failure_rate: 0\n  systematic_fraction: 0\n\
                    inject:\n  failures: [ { at: 100, job: 1, victim: 0, kind: random }, { at: 200, job: 7, victim: 0, kind: random } ]\n";
        let sc = Scenario::from_yaml(text).unwrap();
        match sc.run().unwrap() {
            ScenarioOutcome::Inject { outputs, .. } => {
                // Job 7 does not exist: that injection drops cleanly; the
                // job-1 injection lands.
                assert!(outputs.completed);
                assert_eq!(outputs.failures_total, 1);
            }
            _ => panic!("expected Inject outcome"),
        }
    }

    #[test]
    fn compare_scenario_reports_both_layers() {
        let text = format!("scenario: compare\nseed: 6\nreplications: 3\n{SMALL}");
        let sc = Scenario::from_yaml(&text).unwrap();
        match sc.run().unwrap() {
            ScenarioOutcome::Compare { analytic, des_makespan, .. } => {
                assert!(analytic.makespan_est > 0.0);
                assert_eq!(des_makespan.n, 3);
                assert!(des_makespan.mean >= 1440.0);
            }
            _ => panic!("expected Compare outcome"),
        }
    }

    #[test]
    fn multi_scenario_runs_and_records() {
        let text = format!(
            "scenario: multi\nseed: 3\nreplications: 2\nbaseline: base\n{SMALL}\
             children:\n  - label: base\n  - label: fast\n    params: {{ recovery_time: 5 }}\n"
        );
        let sc = Scenario::from_yaml(&text).unwrap();
        match sc.run().unwrap() {
            ScenarioOutcome::Study(rec) => {
                assert_eq!(rec.children.len(), 2);
                assert_eq!(rec.baseline_label(), Some("base"));
                assert_eq!(rec.children[1].summary("makespan").unwrap().n, 2);
            }
            _ => panic!("expected Study outcome"),
        }
    }

    #[test]
    fn multi_children_may_supply_policy_knobs_the_base_lacks() {
        // The base params carry no checkpoint interval/cost; a child that
        // selects `periodic` supplies the interval itself — like sweep
        // points, children are validated with their overrides applied.
        let text = format!(
            "scenario: multi\nseed: 3\nreplications: 1\n{SMALL}\
             children:\n  - label: p\n    params: {{ checkpoint_interval: 120 }}\n    policies: {{ checkpoint: periodic }}\n"
        );
        let sc = Scenario::from_yaml(&text).unwrap();
        assert!(matches!(sc.kind, ScenarioKind::Multi(_)));
        assert!(sc.run().is_ok());
    }

    #[test]
    fn bad_specs_are_rejected_at_parse_time() {
        assert!(Scenario::from_yaml("scenario: frobnicate\n").is_err());
        // gang + weibull is incompatible: caught before running.
        let text = "scenario: single\nparams:\n  failure_dist: weibull:1.5\n\
                    policies:\n  failure: gang\n";
        assert!(Scenario::from_yaml(text).is_err());
        // whatif against a non-parameter.
        let text = "scenario: whatif\nwhatif: { param: bogus, factor: 2 }\n";
        assert!(Scenario::from_yaml(text).is_err());
    }

    #[test]
    fn render_mentions_policies_and_outcome() {
        let text = format!("scenario: single\nseed: 7\n{SMALL}");
        let sc = Scenario::from_yaml(&text).unwrap();
        let outcome = sc.run().unwrap();
        let rendered = sc.render(&outcome);
        assert!(rendered.contains("selection=first_fit"));
        assert!(rendered.contains("makespan"));
    }
}
