//! `multi:` studies — composable scenario comparison on one shared
//! execution pool.
//!
//! A study is a list of labeled children, each expressed as param/policy
//! **overrides on the shared base config**, with an optional designated
//! baseline and optional common random numbers:
//!
//! ```yaml
//! scenario: multi
//! title: placement x checkpoint-policy study
//! seed: 42
//! replications: 30
//! crn: true                      # all children share master streams
//! baseline: locality_periodic    # delta columns compare against this child
//! params:                        # the shared base config
//!   job_size: 64
//!   checkpoint_cost: 10
//! policies:                      # shared base policies
//!   repair: job_first
//! children:
//!   - label: locality_periodic
//!     params: { checkpoint_interval: 120 }
//!     policies: { selection: locality, checkpoint: periodic }
//!   - label: anti_young
//!     policies: { selection: anti_affinity, checkpoint: young_daly }
//! ```
//!
//! ## Execution: one shared work queue
//!
//! [`run_study`] flattens **every child's replications** into the single
//! (unit, replication) work queue of [`crate::sweep::run_pool_ordered`] —
//! the same [`crate::model::ReplicationRunner`] worker pool sweeps use
//! (the replication-ordered variant, so paired-CRN delta CIs can match
//! replication `r` across children). A
//! 6-child study therefore saturates all cores instead of running its
//! children serially, and results are independent of the thread count.
//!
//! ## Seed discipline
//!
//! Replication `r` of a child labeled `L` draws from
//! `Rng::derived(seed, &[fnv1a(L), r])` — keyed by the **label**, not the
//! child's position, so a child's outputs are byte-identical whether it
//! runs alone or inside a larger study (reordering or deleting siblings
//! never perturbs it). With `crn: true` the label key is replaced by the
//! shared [`crate::sweep::CRN_STREAM`] sentinel: every child sees the
//! same draws at replication `r` (and the same draws a CRN *sweep* with
//! this master seed would see), the classic variance-reduction setup for
//! estimating child-to-child differences.

use crate::config::{validate, yaml, Params};
use crate::model::cluster::Simulation;
use crate::model::PolicySpec;
use crate::report::record::{StudyChildRecord, StudyRecord};
use crate::sim::rng::Rng;
use crate::stats::Collector;
use crate::sweep::{collect_outputs, parse_crn, run_pool_ordered, AxisValue, SweepPoint, CRN_STREAM};
use crate::trace::Trace;

/// One child of a study: a label plus overrides on the shared base.
#[derive(Clone, Debug)]
pub struct StudyChild {
    pub label: String,
    /// Numeric parameter names and `policies.<axis>` names — the sweep
    /// point override form ([`SweepPoint::apply_full`] resolves them).
    pub overrides: Vec<(String, AxisValue)>,
}

/// A parsed `multi:` study specification.
#[derive(Clone, Debug)]
pub struct Study {
    pub children: Vec<StudyChild>,
    /// Index of the designated `baseline:` child, if any.
    pub baseline: Option<usize>,
    pub replications: usize,
    /// Common random numbers across children.
    pub crn: bool,
    /// Show the delta-CI / significance columns in the text comparison
    /// table (`show_ci: true`); machine formats always carry them.
    pub show_ci: bool,
}

/// FNV-1a hash of a child label: the label's stream-path key.
fn label_key(label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Study {
    /// The RNG stream for replication `rep` of child `idx`.
    fn rng(&self, seed: u64, idx: usize, rep: usize) -> Rng {
        let key = if self.crn { CRN_STREAM } else { label_key(&self.children[idx].label) };
        Rng::derived(seed, &[key, rep as u64])
    }

    /// Resolve one child against the base config: overrides applied,
    /// params range-validated, and the policy spec proven to build —
    /// every error names the offending child.
    fn resolve(
        &self,
        idx: usize,
        base: &Params,
        policies: &PolicySpec,
    ) -> Result<(Params, PolicySpec), String> {
        let child = &self.children[idx];
        let err = |e: String| format!("study child `{}`: {e}", child.label);
        let point = SweepPoint { overrides: child.overrides.clone() };
        let (p, spec) = point.apply_full(base, policies).map_err(&err)?;
        validate::validate(&p).map_err(|e| err(e.to_string()))?;
        spec.build(&p).map_err(&err)?;
        Ok((p, spec))
    }

    /// Resolve every child (the study-wide pre-flight: run after CLI
    /// `--set`/`--policy` overrides land on the base, so no worker thread
    /// ever sees a build error).
    pub fn resolve_all(
        &self,
        base: &Params,
        policies: &PolicySpec,
    ) -> Result<Vec<(Params, PolicySpec)>, String> {
        (0..self.children.len()).map(|i| self.resolve(i, base, policies)).collect()
    }
}

/// Parse one child's override sections: `params:` (numeric) and
/// `policies:` (names), in that order so labels render params-first.
fn child_overrides(
    item: &yaml::Value,
    label: &str,
    base: &Params,
) -> Result<Vec<(String, AxisValue)>, String> {
    let mut overrides = Vec::new();
    if let Some(params) = item.get("params") {
        let map = params
            .as_map()
            .ok_or_else(|| format!("study child `{label}`: `params:` must be a map"))?;
        for (name, v) in map {
            // Reject unknown names here, where the offender can be named;
            // `apply_full` would catch them later but without the child.
            if base.get_by_name(name).is_none() {
                return Err(format!(
                    "study child `{label}`: unknown parameter `{name}` in overrides"
                ));
            }
            let val = v.as_f64().ok_or_else(|| {
                format!("study child `{label}`: `{name}` needs a numeric value")
            })?;
            overrides.push((name.clone(), AxisValue::Num(val)));
        }
    }
    if let Some(policies) = item.get("policies") {
        let map = policies
            .as_map()
            .ok_or_else(|| format!("study child `{label}`: `policies:` must be a map"))?;
        let mut probe = PolicySpec::default();
        for (axis, v) in map {
            let name = v.as_str().ok_or_else(|| {
                format!("study child `{label}`: policies.{axis} must be a name")
            })?;
            // Validate axis + name against the registry at parse time.
            probe
                .set(axis, name)
                .map_err(|e| format!("study child `{label}`: {e}"))?;
            overrides.push((format!("policies.{axis}"), AxisValue::Name(name.into())));
        }
    }
    Ok(overrides)
}

/// Build a [`Study`] from a parsed `scenario: multi` document. The
/// `children:` list, `baseline:`, and `crn:` keys are document-level;
/// every child is validated against the base config here, so a bad study
/// file is one clean build error naming the offending child.
pub fn study_from_doc(
    doc: &yaml::Value,
    base: &Params,
    policies: &PolicySpec,
    replications: usize,
) -> Result<Study, String> {
    let list = doc
        .get("children")
        .ok_or("multi scenario needs a `children:` list")?
        .as_list()
        .ok_or("`children:` must be a list")?;
    if list.is_empty() {
        return Err("multi scenario needs at least one child in `children:`".into());
    }
    let mut children = Vec::with_capacity(list.len());
    for item in list {
        let label = item
            .get("label")
            .and_then(|v| v.as_str())
            .ok_or("every study child needs a `label:`")?
            .to_string();
        // A misspelled `params:`/`policies:` key would otherwise be
        // silently ignored — the child would run the bare base config
        // under its label, a 0-delta "mitigation" nobody asked for.
        if let Some(map) = item.as_map() {
            for key in map.keys() {
                if !["label", "params", "policies"].contains(&key.as_str()) {
                    return Err(format!(
                        "study child `{label}`: unknown key `{key}` (expected \
                         label, params, policies)"
                    ));
                }
            }
        }
        if children.iter().any(|c: &StudyChild| c.label == label) {
            return Err(format!("duplicate study child label `{label}`"));
        }
        let overrides = child_overrides(item, &label, base)?;
        children.push(StudyChild { label, overrides });
    }
    let baseline = match doc.get("baseline").and_then(|v| v.as_str()) {
        Some(label) => Some(
            children.iter().position(|c| c.label == label).ok_or_else(|| {
                format!(
                    "baseline `{label}` is not a study child (children: {})",
                    children.iter().map(|c| c.label.as_str()).collect::<Vec<_>>().join(", ")
                )
            })?,
        ),
        None => None,
    };
    let crn = match doc.get("crn") {
        None => false,
        Some(v) => parse_crn(v)?,
    };
    // `show_ci:` shares `crn:`'s strict boolean parse: a misspelling must
    // not silently drop the significance columns someone asked for.
    let show_ci = match doc.get("show_ci") {
        None => false,
        Some(v) => parse_crn(v).map_err(|e| e.replace("crn", "show_ci"))?,
    };
    let study = Study { children, baseline, replications, crn, show_ci };
    // Every child must resolve against the base it was written for.
    study.resolve_all(base, policies)?;
    Ok(study)
}

/// Execute a study: every child's replications flattened into one shared
/// [`run_pool_ordered`] work queue, collected into a [`StudyRecord`]
/// (per-child records + the derived comparison table).
pub fn run_study(
    base: &Params,
    policies: &PolicySpec,
    study: &Study,
    seed: u64,
    threads: usize,
) -> Result<StudyRecord, String> {
    // Re-resolve against the *current* base: CLI --set/--policy overrides
    // land after parse time, and a worker must never see a build error.
    let resolved = study.resolve_all(base, policies)?;
    let reps = study.replications.max(1);
    // Replication-ordered execution: the paired-delta CIs in the
    // comparison table match CRN replication `r` of one child against
    // replication `r` of another, so collectors must be filled in rep
    // order, not worker completion order. (Summaries sort before
    // reducing, so every other output is unaffected.)
    let results = run_pool_ordered(study.children.len(), reps, threads, |runner, idx, rep| {
        let (p, spec) = &resolved[idx];
        let out = runner.run(p, spec, study.rng(seed, idx, rep));
        (p.clone(), out)
    });
    Ok(StudyRecord {
        replications: reps,
        crn: study.crn,
        baseline: study.baseline,
        show_ci: study.show_ci,
        children: study
            .children
            .iter()
            .zip(resolved.iter().zip(results))
            .map(|(child, ((_, spec), (p, outs)))| {
                let mut collector = Collector::new();
                for out in &outs {
                    collect_outputs(&mut collector, &p, out);
                }
                StudyChildRecord {
                    label: child.label.clone(),
                    overrides: child.overrides.clone(),
                    policies: spec.clone(),
                    collector,
                }
            })
            .collect(),
    })
}

/// Capture one event timeline per child (`--trace-out` on a
/// `replications: 1` study): replication 0 of every child re-run with
/// tracing on. Traces never perturb draws, so these runs see exactly the
/// streams the pooled report runs saw.
pub fn child_timelines(
    base: &Params,
    policies: &PolicySpec,
    study: &Study,
    seed: u64,
) -> Result<Vec<(String, Trace)>, String> {
    let resolved = study.resolve_all(base, policies)?;
    let mut out = Vec::with_capacity(study.children.len());
    for (idx, (p, spec)) in resolved.iter().enumerate() {
        let (_, trace) = Simulation::from_spec(p, spec, study.rng(seed, idx, 0))?
            .with_trace()
            .run_traced();
        out.push((study.children[idx].label.clone(), trace));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Params {
        Params::small_test()
    }

    fn parse(doc: &str) -> Result<Study, String> {
        study_from_doc(
            &yaml::parse(doc).unwrap(),
            &base(),
            &PolicySpec::default(),
            4,
        )
    }

    #[test]
    fn parses_children_baseline_and_crn() {
        let s = parse(
            "crn: true\nbaseline: b\nchildren:\n\
             - label: a\n  params: { recovery_time: 10 }\n\
             - label: b\n  policies: { selection: locality }\n",
        )
        .unwrap();
        assert_eq!(s.children.len(), 2);
        assert!(s.crn);
        assert_eq!(s.baseline, Some(1));
        assert_eq!(
            s.children[0].overrides,
            vec![("recovery_time".to_string(), AxisValue::Num(10.0))]
        );
        assert_eq!(
            s.children[1].overrides,
            vec![("policies.selection".to_string(), AxisValue::Name("locality".into()))]
        );
    }

    #[test]
    fn error_paths_name_the_offender() {
        // Empty child list.
        let err = parse("children: []\n").unwrap_err();
        assert!(err.contains("at least one child"), "{err}");
        // Missing children key entirely.
        let err = parse("seed: 1\n").unwrap_err();
        assert!(err.contains("children"), "{err}");
        // Duplicate labels.
        let err = parse("children:\n- label: x\n- label: x\n").unwrap_err();
        assert!(err.contains("duplicate") && err.contains('x'), "{err}");
        // Unknown baseline label.
        let err = parse("baseline: nope\nchildren:\n- label: x\n").unwrap_err();
        assert!(err.contains("nope") && err.contains('x'), "{err}");
        // Unknown parameter in a child override.
        let err =
            parse("children:\n- label: x\n  params: { bogus_knob: 3 }\n").unwrap_err();
        assert!(err.contains('x') && err.contains("bogus_knob"), "{err}");
        // Unknown policy name in a child override.
        let err =
            parse("children:\n- label: x\n  policies: { selection: bogus }\n").unwrap_err();
        assert!(err.contains('x') && err.contains("bogus"), "{err}");
        // A child whose resolved policies cannot build (anti_affinity
        // without a topology) is caught at parse time, naming the child.
        let err = parse("children:\n- label: x\n  policies: { selection: anti_affinity }\n")
            .unwrap_err();
        assert!(err.contains('x') && err.contains("topology"), "{err}");
        // A child whose resolved params fail range validation.
        let err =
            parse("children:\n- label: x\n  params: { auto_repair_prob: 1.5 }\n").unwrap_err();
        assert!(err.contains('x') && err.contains("auto_repair_prob"), "{err}");
        // Misspelled crn is an error, not independent streams.
        let err = parse("crn: ture\nchildren:\n- label: x\n").unwrap_err();
        assert!(err.contains("crn"), "{err}");
        // A misspelled override section must not silently run the base
        // config under the child's label.
        let err = parse("children:\n- label: x\n  polices: { selection: locality }\n")
            .unwrap_err();
        assert!(err.contains("`x`") && err.contains("polices"), "{err}");
    }

    #[test]
    fn label_keyed_streams_are_position_independent() {
        let draws = |mut rng: Rng| -> Vec<u64> { (0..4).map(|_| rng.next_u64()).collect() };
        let study = parse(
            "children:\n- label: a\n- label: b\n  params: { recovery_time: 40 }\n",
        )
        .unwrap();
        // Child `b`'s stream does not depend on its index.
        let solo = parse("children:\n- label: b\n  params: { recovery_time: 40 }\n").unwrap();
        assert_eq!(draws(study.rng(7, 1, 3)), draws(solo.rng(7, 0, 3)));
        // Distinct labels get distinct streams...
        assert_ne!(draws(study.rng(7, 0, 3)), draws(study.rng(7, 1, 3)));
        // ...unless CRN collapses them onto the shared sentinel stream.
        let mut crn = study.clone();
        crn.crn = true;
        assert_eq!(draws(crn.rng(7, 0, 3)), draws(crn.rng(7, 1, 3)));
    }

    #[test]
    fn run_study_collects_every_child() {
        let study = parse(
            "baseline: slow\nchildren:\n\
             - label: slow\n  params: { recovery_time: 60 }\n\
             - label: fast\n  params: { recovery_time: 5 }\n",
        )
        .unwrap();
        let rec = run_study(&base(), &PolicySpec::default(), &study, 42, 2).unwrap();
        assert_eq!(rec.children.len(), 2);
        assert_eq!(rec.baseline_label(), Some("slow"));
        for c in &rec.children {
            assert_eq!(c.summary("makespan").unwrap().n, 4);
        }
        // The comparison carries a delta for the non-baseline child only.
        let (m, entries) = &rec.comparison()[0];
        assert_eq!(m.name, "makespan");
        assert_eq!(entries.len(), 2);
        assert!(entries[0].delta.is_none(), "baseline row has no delta");
        assert!(entries[1].delta.is_some());
    }

    #[test]
    fn crn_children_with_equal_overrides_are_identical() {
        let study = parse(
            "crn: true\nchildren:\n- label: a\n- label: also_a\n",
        )
        .unwrap();
        let rec = run_study(&base(), &PolicySpec::default(), &study, 11, 0).unwrap();
        for m in crate::stats::metrics::REGISTRY {
            assert_eq!(
                rec.children[0].summary(m.name),
                rec.children[1].summary(m.name),
                "CRN twins diverged on {}",
                m.name
            );
        }
    }
}
