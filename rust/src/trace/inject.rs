//! Failure injection: force failures at exact times, independent of the
//! stochastic clocks. Used by integration tests to walk the Figure-1
//! flowchart branch-by-branch, and by the `whatif` CLI to replay observed
//! incident timelines.

use crate::model::events::FailureKind;
use crate::sim::Time;

/// A scripted failure: at time `at`, the active server with gang index
/// `victim_index` (position in the job's active list, mod its length)
/// fails with the given kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Injection {
    pub at: Time,
    pub victim_index: usize,
    pub kind: FailureKind,
}

/// An injection schedule, consumed in time order.
#[derive(Clone, Debug, Default)]
pub struct InjectionPlan {
    ordered: Vec<Injection>,
    next: usize,
}

impl InjectionPlan {
    pub fn new(mut injections: Vec<Injection>) -> Self {
        injections.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        InjectionPlan { ordered: injections, next: 0 }
    }

    /// The next injection not yet consumed, if any.
    pub fn peek(&self) -> Option<&Injection> {
        self.ordered.get(self.next)
    }

    /// Consume and return the next injection.
    pub fn pop(&mut self) -> Option<Injection> {
        let i = self.ordered.get(self.next).copied();
        if i.is_some() {
            self.next += 1;
        }
        i
    }

    pub fn remaining(&self) -> usize {
        self.ordered.len() - self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_orders_by_time() {
        let mut plan = InjectionPlan::new(vec![
            Injection { at: 30.0, victim_index: 0, kind: FailureKind::Random },
            Injection { at: 10.0, victim_index: 1, kind: FailureKind::Systematic },
        ]);
        assert_eq!(plan.remaining(), 2);
        assert_eq!(plan.pop().unwrap().at, 10.0);
        assert_eq!(plan.pop().unwrap().at, 30.0);
        assert!(plan.pop().is_none());
    }
}
