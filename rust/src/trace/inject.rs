//! Failure injection: force failures at exact times, independent of the
//! stochastic clocks. Used by integration tests to walk the Figure-1
//! flowchart branch-by-branch, by `Scenario::inject` what-if specs, and
//! by the CLI to replay observed incident timelines.

use crate::model::events::{FailureKind, ServerId};
use crate::sim::Time;

/// A scripted failure: at time `at`, the active server of job `job` with
/// gang index `victim_index` (position in that job's active list, mod its
/// length) fails with the given kind. If the target job is not running at
/// `at` (or does not exist), the injection is dropped cleanly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Injection {
    pub at: Time,
    /// Target job id (index into the simulation's job table).
    pub job: u32,
    pub victim_index: usize,
    pub kind: FailureKind,
    /// When set, the injection targets this *server* (wherever it is)
    /// instead of `job`/`victim_index` — the form `workload: replay:`
    /// uses, since recorded `failure` events name servers, not gang
    /// slots. Dropped cleanly if the server is not computing at `at`.
    pub server: Option<ServerId>,
}

impl Injection {
    /// An injection against job 0 (the single-job default).
    pub fn new(at: Time, victim_index: usize, kind: FailureKind) -> Injection {
        Injection { at, job: 0, victim_index, kind, server: None }
    }

    /// An injection against an arbitrary job.
    pub fn for_job(job: u32, at: Time, victim_index: usize, kind: FailureKind) -> Injection {
        Injection { at, job, victim_index, kind, server: None }
    }

    /// A server-targeted injection (trace replay).
    pub fn for_server(at: Time, server: ServerId, kind: FailureKind) -> Injection {
        Injection { at, job: 0, victim_index: 0, kind, server: Some(server) }
    }
}

/// An injection schedule, consumed in time order.
#[derive(Clone, Debug, Default)]
pub struct InjectionPlan {
    ordered: Vec<Injection>,
    next: usize,
}

impl InjectionPlan {
    pub fn new(mut injections: Vec<Injection>) -> Self {
        injections.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        InjectionPlan { ordered: injections, next: 0 }
    }

    /// The next injection not yet consumed, if any.
    pub fn peek(&self) -> Option<&Injection> {
        self.ordered.get(self.next)
    }

    /// Consume and return the next injection.
    pub fn pop(&mut self) -> Option<Injection> {
        let i = self.ordered.get(self.next).copied();
        if i.is_some() {
            self.next += 1;
        }
        i
    }

    pub fn remaining(&self) -> usize {
        self.ordered.len() - self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_orders_by_time() {
        let mut plan = InjectionPlan::new(vec![
            Injection::new(30.0, 0, FailureKind::Random),
            Injection::new(10.0, 1, FailureKind::Systematic),
        ]);
        assert_eq!(plan.remaining(), 2);
        assert_eq!(plan.pop().unwrap().at, 10.0);
        assert_eq!(plan.pop().unwrap().at, 30.0);
        assert!(plan.pop().is_none());
    }

    #[test]
    fn constructors_set_target_job() {
        assert_eq!(Injection::new(5.0, 2, FailureKind::Random).job, 0);
        let i = Injection::for_job(3, 5.0, 2, FailureKind::Systematic);
        assert_eq!(i.job, 3);
        assert_eq!(i.victim_index, 2);
        assert_eq!(i.server, None);
    }

    #[test]
    fn for_server_targets_a_server() {
        let i = Injection::for_server(7.5, 19, FailureKind::Random);
        assert_eq!(i.server, Some(19));
        assert_eq!(i.at, 7.5);
    }
}
