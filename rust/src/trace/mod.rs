//! Structured event tracing, the observer API, and failure injection.
//!
//! Three layers:
//!
//! * [`TraceKind`] — the traced event vocabulary (every decision point of
//!   the simulation: failures, repairs, preemptions, stalls, recovery).
//! * [`Observer`] — the pluggable hook [`crate::model::ctx::SimCtx`]
//!   drives: implement it to stream per-event timelines out of a run
//!   (`Simulation::with_observer`). No observer installed = one `None`
//!   check on the hot path, zero allocation, zero draw-order impact.
//! * [`Trace`] — the built-in in-memory observer behind
//!   `Simulation::with_trace`, rendering text (`--trace`) or an NDJSON
//!   event log ([`Trace::to_ndjson`], `--trace-out`) for incident replay
//!   and capacity-planning plots.
//!
//! [`inject`] lets tests and `inject:` scenarios force failures at exact
//! times regardless of the stochastic clocks.

pub mod inject;

use crate::report::json::Json;
use crate::sim::Time;

/// One traced state transition.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub at: Time,
    pub kind: TraceKind,
}

/// The traced event vocabulary (mirrors the simulation's decision points).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    JobStarted,
    /// An open-loop job arrival ([`crate::model::workload`]): the job's
    /// resolved class shape rides along so a recorded trace replays the
    /// exact job mix (`workload: replay:`).
    JobArrival { job: u32, size: u32, len: Time, standbys: u32 },
    /// An arrived job's first successful allocation — it leaves the
    /// admission queue after `waited` minutes (0 when admitted on
    /// arrival). Legacy closed-loop jobs are born admitted and never
    /// emit this.
    JobAdmitted { job: u32, waited: Time },
    Failure { server: u32, systematic: bool },
    StandbySwap { failed: u32, replacement: u32 },
    HostSelection { allotted: usize },
    Stalled { allotted: usize },
    Unstalled { waited: Time },
    RecoveryStart { cost: Time },
    RecoveryDone,
    RepairStart { server: u32, manual: bool },
    RepairQueued { server: u32, manual: bool },
    RepairDone { server: u32, manual: bool, fixed: bool },
    Preempted { server: u32 },
    PreemptArrived { server: u32 },
    Retired { server: u32 },
    /// A correlated domain outage (topology level index + domain id)
    /// took `servers_hit` up-servers down as one event.
    DomainFailure { level: u32, domain_id: u32, servers_hit: usize },
    Regenerated { converted: usize },
    JobCompleted { makespan: Time },
    Horizon,
}

impl TraceKind {
    /// Stable event name (the NDJSON `event` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::JobStarted => "job_started",
            TraceKind::JobArrival { .. } => "job_arrival",
            TraceKind::JobAdmitted { .. } => "job_admitted",
            TraceKind::Failure { .. } => "failure",
            TraceKind::StandbySwap { .. } => "standby_swap",
            TraceKind::HostSelection { .. } => "host_selection",
            TraceKind::Stalled { .. } => "stalled",
            TraceKind::Unstalled { .. } => "unstalled",
            TraceKind::RecoveryStart { .. } => "recovery_start",
            TraceKind::RecoveryDone => "recovery_done",
            TraceKind::RepairStart { .. } => "repair_start",
            TraceKind::RepairQueued { .. } => "repair_queued",
            TraceKind::RepairDone { .. } => "repair_done",
            TraceKind::Preempted { .. } => "preempted",
            TraceKind::PreemptArrived { .. } => "preempt_arrived",
            TraceKind::Retired { .. } => "retired",
            TraceKind::DomainFailure { .. } => "domain_failure",
            TraceKind::Regenerated { .. } => "regenerated",
            TraceKind::JobCompleted { .. } => "job_completed",
            TraceKind::Horizon => "horizon",
        }
    }
}

/// One traced event as a JSON object: `{"at": t, "event": name, ...}`
/// with the kind's payload fields inlined.
pub fn event_json(at: Time, kind: &TraceKind) -> Json {
    let mut fields: Vec<(String, Json)> =
        vec![("at".into(), Json::Num(at)), ("event".into(), Json::str(kind.name()))];
    let mut add = |k: &str, v: Json| fields.push((k.to_string(), v));
    match kind {
        TraceKind::JobStarted | TraceKind::RecoveryDone | TraceKind::Horizon => {}
        TraceKind::JobArrival { job, size, len, standbys } => {
            add("job", (*job as u64).into());
            add("size", (*size as u64).into());
            add("len", (*len).into());
            add("standbys", (*standbys as u64).into());
        }
        TraceKind::JobAdmitted { job, waited } => {
            add("job", (*job as u64).into());
            add("waited", (*waited).into());
        }
        TraceKind::Failure { server, systematic } => {
            add("server", (*server as u64).into());
            add("systematic", (*systematic).into());
        }
        TraceKind::StandbySwap { failed, replacement } => {
            add("failed", (*failed as u64).into());
            add("replacement", (*replacement as u64).into());
        }
        TraceKind::HostSelection { allotted } | TraceKind::Stalled { allotted } => {
            add("allotted", (*allotted).into());
        }
        TraceKind::Unstalled { waited } => add("waited", (*waited).into()),
        TraceKind::RecoveryStart { cost } => add("cost", (*cost).into()),
        TraceKind::RepairStart { server, manual }
        | TraceKind::RepairQueued { server, manual } => {
            add("server", (*server as u64).into());
            add("manual", (*manual).into());
        }
        TraceKind::RepairDone { server, manual, fixed } => {
            add("server", (*server as u64).into());
            add("manual", (*manual).into());
            add("fixed", (*fixed).into());
        }
        TraceKind::Preempted { server }
        | TraceKind::PreemptArrived { server }
        | TraceKind::Retired { server } => add("server", (*server as u64).into()),
        TraceKind::DomainFailure { level, domain_id, servers_hit } => {
            add("level", (*level as u64).into());
            add("domain_id", (*domain_id as u64).into());
            add("servers_hit", (*servers_hit).into());
        }
        TraceKind::Regenerated { converted } => add("converted", (*converted).into()),
        TraceKind::JobCompleted { makespan } => add("makespan", (*makespan).into()),
    }
    Json::Obj(fields)
}

/// The observer hook: called once per traced decision point, in event
/// order, with the simulation clock. Implementations must not assume
/// they see every *engine* event — only the semantic ones above.
pub trait Observer {
    fn observe(&mut self, at: Time, kind: &TraceKind);
}

impl Observer for Trace {
    fn observe(&mut self, at: Time, kind: &TraceKind) {
        self.push(at, kind.clone());
    }
}

/// Adapter sharing one observer between the simulation (which owns its
/// observer box) and the caller (who wants the data back afterwards):
///
/// ```no_run
/// # use airesim::trace::{Shared, Trace};
/// # use airesim::config::Params;
/// # use airesim::model::cluster::Simulation;
/// use std::{cell::RefCell, rc::Rc};
/// let log = Rc::new(RefCell::new(Trace::default()));
/// let p = Params::small_test();
/// Simulation::new(&p, 42).with_observer(Box::new(Shared(log.clone()))).run();
/// println!("{}", log.borrow().to_ndjson());
/// ```
pub struct Shared<T: Observer>(pub std::rc::Rc<std::cell::RefCell<T>>);

impl<T: Observer> Observer for Shared<T> {
    fn observe(&mut self, at: Time, kind: &TraceKind) {
        self.0.borrow_mut().observe(at, kind);
    }
}

/// An in-memory trace of one run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    pub fn push(&mut self, at: Time, kind: TraceKind) {
        self.records.push(TraceRecord { at, kind });
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Count records matching a predicate.
    pub fn count(&self, f: impl Fn(&TraceKind) -> bool) -> usize {
        self.records.iter().filter(|r| f(&r.kind)).count()
    }

    /// Render as a text log (CLI `--trace` output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&format!("{:>14.3}  {:?}\n", r.at, r.kind));
        }
        s
    }

    /// Render as NDJSON — one `{"type":"event",...}` object per line
    /// (`--trace-out`; pipe into `jq` for incident replay and timeline
    /// plots). The schema is identical to the event lines of
    /// `--format ndjson`, so one `jq` filter serves both streams.
    pub fn to_ndjson(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            match event_json(r.at, &r.kind) {
                Json::Obj(mut fields) => {
                    fields.insert(0, ("type".to_string(), Json::str("event")));
                    s.push_str(&Json::Obj(fields).render());
                }
                other => s.push_str(&other.render()),
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_count_render() {
        let mut t = Trace::default();
        assert!(t.is_empty());
        t.push(1.0, TraceKind::JobStarted);
        t.push(5.0, TraceKind::Failure { server: 3, systematic: true });
        t.push(9.0, TraceKind::JobCompleted { makespan: 9.0 });
        assert_eq!(t.len(), 3);
        assert_eq!(t.count(|k| matches!(k, TraceKind::Failure { .. })), 1);
        let rendered = t.render();
        assert!(rendered.contains("JobStarted"));
        assert!(rendered.contains("server: 3"));
    }

    #[test]
    fn event_json_carries_payload() {
        let j = event_json(5.0, &TraceKind::Failure { server: 3, systematic: true });
        assert_eq!(j.render(), r#"{"at":5,"event":"failure","server":3,"systematic":true}"#);
        let j = event_json(0.5, &TraceKind::RecoveryStart { cost: 20.0 });
        assert_eq!(j.render(), r#"{"at":0.5,"event":"recovery_start","cost":20}"#);
    }

    #[test]
    fn ndjson_is_one_line_per_record() {
        let mut t = Trace::default();
        t.push(1.0, TraceKind::JobStarted);
        t.push(2.0, TraceKind::Retired { server: 7 });
        let s = t.to_ndjson();
        let lines: Vec<&str> = s.trim_end().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with(r#"{"type":"event","at":"#), "{}", lines[0]);
        assert!(lines[1].contains("\"retired\""));
    }

    #[test]
    fn shared_observer_collects() {
        use std::{cell::RefCell, rc::Rc};
        let log = Rc::new(RefCell::new(Trace::default()));
        let mut shared = Shared(log.clone());
        shared.observe(1.0, &TraceKind::JobStarted);
        shared.observe(2.0, &TraceKind::Horizon);
        assert_eq!(log.borrow().len(), 2);
    }
}
