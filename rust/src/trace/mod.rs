//! Structured event tracing + failure injection.
//!
//! Tracing is opt-in (`Simulation::with_trace`): the hot path pays one
//! branch when disabled. Traces power the determinism/replay tests and
//! the `--trace` CLI flag; [`inject`] lets tests force failures at exact
//! times regardless of the stochastic clocks.

pub mod inject;

use crate::sim::Time;

/// One traced state transition.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub at: Time,
    pub kind: TraceKind,
}

/// The traced event vocabulary (mirrors the simulation's decision points).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    JobStarted,
    Failure { server: u32, systematic: bool },
    StandbySwap { failed: u32, replacement: u32 },
    HostSelection { allotted: usize },
    Stalled { allotted: usize },
    Unstalled { waited: Time },
    RecoveryDone,
    RepairStart { server: u32, manual: bool },
    RepairDone { server: u32, manual: bool, fixed: bool },
    Preempted { server: u32 },
    PreemptArrived { server: u32 },
    Retired { server: u32 },
    Regenerated { converted: usize },
    JobCompleted { makespan: Time },
    Horizon,
}

/// An in-memory trace of one run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    pub fn push(&mut self, at: Time, kind: TraceKind) {
        self.records.push(TraceRecord { at, kind });
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Count records matching a predicate.
    pub fn count(&self, f: impl Fn(&TraceKind) -> bool) -> usize {
        self.records.iter().filter(|r| f(&r.kind)).count()
    }

    /// Render as a text log (CLI `--trace` output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&format!("{:>14.3}  {:?}\n", r.at, r.kind));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_count_render() {
        let mut t = Trace::default();
        assert!(t.is_empty());
        t.push(1.0, TraceKind::JobStarted);
        t.push(5.0, TraceKind::Failure { server: 3, systematic: true });
        t.push(9.0, TraceKind::JobCompleted { makespan: 9.0 });
        assert_eq!(t.len(), 3);
        assert_eq!(t.count(|k| matches!(k, TraceKind::Failure { .. })), 1);
        let rendered = t.render();
        assert!(rendered.contains("JobStarted"));
        assert!(rendered.contains("server: 3"));
    }
}
