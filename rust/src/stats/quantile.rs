//! Bounded streaming quantile estimation — the P² algorithm
//! (Jain & Chlamtac, CACM 1985).
//!
//! The admission queue reports p50/p99 job wait without storing every
//! wait sample: the P² estimator tracks one quantile with five markers
//! (O(1) memory, O(1) per insert), adjusting marker heights with a
//! piecewise-parabolic interpolation as observations stream in. Below
//! five observations it falls back to the exact sorted-sample quantile,
//! so small runs report exact values.

/// One-quantile P² estimator.
///
/// `value()` of an estimator that has seen no samples is `0.0`, not NaN:
/// the queue-wait metrics live in `RunOutputs` (which derives
/// `PartialEq` for the replication-determinism oracles), and a no-queue
/// run must compare equal to itself.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    /// Target quantile in `[0, 1]`.
    q: f64,
    /// Marker heights (estimated order statistics), ascending.
    heights: [f64; 5],
    /// Actual marker positions (1-based observation ranks).
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Desired-position increments per observation.
    inc: [f64; 5],
    /// Observations seen so far.
    n: u64,
}

impl P2Quantile {
    /// A fresh estimator for quantile `q` (e.g. `0.5`, `0.99`).
    pub fn new(q: f64) -> Self {
        debug_assert!((0.0..=1.0).contains(&q));
        P2Quantile {
            q,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            inc: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            n: 0,
        }
    }

    /// Observations inserted so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Insert one observation.
    pub fn insert(&mut self, x: f64) {
        if self.n < 5 {
            // Bootstrap: collect the first five exactly, sorted.
            let i = self.n as usize;
            self.heights[i] = x;
            self.n += 1;
            let slice = &mut self.heights[..self.n as usize];
            slice.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return;
        }

        // Find the cell k such that heights[k] <= x < heights[k+1],
        // extending the extreme markers when x falls outside them.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            // heights[0] <= x < heights[4]: one of cells 0..=3.
            let mut cell = 0;
            for i in 1..4 {
                if x >= self.heights[i] {
                    cell = i;
                }
            }
            cell
        };

        // Shift actual positions above the insertion cell.
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        self.n += 1;
        for i in 0..5 {
            self.desired[i] += self.inc[i];
        }

        // Adjust the three interior markers toward their desired
        // positions, parabolic when the neighbour gap allows, linear
        // otherwise (the P² update rule).
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let step_up = self.pos[i + 1] - self.pos[i] > 1.0;
            let step_dn = self.pos[i - 1] - self.pos[i] < -1.0;
            if (d >= 1.0 && step_up) || (d <= -1.0 && step_dn) {
                let d = d.signum();
                let h = self.parabolic(i, d);
                self.heights[i] =
                    if self.heights[i - 1] < h && h < self.heights[i + 1] {
                        h
                    } else {
                        self.linear(i, d)
                    };
                self.pos[i] += d;
            }
        }
    }

    /// Current quantile estimate; `0.0` before any observation, exact
    /// below five observations.
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n < 5 {
            // Exact sorted-sample quantile over what we have.
            let have = &self.heights[..self.n as usize];
            return crate::stats::percentile(have, self.q);
        }
        self.heights[2]
    }

    /// Piecewise-parabolic height prediction for marker `i` moved by
    /// `d` (±1).
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, pos) = (&self.heights, &self.pos);
        q[i] + d / (pos[i + 1] - pos[i - 1])
            * ((pos[i] - pos[i - 1] + d) * (q[i + 1] - q[i])
                / (pos[i + 1] - pos[i])
                + (pos[i + 1] - pos[i] - d) * (q[i] - q[i - 1])
                    / (pos[i] - pos[i - 1]))
    }

    /// Linear fallback when the parabola would leave the bracket.
    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + d * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn empty_is_zero_not_nan() {
        let est = P2Quantile::new(0.99);
        assert_eq!(est.value(), 0.0);
        assert_eq!(est.count(), 0);
    }

    #[test]
    fn small_samples_are_exact() {
        let mut est = P2Quantile::new(0.5);
        est.insert(7.0);
        assert_eq!(est.value(), 7.0);
        est.insert(1.0);
        assert_eq!(est.value(), 4.0); // exact interpolated median of {1,7}
        est.insert(3.0);
        assert_eq!(est.value(), 3.0);
    }

    #[test]
    fn median_of_uniform_stream() {
        let mut est = P2Quantile::new(0.5);
        let mut rng = Rng::new(42);
        for _ in 0..20_000 {
            est.insert(rng.next_f64());
        }
        let v = est.value();
        assert!((v - 0.5).abs() < 0.02, "median estimate {v}");
    }

    #[test]
    fn p99_of_uniform_stream() {
        let mut est = P2Quantile::new(0.99);
        let mut rng = Rng::new(7);
        for _ in 0..50_000 {
            est.insert(rng.next_f64());
        }
        let v = est.value();
        assert!((v - 0.99).abs() < 0.02, "p99 estimate {v}");
    }

    #[test]
    fn exponential_median_matches_ln2() {
        // Exp(1) median = ln 2 ≈ 0.693.
        let mut est = P2Quantile::new(0.5);
        let mut rng = Rng::new(9);
        for _ in 0..30_000 {
            est.insert(-rng.next_open_f64().ln());
        }
        let v = est.value();
        let want = std::f64::consts::LN_2;
        assert!((v - want).abs() / want < 0.05, "median {v} want {want}");
    }

    #[test]
    fn constant_stream_is_constant() {
        let mut est = P2Quantile::new(0.99);
        for _ in 0..1000 {
            est.insert(5.0);
        }
        assert_eq!(est.value(), 5.0);
    }

    #[test]
    fn estimate_stays_in_range() {
        let mut est = P2Quantile::new(0.9);
        let mut rng = Rng::new(3);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..5000 {
            let x = rng.next_f64() * 100.0 - 50.0;
            lo = lo.min(x);
            hi = hi.max(x);
            est.insert(x);
            let v = est.value();
            assert!(v >= lo && v <= hi, "estimate {v} outside [{lo}, {hi}]");
        }
    }
}
