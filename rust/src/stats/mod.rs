//! Output statistics (§III-B): "common statistics such as mean, median,
//! standard deviation and order percentiles for each of the outputs."
//!
//! [`metrics`] holds the central registry naming every reported output;
//! the [`Collector`]/[`Summary`] machinery here reduces registry metrics
//! across replications.

pub mod metrics;
pub mod quantile;

use std::collections::BTreeMap;

/// Summary statistics of one output across replications.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns None for an empty sample.
    pub fn from_values(values: &[f64]) -> Option<Summary> {
        if values.is_empty() {
            return None;
        }
        let n = values.len();
        let mut sorted: Vec<f64> = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        // Sample standard deviation (n-1), 0 for a single observation.
        let std = if n > 1 {
            (sorted.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / (n - 1) as f64)
                .sqrt()
        } else {
            0.0
        };
        Some(Summary {
            n,
            mean,
            std,
            min: sorted[0],
            p25: percentile(&sorted, 0.25),
            median: percentile(&sorted, 0.50),
            p75: percentile(&sorted, 0.75),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        })
    }

    /// 95% confidence half-width of the mean (normal approximation).
    pub fn ci95_halfwidth(&self) -> f64 {
        if self.n > 1 {
            1.96 * self.std / (self.n as f64).sqrt()
        } else {
            f64::INFINITY
        }
    }
}

/// Linear-interpolated order percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&q));
    debug_assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Collects named metric samples across replications and summarizes them.
#[derive(Clone, Debug, Default)]
pub struct Collector {
    series: BTreeMap<String, Vec<f64>>,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, metric: &str, value: f64) {
        self.series.entry(metric.to_string()).or_default().push(value);
    }

    pub fn values(&self, metric: &str) -> Option<&[f64]> {
        self.series.get(metric).map(|v| v.as_slice())
    }

    pub fn summary(&self, metric: &str) -> Option<Summary> {
        self.series.get(metric).and_then(|v| Summary::from_values(v))
    }

    /// All metric names, sorted (BTreeMap order → stable reports).
    pub fn metrics(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::from_values(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]).unwrap();
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        // Sample std of this classic set is sqrt(32/7).
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
        assert!((s.median - 4.5).abs() < 1e-12);
    }

    #[test]
    fn empty_sample_is_none() {
        assert!(Summary::from_values(&[]).is_none());
    }

    #[test]
    fn single_value() {
        let s = Summary::from_values(&[3.0]).unwrap();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.p99, 3.0);
        assert_eq!(s.ci95_halfwidth(), f64::INFINITY);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&sorted, 1.0), 5.0);
        assert_eq!(percentile(&sorted, 0.5), 3.0);
        assert!((percentile(&sorted, 0.25) - 2.0).abs() < 1e-12);
        assert!((percentile(&sorted, 0.1) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn percentiles_are_monotone() {
        let mut vals: Vec<f64> = (0..101).map(|i| ((i * 37) % 101) as f64).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut last = f64::NEG_INFINITY;
        for i in 0..=100 {
            let p = percentile(&vals, i as f64 / 100.0);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn collector_accumulates() {
        let mut c = Collector::new();
        for i in 0..10 {
            c.push("makespan", i as f64);
            c.push("failures", (i * 2) as f64);
        }
        assert_eq!(c.metrics(), vec!["failures", "makespan"]);
        let s = c.summary("makespan").unwrap();
        assert_eq!(s.n, 10);
        assert!((s.mean - 4.5).abs() < 1e-12);
        assert!(c.summary("nope").is_none());
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_values(&vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let many: Vec<f64> = (0..400).map(|i| 1.0 + (i % 4) as f64).collect();
        let b = Summary::from_values(&many).unwrap();
        assert!(b.ci95_halfwidth() < a.ci95_halfwidth());
    }
}
