//! The metric registry: one central table describing every output metric
//! the simulator reports.
//!
//! Before this registry, metric names were free-floating strings — the
//! sweep collector pushed one hand-written line per metric, the CLI
//! accepted any `--metric` and silently produced "(no data)" on a typo,
//! and nothing recorded units or meaning. Every consumer now resolves
//! names through [`REGISTRY`]:
//!
//! * [`crate::sweep::collect_outputs`] iterates it to populate the
//!   per-point [`crate::stats::Collector`];
//! * the JSON/CSV/NDJSON sinks in [`crate::report`] emit exactly the
//!   registry's metrics, with units;
//! * `airesim list-metrics` prints it;
//! * the CLI validates `--metric` against [`find`] and fails with the
//!   full name list instead of producing an empty table.
//!
//! Adding a metric is one [`Metric`] entry here — collectors, sinks, and
//! the CLI pick it up automatically.

use crate::config::Params;
use crate::model::outputs::RunOutputs;

/// One registered output metric.
pub struct Metric {
    /// Stable name (the `--metric` / collector / JSON key).
    pub name: &'static str,
    /// Unit label (`min`, `h`, `count`, `ratio`, `bool`).
    pub unit: &'static str,
    /// One-line meaning, shown by `list-metrics`.
    pub doc: &'static str,
    /// Pure extractor from one run's outputs.
    pub extract: fn(&Params, &RunOutputs) -> f64,
}

/// The default headline metric for sweep tables and what-if reports.
pub const DEFAULT_METRIC: &str = "makespan_hours";

/// Every metric the simulator reports, in presentation order.
pub const REGISTRY: &[Metric] = &[
    Metric {
        name: "makespan",
        unit: "min",
        doc: "total time to train all jobs (the last job's finish time)",
        extract: |_, o| o.makespan,
    },
    Metric {
        name: "makespan_hours",
        unit: "h",
        doc: "makespan in hours",
        extract: |_, o| o.makespan / 60.0,
    },
    Metric {
        name: "completed",
        unit: "bool",
        doc: "1 if every job finished before max_sim_time, else 0",
        extract: |_, o| if o.completed { 1.0 } else { 0.0 },
    },
    Metric {
        name: "failures_total",
        unit: "count",
        doc: "failures of both kinds across all jobs",
        extract: |_, o| o.failures_total as f64,
    },
    Metric {
        name: "failures_random",
        unit: "count",
        doc: "random (transient) failures",
        extract: |_, o| o.failures_random as f64,
    },
    Metric {
        name: "failures_systematic",
        unit: "count",
        doc: "systematic failures caused by bad servers",
        extract: |_, o| o.failures_systematic as f64,
    },
    Metric {
        name: "preemptions",
        unit: "count",
        doc: "spare-pool preemptions of other jobs' servers",
        extract: |_, o| o.preemptions as f64,
    },
    Metric {
        name: "preemption_cost",
        unit: "min",
        doc: "other-job work destroyed by preemptions (assumption 7)",
        extract: |_, o| o.preemption_cost,
    },
    Metric {
        name: "repairs_auto",
        unit: "count",
        doc: "repairs resolved at the automated stage",
        extract: |_, o| o.repairs_auto as f64,
    },
    Metric {
        name: "repairs_manual",
        unit: "count",
        doc: "repairs escalated to and completed by technicians",
        extract: |_, o| o.repairs_manual as f64,
    },
    Metric {
        name: "avg_run_duration",
        unit: "min",
        doc: "mean uninterrupted running burst between failures",
        extract: |_, o| o.avg_run_duration,
    },
    Metric {
        name: "host_selections",
        unit: "count",
        doc: "full host selections (standbys exhausted)",
        extract: |_, o| o.host_selections as f64,
    },
    Metric {
        name: "standby_swaps",
        unit: "count",
        doc: "failures absorbed by a warm-standby swap",
        extract: |_, o| o.standby_swaps as f64,
    },
    Metric {
        name: "stall_time",
        unit: "min",
        doc: "total time jobs sat stalled waiting for servers",
        extract: |_, o| o.stall_time,
    },
    Metric {
        name: "recovery_total",
        unit: "min",
        doc: "total time in checkpoint-restore recovery",
        extract: |_, o| o.recovery_total,
    },
    Metric {
        name: "retirements",
        unit: "count",
        doc: "servers permanently retired by the failure score",
        extract: |_, o| o.retirements as f64,
    },
    Metric {
        name: "undiagnosed",
        unit: "count",
        doc: "failures where no server could be blamed",
        extract: |_, o| o.undiagnosed as f64,
    },
    Metric {
        name: "wrong_diagnoses",
        unit: "count",
        doc: "failures where a healthy server was blamed",
        extract: |_, o| o.wrong_diagnoses as f64,
    },
    Metric {
        name: "regenerated_bad",
        unit: "count",
        doc: "servers turned bad by regeneration ticks",
        extract: |_, o| o.regenerated_bad as f64,
    },
    Metric {
        name: "work_lost",
        unit: "min",
        doc: "useful work lost to checkpoint granularity",
        extract: |_, o| o.work_lost,
    },
    Metric {
        name: "checkpoints_committed",
        unit: "count",
        doc: "checkpoints committed across all jobs (and tiers)",
        extract: |_, o| o.checkpoints_committed as f64,
    },
    Metric {
        name: "checkpoint_overhead",
        unit: "min",
        doc: "wall-clock spent writing checkpoints (gangs stalled)",
        extract: |_, o| o.checkpoint_overhead,
    },
    Metric {
        name: "goodput_fraction",
        unit: "ratio",
        doc: "useful work retained / wall-clock elapsed, summed over jobs",
        extract: |p, o| {
            let elapsed: f64 = o
                .per_job_makespans
                .iter()
                .map(|&m| if m > 0.0 { m } else { p.max_sim_time })
                .sum();
            if elapsed > 0.0 {
                o.work_done / elapsed
            } else {
                0.0
            }
        },
    },
    Metric {
        name: "domain_failures",
        unit: "count",
        doc: "correlated domain outages delivered (topology levels)",
        extract: |_, o| o.domain_failures as f64,
    },
    Metric {
        name: "domain_servers_lost",
        unit: "count",
        doc: "up-servers taken down by domain outages",
        extract: |_, o| o.domain_servers_lost as f64,
    },
    Metric {
        name: "domain_max_blast",
        unit: "count",
        doc: "most servers lost to a single domain outage",
        extract: |_, o| o.domain_max_blast as f64,
    },
    Metric {
        name: "domain_job_interruptions",
        unit: "count",
        doc: "whole-job interruptions: domain outages exceeding the standby stock",
        extract: |_, o| o.domain_job_interruptions as f64,
    },
    Metric {
        name: "domain_downtime",
        unit: "min",
        doc: "job downtime attributable to correlated domain outages",
        extract: |_, o| o.domain_downtime,
    },
    Metric {
        name: "utilization",
        unit: "ratio",
        doc: "failure-free job length / makespan",
        extract: |p, o| o.utilization(p.job_len),
    },
    Metric {
        name: "jobs_arrived",
        unit: "count",
        doc: "open-loop job arrivals delivered (workload subsystem)",
        extract: |_, o| o.jobs_arrived as f64,
    },
    Metric {
        name: "jobs_admitted",
        unit: "count",
        doc: "arrivals admitted: first successful allocation after arriving",
        extract: |_, o| o.jobs_admitted as f64,
    },
    Metric {
        name: "queue_wait_total",
        unit: "min",
        doc: "total admission-queue wait (still-queued jobs censored at the horizon)",
        extract: |_, o| o.queue_wait_total,
    },
    Metric {
        name: "queue_depth_max",
        unit: "count",
        doc: "peak admission-queue depth",
        extract: |_, o| o.queue_depth_max as f64,
    },
    Metric {
        name: "queue_wait_p50",
        unit: "min",
        doc: "median admission wait (P2 streaming estimate, exact below 5 samples)",
        extract: |_, o| o.queue_wait_p50,
    },
    Metric {
        name: "queue_wait_p99",
        unit: "min",
        doc: "99th-percentile admission wait (P2 streaming estimate)",
        extract: |_, o| o.queue_wait_p99,
    },
    Metric {
        name: "events_delivered",
        unit: "count",
        doc: "events the engine delivered (perf accounting)",
        extract: |_, o| o.events_delivered as f64,
    },
    Metric {
        name: "events_scheduled",
        unit: "count",
        doc: "events scheduled into the engine (thinning efficiency accounting)",
        extract: |_, o| o.events_scheduled as f64,
    },
];

/// Look a metric up by name.
pub fn find(name: &str) -> Option<&'static Metric> {
    REGISTRY.iter().find(|m| m.name == name)
}

/// All registered metric names, in registry order.
pub fn names() -> impl Iterator<Item = &'static str> {
    REGISTRY.iter().map(|m| m.name)
}

/// Resolve `--metric` input: the metric, or an error naming every
/// valid choice (replaces the old silent "(no data)" table on a typo).
pub fn resolve(name: &str) -> Result<&'static Metric, String> {
    find(name).ok_or_else(|| {
        format!(
            "unknown metric `{name}` (see `airesim list-metrics`; expected one of {})",
            names().collect::<Vec<_>>().join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for m in REGISTRY {
            assert!(seen.insert(m.name), "duplicate metric {}", m.name);
            assert!(!m.unit.is_empty() && !m.doc.is_empty(), "{}", m.name);
        }
    }

    #[test]
    fn find_and_resolve() {
        assert_eq!(find("makespan").unwrap().unit, "min");
        assert!(find("bogus").is_none());
        assert!(resolve(DEFAULT_METRIC).is_ok());
        let err = resolve("makespam").unwrap_err();
        assert!(err.contains("list-metrics") && err.contains("makespan"), "{err}");
    }

    #[test]
    fn extractors_cover_outputs() {
        let p = Params::small_test();
        let o = RunOutputs {
            makespan: 120.0,
            completed: true,
            failures_total: 3,
            ..Default::default()
        };
        let get = |n: &str| (find(n).unwrap().extract)(&p, &o);
        assert_eq!(get("makespan"), 120.0);
        assert_eq!(get("makespan_hours"), 2.0);
        assert_eq!(get("completed"), 1.0);
        assert_eq!(get("failures_total"), 3.0);
        assert_eq!(get("utilization"), o.utilization(p.job_len));
    }
}
