//! # AIReSim — AI cluster Reliability Simulator
//!
//! A production-grade reproduction of *"AIReSim: A Discrete Event Simulator
//! for Large-scale AI Cluster Reliability Modeling"* (Pattabiraman, Patel,
//! Lin — CS.DC 2026).
//!
//! The crate is a three-layer system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a deterministic
//!   discrete-event simulation of failure / recovery / repair / scheduling /
//!   pooling in clusters running gang-scheduled AI training jobs. The
//!   simulation core is decomposed into pluggable policy subsystems
//!   (host [`model::selection`], repair queueing [`model::repair`],
//!   checkpointing [`model::checkpoint`], failure clocks
//!   [`model::failure`]) over a shared [`model::ctx::SimCtx`], with a
//!   declarative [`scenario`] layer, a batched-replication [`sweep`]
//!   runner, and a config + statistics + reporting stack around it.
//! * **Layer 2 (`python/compile/model.py`)** — the paper's analytical
//!   comparator (batched CTMC transient analysis), authored in JAX and
//!   AOT-compiled to `artifacts/analytic.hlo.txt`.
//! * **Layer 1 (`python/compile/kernels/uniformization.py`)** — the Pallas
//!   kernel at the analytical model's hot spot (batched squaring chain).
//!
//! Python never runs at simulation time: [`runtime`] loads the HLO artifact
//! through PJRT (`xla` crate) and [`analytical`] provides a bit-equivalent
//! pure-Rust fallback used for cross-validation.
//!
//! ## Quick start
//!
//! ```no_run
//! use airesim::config::Params;
//! use airesim::model::cluster::Simulation;
//!
//! let params = Params::table1_defaults();
//! let outputs = Simulation::new(&params, 42).run();
//! println!("makespan = {:.1} h", outputs.makespan / 60.0);
//! ```

pub mod analytical;
pub mod config;
pub mod model;
pub mod optimize;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod sweep;
pub mod testkit;
pub mod trace;
pub mod util;
