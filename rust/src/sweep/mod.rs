//! Parameter sweeps (§III-D): one- or multi-axis sweeps with
//! replications, run in parallel across OS threads.
//!
//! Axes are **typed**: a sweep point's overrides hold [`AxisValue`]s — a
//! number for the Table-I knobs, or a *name* for policy axes. A `sweep:`
//! spec can therefore cross-product `policies.selection` alongside
//! `recovery_time`, and the record/report layer labels both in the same
//! tables.
//!
//! Seed discipline: replication `r` of point `i` uses
//! `Rng::derived(master_seed, &[i, r])`, so changing the swept values or
//! the replication count of one axis never perturbs another point's
//! random stream. [`Sweep::with_crn`] switches to common random numbers
//! (same stream at every point for a given `r`) — the classic variance-
//! reduction technique for estimating point-to-point *differences*.

pub mod ctrl;

use crate::config::Params;
use crate::model::cluster::ReplicationRunner;
use crate::model::{PolicySpec, RunOutputs};
use crate::sim::rng::Rng;
use crate::stats::{metrics, Collector, Summary};
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One value of one sweep axis: a numeric parameter value, or a policy
/// (or other registry) name for `policies.*` axes.
#[derive(Clone, Debug, PartialEq)]
pub enum AxisValue {
    Num(f64),
    Name(String),
}

impl fmt::Display for AxisValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AxisValue::Num(v) => write!(f, "{v}"),
            AxisValue::Name(s) => write!(f, "{s}"),
        }
    }
}

impl From<f64> for AxisValue {
    fn from(v: f64) -> Self {
        AxisValue::Num(v)
    }
}

impl From<&str> for AxisValue {
    fn from(s: &str) -> Self {
        AxisValue::Name(s.to_string())
    }
}

/// One point of a sweep: the overridden axis values and its label.
/// Numeric names address [`Params`] fields; `policies.<axis>` names
/// address [`PolicySpec`] axes.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// (axis name, value) overrides applied to the base params/policies.
    pub overrides: Vec<(String, AxisValue)>,
}

impl SweepPoint {
    pub fn label(&self) -> String {
        self.overrides
            .iter()
            .map(|(n, v)| format!("{n}={v}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Apply the *numeric* overrides to a parameter set (policy axes are
    /// skipped — the analytic prescreen layer is policy-blind).
    pub fn apply(&self, base: &Params) -> Params {
        let mut p = base.clone();
        for (name, value) in &self.overrides {
            if let AxisValue::Num(v) = value {
                let ok = p.set_by_name(name, *v);
                assert!(ok, "unknown sweep parameter `{name}`");
            }
        }
        p
    }

    /// Apply every override: numeric axes onto `base`, `policies.*` axes
    /// onto `policies`. This is the sweep workers' entry point; validate
    /// with [`Sweep::validate`] first so workers never see an error.
    pub fn apply_full(
        &self,
        base: &Params,
        policies: &PolicySpec,
    ) -> Result<(Params, PolicySpec), String> {
        let mut p = base.clone();
        let mut spec = policies.clone();
        for (name, value) in &self.overrides {
            match (name.strip_prefix("policies."), value) {
                (Some(axis), AxisValue::Name(v)) => spec.set(axis, v)?,
                (Some(_), AxisValue::Num(v)) => {
                    return Err(format!(
                        "policy axis `{name}` needs a policy name, got `{v}`"
                    ))
                }
                (None, AxisValue::Num(v)) => {
                    if !p.set_by_name(name, *v) {
                        return Err(format!("unknown sweep parameter `{name}`"));
                    }
                }
                (None, AxisValue::Name(v)) => {
                    return Err(format!(
                        "parameter `{name}` needs a numeric value, got `{v}`"
                    ))
                }
            }
        }
        Ok((p, spec))
    }
}

/// A sweep specification (§III-D: `OneWaySweep` / `TwoWaySweep`, plus
/// policy axes).
#[derive(Clone, Debug)]
pub struct Sweep {
    /// Human-readable experiment title.
    pub title: String,
    pub points: Vec<SweepPoint>,
    pub replications: usize,
    pub master_seed: u64,
    /// Common random numbers: replication `r` uses the *same* stream at
    /// every point (variance reduction for point-to-point differences).
    /// Off by default: independent streams per (point, replication).
    pub crn: bool,
    /// Named policy selection applied at every point (defaults to the
    /// paper's policies); `policies.*` axes override per point.
    pub policies: PolicySpec,
}

impl Sweep {
    /// Vary one numeric parameter (the paper's
    /// `OneWaySweep("...", "name", [v...])`).
    pub fn one_way(
        title: &str,
        name: &str,
        values: &[f64],
        replications: usize,
        master_seed: u64,
    ) -> Sweep {
        let axis: Vec<AxisValue> = values.iter().map(|&v| v.into()).collect();
        Sweep::from_axes(title, &[(name.to_string(), axis)], replications, master_seed)
    }

    /// Vary two numeric parameters over their cross product (x-major
    /// order).
    pub fn two_way(
        title: &str,
        x_name: &str,
        x_values: &[f64],
        y_name: &str,
        y_values: &[f64],
        replications: usize,
        master_seed: u64,
    ) -> Sweep {
        let x: Vec<AxisValue> = x_values.iter().map(|&v| v.into()).collect();
        let y: Vec<AxisValue> = y_values.iter().map(|&v| v.into()).collect();
        Sweep::from_axes(
            title,
            &[(x_name.to_string(), x), (y_name.to_string(), y)],
            replications,
            master_seed,
        )
    }

    /// Cross-product any number of typed axes (first axis outermost —
    /// matches [`Sweep::two_way`]'s x-major order). Numeric and
    /// `policies.*` axes mix freely.
    pub fn from_axes(
        title: &str,
        axes: &[(String, Vec<AxisValue>)],
        replications: usize,
        master_seed: u64,
    ) -> Sweep {
        let mut points = vec![SweepPoint { overrides: Vec::new() }];
        for (name, values) in axes {
            let mut next = Vec::with_capacity(points.len() * values.len().max(1));
            for stem in &points {
                for v in values {
                    let mut overrides = stem.overrides.clone();
                    overrides.push((name.clone(), v.clone()));
                    next.push(SweepPoint { overrides });
                }
            }
            points = next;
        }
        Sweep {
            title: title.to_string(),
            points,
            replications,
            master_seed,
            crn: false,
            policies: PolicySpec::default(),
        }
    }

    /// Enable common random numbers across points.
    pub fn with_crn(mut self) -> Self {
        self.crn = true;
        self
    }

    /// Run every point under the given named policies (per-point
    /// `policies.*` axes override individual axes on top).
    pub fn with_policies(mut self, policies: PolicySpec) -> Self {
        self.policies = policies;
        self
    }

    /// Check every point up front: unknown parameter names, mistyped
    /// axis values, and policy specs that cannot build against the swept
    /// params (e.g. `failure=gang` with Weibull clocks) become one clean
    /// error here instead of a worker-thread panic mid-sweep.
    pub fn validate(&self, base: &Params) -> Result<(), String> {
        for pt in &self.points {
            let (p, spec) = pt
                .apply_full(base, &self.policies)
                .map_err(|e| format!("sweep point `{}`: {e}", pt.label()))?;
            spec.build(&p)
                .map_err(|e| format!("sweep point `{}`: {e}", pt.label()))?;
        }
        Ok(())
    }
}

/// Parse a config document's optional `policies:` section into a spec:
///
/// ```yaml
/// policies:
///   selection: locality
///   repair: job_first
/// ```
pub fn policies_from_doc(doc: &crate::config::yaml::Value) -> Result<PolicySpec, String> {
    let mut spec = PolicySpec::default();
    if let Some(section) = doc.get("policies") {
        let map = section.as_map().ok_or("`policies:` must be a map")?;
        for (axis, v) in map {
            let value = v
                .as_str()
                .ok_or_else(|| format!("policies.{axis} must be a name"))?;
            spec.set(axis, value)?;
        }
    }
    Ok(spec)
}

/// Strict boolean parse of a `crn:` value. A misspelling must not
/// silently run a comparison on independent streams, so anything outside
/// the standard spellings is an error, not `false`. Shared by the
/// `sweep:` parser and the `multi:` study parser.
pub fn parse_crn(v: &crate::config::yaml::Value) -> Result<bool, String> {
    let s = v.as_str().unwrap_or("");
    match s.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => Err(format!("bad `crn:` value `{other}` (expected true or false)")),
    }
}

/// Build a sweep from a parsed config document's `sweep:` section
/// (§III-D's experiment files). Axes are numeric parameters or
/// `policies.<axis>` names; `crn: true` (top-level, or inside the
/// `sweep:` section) runs every point on common random numbers (the
/// variance-reduction mode policy shoot-outs want — "the same master
/// streams"):
///
/// ```yaml
/// sweep:
///   kind: two_way            # or one_way
///   x: { name: policies.selection, values: [first_fit, locality] }
///   y: { name: working_pool, values: [4112, 4128, 4160, 4192] }
/// replications: 30
/// seed: 42
/// crn: true                  # optional: common random numbers
/// ```
pub fn sweep_from_doc(
    doc: &crate::config::yaml::Value,
    default_reps: usize,
    default_seed: u64,
) -> Result<Sweep, String> {
    let sweep = doc.get("sweep").ok_or("no `sweep:` section")?;
    let reps = doc
        .get("replications")
        .and_then(|v| v.as_f64())
        .map(|v| v as usize)
        .unwrap_or(default_reps);
    let seed = doc
        .get("seed")
        .and_then(|v| v.as_f64())
        .map(|v| v as u64)
        .unwrap_or(default_seed);
    let axis = |key: &str| -> Result<(String, Vec<AxisValue>), String> {
        let a = sweep.get(key).ok_or_else(|| format!("sweep.{key} missing"))?;
        let name = a
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("sweep.{key}.name missing"))?;
        let raw = a
            .get("values")
            .ok_or_else(|| format!("sweep.{key}.values missing"))?;
        let values = match name.strip_prefix("policies.") {
            // Policy axis: a list of names, each validated against the
            // policy registry at parse time.
            Some(axis_name) => {
                let list = raw
                    .as_list()
                    .ok_or_else(|| format!("sweep.{key}.values must be a list"))?;
                let mut out = Vec::with_capacity(list.len());
                for v in list {
                    let s = v.as_str().ok_or_else(|| {
                        format!("sweep.{key}.values: expected policy names")
                    })?;
                    PolicySpec::default()
                        .set(axis_name, s)
                        .map_err(|e| format!("sweep.{key}: {e}"))?;
                    out.push(AxisValue::Name(s.to_string()));
                }
                out
            }
            None => raw
                .as_f64_list()
                .ok_or_else(|| format!("sweep.{key}.values missing"))?
                .into_iter()
                .map(AxisValue::Num)
                .collect(),
        };
        Ok((name.to_string(), values))
    };
    // NOTE: the doc's `policies:` section is deliberately NOT attached
    // here — policy resolution (doc section + CLI overrides + build
    // validation) has one owner per entry point, which then calls
    // [`Sweep::with_policies`]. See `policies_from_doc`.
    // Accepted at the document top level or inside the `sweep:` section —
    // both placements are natural, and the unused one being silently
    // ignored would be the exact failure mode the strict parse exists to
    // prevent.
    let crn = match doc.get("crn").or_else(|| sweep.get("crn")) {
        None => false,
        Some(v) => parse_crn(v)?,
    };
    let kind = sweep.get("kind").and_then(|v| v.as_str()).unwrap_or("one_way");
    let built = match kind {
        "one_way" => {
            let (name, values) = axis("x")?;
            let title = name.clone();
            Sweep::from_axes(&title, &[(name, values)], reps, seed)
        }
        "two_way" => {
            let (xn, xv) = axis("x")?;
            let (yn, yv) = axis("y")?;
            Sweep::from_axes(&format!("{xn} x {yn}"), &[(xn, xv), (yn, yv)], reps, seed)
        }
        other => return Err(format!("unknown sweep kind `{other}`")),
    };
    Ok(if crn { built.with_crn() } else { built })
}

/// Results of one sweep point across replications.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: SweepPoint,
    pub collector: Collector,
}

impl PointResult {
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        self.collector.summary(metric)
    }
}

/// Full sweep results, in point order.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub title: String,
    pub points: Vec<PointResult>,
}

/// Push one run's outputs into a metric collector — every metric in the
/// central registry ([`crate::stats::metrics::REGISTRY`]), nothing else.
pub fn collect_outputs(c: &mut Collector, p: &Params, o: &RunOutputs) {
    for m in metrics::REGISTRY {
        c.push(m.name, (m.extract)(p, o));
    }
}

/// Run one replication of one point on a (reusable) runner.
fn run_one(
    runner: &mut ReplicationRunner,
    base: &Params,
    sweep: &Sweep,
    point_idx: usize,
    rep: usize,
) -> (Params, RunOutputs) {
    let (p, spec) = sweep.points[point_idx]
        .apply_full(base, &sweep.policies)
        .expect("sweep validated before running");
    // CRN: drop the point index from the stream path so every point sees
    // the same draws at replication `rep`.
    let rng = if sweep.crn {
        Rng::derived(sweep.master_seed, &[CRN_STREAM, rep as u64])
    } else {
        Rng::derived(sweep.master_seed, &[point_idx as u64, rep as u64])
    };
    let out = runner.run(&p, &spec, rng);
    (p, out)
}

/// The sentinel stream-path element common random numbers substitute for
/// the per-unit index: every sweep point (and every study child) derives
/// replication `r` from `Rng::derived(master, &[CRN_STREAM, r])`, so CRN
/// comparisons across *different* experiment shapes share draws too.
pub const CRN_STREAM: u64 = u64::MAX;

/// The shared execution pool: drain `n_units * reps` (unit, replication)
/// tasks through `threads` OS threads (0 = available parallelism), each
/// worker owning one [`ReplicationRunner`] so simulation state is reset —
/// not reallocated — between that worker's tasks. Returns one filled
/// [`Collector`] per unit, in unit order.
///
/// This is the one worker pool behind every batched experiment shape:
/// [`run_sweep`] drains sweep points through it, and a `multi:` study
/// ([`crate::scenario::study`]) flattens *all* of its children's
/// replications into this single queue — a 6-child study saturates every
/// core instead of running children serially. Results are independent of
/// the thread count by construction (each task's stream is derived from
/// its `(unit, rep)` identity, and collectors sort before reducing).
pub fn run_pool<F>(n_units: usize, reps: usize, threads: usize, run: F) -> Vec<Collector>
where
    F: Fn(&mut ReplicationRunner, usize, usize) -> (Params, RunOutputs) + Sync,
{
    let reps = reps.max(1);
    let total = n_units * reps;

    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(total.max(1));

    // Work queue: flat task index -> (unit, replication).
    let next = AtomicUsize::new(0);
    let collectors: Vec<Mutex<Collector>> =
        (0..n_units).map(|_| Mutex::new(Collector::new())).collect();
    // Ambient execution control (serve requests install a gate /
    // cancellation flag / warm cache; the CLI default is all-None).
    let ec = ctrl::current();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut runner = ReplicationRunner::new();
                runner.warm = ec.warm.clone();
                runner.cancel = ec.cancel.clone();
                loop {
                    let _permit = ec.gate.as_ref().map(|g| g.acquire());
                    let task = next.fetch_add(1, Ordering::Relaxed);
                    if task >= total {
                        break;
                    }
                    let unit = task / reps;
                    let rep = task % reps;
                    let (p, out) = run(&mut runner, unit, rep);
                    let mut c = collectors[unit].lock().unwrap();
                    collect_outputs(&mut c, &p, &out);
                }
            });
        }
    });

    collectors.into_iter().map(|c| c.into_inner().unwrap()).collect()
}

/// [`run_pool`]'s replication-ordered sibling: the same flat task queue
/// and per-worker runner reuse, but raw outputs land in `slots[rep]` of
/// their unit instead of a completion-ordered collector. Paired-CRN
/// inference ([`crate::optimize`]) needs replication `r` of unit A
/// aligned with replication `r` of unit B — a completion-ordered
/// collector destroys exactly that alignment under multi-threading.
/// Returns, per unit, the unit's params and its outputs in rep order.
pub fn run_pool_ordered<F>(
    n_units: usize,
    reps: usize,
    threads: usize,
    run: F,
) -> Vec<(Params, Vec<RunOutputs>)>
where
    F: Fn(&mut ReplicationRunner, usize, usize) -> (Params, RunOutputs) + Sync,
{
    let reps = reps.max(1);
    let total = n_units * reps;

    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(total.max(1));

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Vec<Option<(Params, RunOutputs)>>>> = (0..n_units)
        .map(|_| Mutex::new((0..reps).map(|_| None).collect()))
        .collect();
    let ec = ctrl::current();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut runner = ReplicationRunner::new();
                runner.warm = ec.warm.clone();
                runner.cancel = ec.cancel.clone();
                loop {
                    let _permit = ec.gate.as_ref().map(|g| g.acquire());
                    let task = next.fetch_add(1, Ordering::Relaxed);
                    if task >= total {
                        break;
                    }
                    let unit = task / reps;
                    let rep = task % reps;
                    // Cancellation never skips a slot (`run_pool_ordered`
                    // asserts completeness): the runner fast-skips and
                    // fills the slot with default outputs instead.
                    let (p, out) = run(&mut runner, unit, rep);
                    slots[unit].lock().unwrap()[rep] = Some((p, out));
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|unit| {
            let filled = unit.into_inner().unwrap();
            let mut params = None;
            let outs = filled
                .into_iter()
                .map(|slot| {
                    let (p, out) = slot.expect("every (unit, rep) task ran");
                    params.get_or_insert(p);
                    out
                })
                .collect();
            (params.expect("reps >= 1"), outs)
        })
        .collect()
}

/// Execute a sweep over the shared execution pool ([`run_pool`]).
pub fn run_sweep(base: &Params, sweep: &Sweep, threads: usize) -> SweepResult {
    let reps = sweep.replications.max(1);
    let collectors =
        run_pool(sweep.points.len(), reps, threads, |runner, point_idx, rep| {
            run_one(runner, base, sweep, point_idx, rep)
        });
    SweepResult {
        title: sweep.title.clone(),
        points: sweep
            .points
            .iter()
            .cloned()
            .zip(collectors)
            .map(|(point, collector)| PointResult { point, collector })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_way_points() {
        let s = Sweep::one_way("t", "recovery_time", &[10.0, 20.0, 30.0], 5, 1);
        assert_eq!(s.points.len(), 3);
        assert_eq!(
            s.points[1].overrides,
            vec![("recovery_time".to_string(), AxisValue::Num(20.0))]
        );
        assert_eq!(s.points[1].label(), "recovery_time=20");
    }

    #[test]
    fn two_way_cross_product() {
        let s = Sweep::two_way("t", "a_x", &[1.0, 2.0], "warm_standbys", &[4.0, 8.0, 16.0], 1, 1);
        assert_eq!(s.points.len(), 6);
        // x-major order.
        assert_eq!(s.points[0].overrides[0].1, AxisValue::Num(1.0));
        assert_eq!(s.points[0].overrides[1].1, AxisValue::Num(4.0));
        assert_eq!(s.points[2].overrides[1].1, AxisValue::Num(16.0));
        assert_eq!(s.points[3].overrides[0].1, AxisValue::Num(2.0));
    }

    #[test]
    fn apply_overrides() {
        let base = Params::small_test();
        let point = SweepPoint {
            overrides: vec![
                ("recovery_time".into(), 99.0.into()),
                ("warm_standbys".into(), 2.0.into()),
            ],
        };
        let p = point.apply(&base);
        assert_eq!(p.recovery_time, 99.0);
        assert_eq!(p.warm_standbys, 2);
        assert_eq!(base.recovery_time, 20.0, "base untouched");
    }

    #[test]
    fn apply_full_routes_policy_axes() {
        let base = Params::small_test();
        let point = SweepPoint {
            overrides: vec![
                ("policies.selection".into(), "locality".into()),
                ("recovery_time".into(), 40.0.into()),
            ],
        };
        let (p, spec) = point.apply_full(&base, &PolicySpec::default()).unwrap();
        assert_eq!(p.recovery_time, 40.0);
        assert_eq!(spec.selection, "locality");
        assert_eq!(spec.repair, "fifo", "other axes untouched");
        assert_eq!(point.label(), "policies.selection=locality, recovery_time=40");

        // Mistyped values are errors, not panics.
        let bad = SweepPoint {
            overrides: vec![("policies.selection".into(), 3.0.into())],
        };
        assert!(bad.apply_full(&base, &PolicySpec::default()).is_err());
        let bad = SweepPoint {
            overrides: vec![("recovery_time".into(), "locality".into())],
        };
        assert!(bad.apply_full(&base, &PolicySpec::default()).is_err());
    }

    #[test]
    fn policy_axis_sweep_from_doc() {
        let doc = crate::config::yaml::parse(
            "sweep:\n  kind: two_way\n  x: { name: policies.selection, values: [first_fit, locality] }\n  y: { name: recovery_time, values: [10, 30] }\n",
        )
        .unwrap();
        let s = sweep_from_doc(&doc, 2, 1).unwrap();
        assert_eq!(s.points.len(), 4);
        assert_eq!(s.points[0].label(), "policies.selection=first_fit, recovery_time=10");
        assert_eq!(s.points[3].label(), "policies.selection=locality, recovery_time=30");
        s.validate(&Params::small_test()).unwrap();

        // Bad policy names are parse-time errors.
        let bad = crate::config::yaml::parse(
            "sweep:\n  kind: one_way\n  x: { name: policies.selection, values: [bogus] }\n",
        )
        .unwrap();
        assert!(sweep_from_doc(&bad, 2, 1).is_err());
    }

    #[test]
    fn validate_catches_incompatible_policy_points() {
        use crate::config::DistKind;
        let mut base = Params::small_test();
        base.failure_dist = DistKind::Weibull { shape: 1.5 };
        let s = Sweep::from_axes(
            "t",
            &[("policies.failure".to_string(), vec!["per_server".into(), "gang".into()])],
            1,
            1,
        );
        let err = s.validate(&base).unwrap_err();
        assert!(err.contains("gang"), "{err}");
    }

    #[test]
    fn policy_axis_sweep_runs_end_to_end() {
        let base = Params::small_test();
        let s = Sweep::from_axes(
            "sel",
            &[(
                "policies.selection".to_string(),
                vec!["first_fit".into(), "locality".into()],
            )],
            2,
            9,
        );
        s.validate(&base).unwrap();
        let r = run_sweep(&base, &s, 2);
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.points[0].point.label(), "policies.selection=first_fit");
        assert_eq!(r.points[1].point.label(), "policies.selection=locality");
        for pr in &r.points {
            assert_eq!(pr.summary("makespan").unwrap().n, 2);
        }
    }

    #[test]
    fn sweep_runs_and_is_deterministic() {
        let base = Params::small_test();
        let sweep = Sweep::one_way("det", "recovery_time", &[10.0, 30.0], 4, 7);
        let r1 = run_sweep(&base, &sweep, 2);
        let r2 = run_sweep(&base, &sweep, 4); // thread count must not matter
        assert_eq!(r1.points.len(), 2);
        for (a, b) in r1.points.iter().zip(&r2.points) {
            let sa = a.summary("makespan").unwrap();
            let sb = b.summary("makespan").unwrap();
            assert_eq!(sa.n, 4);
            assert_eq!(sa.mean, sb.mean, "determinism across thread counts");
            assert_eq!(sa.std, sb.std);
        }
    }

    #[test]
    fn crn_key_enables_common_random_numbers() {
        let parse = |head: &str| {
            crate::config::yaml::parse(&format!(
                "{head}sweep:\n  kind: one_way\n  x: {{ name: recovery_time, values: [10, 30] }}\n"
            ))
            .unwrap()
        };
        for head in ["crn: true\n", "crn: True\n", "crn: yes\n", "crn: 1\n"] {
            assert!(sweep_from_doc(&parse(head), 2, 1).unwrap().crn, "{head}");
        }
        for head in ["", "crn: false\n", "crn: off\n"] {
            assert!(!sweep_from_doc(&parse(head), 2, 1).unwrap().crn, "{head:?}");
        }
        // A misspelling is an error, not a silent independent-streams run.
        let err = sweep_from_doc(&parse("crn: ture\n"), 2, 1).unwrap_err();
        assert!(err.contains("crn"), "{err}");
        // The key is also honored inside the sweep: section itself.
        let doc = crate::config::yaml::parse(
            "sweep:\n  kind: one_way\n  crn: true\n  x: { name: recovery_time, values: [10] }\n",
        )
        .unwrap();
        assert!(sweep_from_doc(&doc, 2, 1).unwrap().crn, "crn nested under sweep:");
    }

    #[test]
    fn policies_section_parses() {
        let doc = crate::config::yaml::parse(
            "policies:\n  selection: locality\n  repair: job_first\n",
        )
        .unwrap();
        let spec = policies_from_doc(&doc).unwrap();
        assert_eq!(spec.selection, "locality");
        assert_eq!(spec.repair, "job_first");
        // No section: defaults.
        let empty = crate::config::yaml::parse("seed: 1\n").unwrap();
        assert_eq!(policies_from_doc(&empty).unwrap(), PolicySpec::default());
        // Bad name: rejected.
        let bad = crate::config::yaml::parse("policies:\n  selection: bogus\n").unwrap();
        assert!(policies_from_doc(&bad).is_err());
    }

    #[test]
    fn non_default_policies_sweep_deterministically() {
        let base = Params::small_test();
        let spec = PolicySpec {
            selection: "locality".into(),
            repair: "job_first".into(),
            checkpoint: "auto".into(),
            failure: "per_server".into(),
        };
        let sweep = Sweep::one_way("pol", "recovery_time", &[10.0, 30.0], 3, 5)
            .with_policies(spec);
        let r1 = run_sweep(&base, &sweep, 1);
        let r2 = run_sweep(&base, &sweep, 3);
        for (a, b) in r1.points.iter().zip(&r2.points) {
            let sa = a.summary("makespan").unwrap();
            let sb = b.summary("makespan").unwrap();
            assert_eq!(sa.n, 3);
            assert_eq!(sa.mean, sb.mean);
        }
    }

    #[test]
    fn collector_holds_every_registry_metric() {
        let base = Params::small_test();
        let sweep = Sweep::one_way("m", "recovery_time", &[10.0], 2, 3);
        let r = run_sweep(&base, &sweep, 1);
        for m in crate::stats::metrics::REGISTRY {
            let s = r.points[0].summary(m.name);
            assert!(s.is_some(), "metric {} missing from collector", m.name);
            assert_eq!(s.unwrap().n, 2);
        }
    }

    #[test]
    fn recovery_time_monotone_in_small_config() {
        // The paper's Fig 2(a) shape on the small test config.
        let base = Params::small_test();
        let sweep = Sweep::one_way("fig2a-small", "recovery_time", &[5.0, 120.0], 8, 11);
        let r = run_sweep(&base, &sweep, 0);
        let lo = r.points[0].summary("makespan").unwrap().mean;
        let hi = r.points[1].summary("makespan").unwrap().mean;
        assert!(hi > lo, "makespan should grow with recovery time: {lo} vs {hi}");
    }
}
