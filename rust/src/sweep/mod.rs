//! Parameter sweeps (§III-D): one-way and two-way sweeps with replications,
//! run in parallel across OS threads.
//!
//! Seed discipline: replication `r` of point `i` uses
//! `Rng::derived(master_seed, &[i, r])`, so changing the swept values or
//! the replication count of one axis never perturbs another point's
//! random stream. [`Sweep::with_crn`] switches to common random numbers
//! (same stream at every point for a given `r`) — the classic variance-
//! reduction technique for estimating point-to-point *differences*.

use crate::config::Params;
use crate::model::cluster::ReplicationRunner;
use crate::model::{PolicySpec, RunOutputs};
use crate::sim::rng::Rng;
use crate::stats::{Collector, Summary};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One point of a sweep: the overridden parameter values and its label.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// (parameter name, value) overrides applied to the base params.
    pub overrides: Vec<(String, f64)>,
}

impl SweepPoint {
    pub fn label(&self) -> String {
        self.overrides
            .iter()
            .map(|(n, v)| format!("{n}={v}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    pub fn apply(&self, base: &Params) -> Params {
        let mut p = base.clone();
        for (name, value) in &self.overrides {
            let ok = p.set_by_name(name, *value);
            assert!(ok, "unknown sweep parameter `{name}`");
        }
        p
    }
}

/// A sweep specification (§III-D: `OneWaySweep` / `TwoWaySweep`).
#[derive(Clone, Debug)]
pub struct Sweep {
    /// Human-readable experiment title.
    pub title: String,
    pub points: Vec<SweepPoint>,
    pub replications: usize,
    pub master_seed: u64,
    /// Common random numbers: replication `r` uses the *same* stream at
    /// every point (variance reduction for point-to-point differences).
    /// Off by default: independent streams per (point, replication).
    pub crn: bool,
    /// Named policy selection applied at every point (defaults to the
    /// paper's policies). Policy axes sweep alongside numeric ones.
    pub policies: PolicySpec,
}

impl Sweep {
    /// Vary one parameter (the paper's
    /// `OneWaySweep("...", "name", [v...])`).
    pub fn one_way(
        title: &str,
        name: &str,
        values: &[f64],
        replications: usize,
        master_seed: u64,
    ) -> Sweep {
        Sweep {
            title: title.to_string(),
            points: values
                .iter()
                .map(|&v| SweepPoint { overrides: vec![(name.to_string(), v)] })
                .collect(),
            replications,
            master_seed,
            crn: false,
            policies: PolicySpec::default(),
        }
    }

    /// Enable common random numbers across points.
    pub fn with_crn(mut self) -> Self {
        self.crn = true;
        self
    }

    /// Run every point under the given named policies.
    pub fn with_policies(mut self, policies: PolicySpec) -> Self {
        self.policies = policies;
        self
    }

    /// Vary two parameters over their cross product (x-major order).
    pub fn two_way(
        title: &str,
        x_name: &str,
        x_values: &[f64],
        y_name: &str,
        y_values: &[f64],
        replications: usize,
        master_seed: u64,
    ) -> Sweep {
        let mut points = Vec::new();
        for &x in x_values {
            for &y in y_values {
                points.push(SweepPoint {
                    overrides: vec![
                        (x_name.to_string(), x),
                        (y_name.to_string(), y),
                    ],
                });
            }
        }
        Sweep {
            title: title.to_string(),
            points,
            replications,
            master_seed,
            crn: false,
            policies: PolicySpec::default(),
        }
    }
}

/// Parse a config document's optional `policies:` section into a spec:
///
/// ```yaml
/// policies:
///   selection: locality
///   repair: job_first
/// ```
pub fn policies_from_doc(doc: &crate::config::yaml::Value) -> Result<PolicySpec, String> {
    let mut spec = PolicySpec::default();
    if let Some(section) = doc.get("policies") {
        let map = section.as_map().ok_or("`policies:` must be a map")?;
        for (axis, v) in map {
            let value = v
                .as_str()
                .ok_or_else(|| format!("policies.{axis} must be a name"))?;
            spec.set(axis, value)?;
        }
    }
    Ok(spec)
}

/// Build a sweep from a parsed config document's `sweep:` section
/// (§III-D's experiment files):
///
/// ```yaml
/// sweep:
///   kind: two_way            # or one_way
///   x: { name: recovery_time, values: [10, 20, 30] }
///   y: { name: working_pool, values: [4112, 4128, 4160, 4192] }
/// replications: 30
/// seed: 42
/// ```
pub fn sweep_from_doc(
    doc: &crate::config::yaml::Value,
    default_reps: usize,
    default_seed: u64,
) -> Result<Sweep, String> {
    let sweep = doc.get("sweep").ok_or("no `sweep:` section")?;
    let reps = doc
        .get("replications")
        .and_then(|v| v.as_f64())
        .map(|v| v as usize)
        .unwrap_or(default_reps);
    let seed = doc
        .get("seed")
        .and_then(|v| v.as_f64())
        .map(|v| v as u64)
        .unwrap_or(default_seed);
    let axis = |key: &str| -> Result<(String, Vec<f64>), String> {
        let a = sweep.get(key).ok_or_else(|| format!("sweep.{key} missing"))?;
        let name = a
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("sweep.{key}.name missing"))?;
        let values = a
            .get("values")
            .and_then(|v| v.as_f64_list())
            .ok_or_else(|| format!("sweep.{key}.values missing"))?;
        Ok((name.to_string(), values))
    };
    // NOTE: the doc's `policies:` section is deliberately NOT attached
    // here — policy resolution (doc section + CLI overrides + build
    // validation) has one owner per entry point, which then calls
    // [`Sweep::with_policies`]. See `policies_from_doc`.
    let kind = sweep.get("kind").and_then(|v| v.as_str()).unwrap_or("one_way");
    match kind {
        "one_way" => {
            let (name, values) = axis("x")?;
            Ok(Sweep::one_way(&name.clone(), &name, &values, reps, seed))
        }
        "two_way" => {
            let (xn, xv) = axis("x")?;
            let (yn, yv) = axis("y")?;
            Ok(Sweep::two_way(&format!("{xn} x {yn}"), &xn, &xv, &yn, &yv, reps, seed))
        }
        other => Err(format!("unknown sweep kind `{other}`")),
    }
}

/// Results of one sweep point across replications.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: SweepPoint,
    pub collector: Collector,
}

impl PointResult {
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        self.collector.summary(metric)
    }
}

/// Full sweep results, in point order.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub title: String,
    pub points: Vec<PointResult>,
}

/// Push one run's outputs into a metric collector.
pub fn collect_outputs(c: &mut Collector, p: &Params, o: &RunOutputs) {
    c.push("makespan", o.makespan);
    c.push("makespan_hours", o.makespan / 60.0);
    c.push("completed", if o.completed { 1.0 } else { 0.0 });
    c.push("failures_total", o.failures_total as f64);
    c.push("failures_random", o.failures_random as f64);
    c.push("failures_systematic", o.failures_systematic as f64);
    c.push("preemptions", o.preemptions as f64);
    c.push("preemption_cost", o.preemption_cost);
    c.push("repairs_auto", o.repairs_auto as f64);
    c.push("repairs_manual", o.repairs_manual as f64);
    c.push("avg_run_duration", o.avg_run_duration);
    c.push("host_selections", o.host_selections as f64);
    c.push("standby_swaps", o.standby_swaps as f64);
    c.push("stall_time", o.stall_time);
    c.push("recovery_total", o.recovery_total);
    c.push("retirements", o.retirements as f64);
    c.push("undiagnosed", o.undiagnosed as f64);
    c.push("wrong_diagnoses", o.wrong_diagnoses as f64);
    c.push("work_lost", o.work_lost);
    c.push("utilization", o.utilization(p.job_len));
    c.push("events_delivered", o.events_delivered as f64);
}

/// Run one replication of one point on a (reusable) runner.
fn run_one(
    runner: &mut ReplicationRunner,
    base: &Params,
    sweep: &Sweep,
    point_idx: usize,
    rep: usize,
) -> (Params, RunOutputs) {
    let p = sweep.points[point_idx].apply(base);
    // CRN: drop the point index from the stream path so every point sees
    // the same draws at replication `rep`.
    let rng = if sweep.crn {
        Rng::derived(sweep.master_seed, &[u64::MAX, rep as u64])
    } else {
        Rng::derived(sweep.master_seed, &[point_idx as u64, rep as u64])
    };
    let out = runner.run(&p, &sweep.policies, rng);
    (p, out)
}

/// Execute a sweep, parallelizing (point, replication) tasks over
/// `threads` OS threads (0 = available parallelism). Each worker owns one
/// [`ReplicationRunner`], so simulation state is reset — not reallocated —
/// between that worker's replications.
pub fn run_sweep(base: &Params, sweep: &Sweep, threads: usize) -> SweepResult {
    let n_points = sweep.points.len();
    let reps = sweep.replications.max(1);
    let total = n_points * reps;

    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(total.max(1));

    // Work queue: flat task index -> (point, replication).
    let next = AtomicUsize::new(0);
    let collectors: Vec<Mutex<Collector>> =
        (0..n_points).map(|_| Mutex::new(Collector::new())).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut runner = ReplicationRunner::new();
                loop {
                    let task = next.fetch_add(1, Ordering::Relaxed);
                    if task >= total {
                        break;
                    }
                    let point_idx = task / reps;
                    let rep = task % reps;
                    let (p, out) = run_one(&mut runner, base, sweep, point_idx, rep);
                    let mut c = collectors[point_idx].lock().unwrap();
                    collect_outputs(&mut c, &p, &out);
                }
            });
        }
    });

    SweepResult {
        title: sweep.title.clone(),
        points: sweep
            .points
            .iter()
            .cloned()
            .zip(collectors)
            .map(|(point, c)| PointResult { point, collector: c.into_inner().unwrap() })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_way_points() {
        let s = Sweep::one_way("t", "recovery_time", &[10.0, 20.0, 30.0], 5, 1);
        assert_eq!(s.points.len(), 3);
        assert_eq!(s.points[1].overrides, vec![("recovery_time".into(), 20.0)]);
        assert_eq!(s.points[1].label(), "recovery_time=20");
    }

    #[test]
    fn two_way_cross_product() {
        let s = Sweep::two_way("t", "a_x", &[1.0, 2.0], "warm_standbys", &[4.0, 8.0, 16.0], 1, 1);
        assert_eq!(s.points.len(), 6);
        // x-major order.
        assert_eq!(s.points[0].overrides[0].1, 1.0);
        assert_eq!(s.points[0].overrides[1].1, 4.0);
        assert_eq!(s.points[2].overrides[1].1, 16.0);
        assert_eq!(s.points[3].overrides[0].1, 2.0);
    }

    #[test]
    fn apply_overrides() {
        let base = Params::small_test();
        let point = SweepPoint {
            overrides: vec![("recovery_time".into(), 99.0), ("warm_standbys".into(), 2.0)],
        };
        let p = point.apply(&base);
        assert_eq!(p.recovery_time, 99.0);
        assert_eq!(p.warm_standbys, 2);
        assert_eq!(base.recovery_time, 20.0, "base untouched");
    }

    #[test]
    fn sweep_runs_and_is_deterministic() {
        let base = Params::small_test();
        let sweep = Sweep::one_way("det", "recovery_time", &[10.0, 30.0], 4, 7);
        let r1 = run_sweep(&base, &sweep, 2);
        let r2 = run_sweep(&base, &sweep, 4); // thread count must not matter
        assert_eq!(r1.points.len(), 2);
        for (a, b) in r1.points.iter().zip(&r2.points) {
            let sa = a.summary("makespan").unwrap();
            let sb = b.summary("makespan").unwrap();
            assert_eq!(sa.n, 4);
            assert_eq!(sa.mean, sb.mean, "determinism across thread counts");
            assert_eq!(sa.std, sb.std);
        }
    }

    #[test]
    fn policies_section_parses() {
        let doc = crate::config::yaml::parse(
            "policies:\n  selection: locality\n  repair: job_first\n",
        )
        .unwrap();
        let spec = policies_from_doc(&doc).unwrap();
        assert_eq!(spec.selection, "locality");
        assert_eq!(spec.repair, "job_first");
        // No section: defaults.
        let empty = crate::config::yaml::parse("seed: 1\n").unwrap();
        assert_eq!(policies_from_doc(&empty).unwrap(), PolicySpec::default());
        // Bad name: rejected.
        let bad = crate::config::yaml::parse("policies:\n  selection: bogus\n").unwrap();
        assert!(policies_from_doc(&bad).is_err());
    }

    #[test]
    fn non_default_policies_sweep_deterministically() {
        let base = Params::small_test();
        let spec = PolicySpec {
            selection: "locality".into(),
            repair: "job_first".into(),
            checkpoint: "auto".into(),
            failure: "per_server".into(),
        };
        let sweep = Sweep::one_way("pol", "recovery_time", &[10.0, 30.0], 3, 5)
            .with_policies(spec);
        let r1 = run_sweep(&base, &sweep, 1);
        let r2 = run_sweep(&base, &sweep, 3);
        for (a, b) in r1.points.iter().zip(&r2.points) {
            let sa = a.summary("makespan").unwrap();
            let sb = b.summary("makespan").unwrap();
            assert_eq!(sa.n, 3);
            assert_eq!(sa.mean, sb.mean);
        }
    }

    #[test]
    fn recovery_time_monotone_in_small_config() {
        // The paper's Fig 2(a) shape on the small test config.
        let base = Params::small_test();
        let sweep = Sweep::one_way("fig2a-small", "recovery_time", &[5.0, 120.0], 8, 11);
        let r = run_sweep(&base, &sweep, 0);
        let lo = r.points[0].summary("makespan").unwrap().mean;
        let hi = r.points[1].summary("makespan").unwrap().mean;
        assert!(hi > lo, "makespan should grow with recovery time: {lo} vs {hi}");
    }
}
