//! Ambient execution control for the shared worker pools.
//!
//! The serve daemon multiplexes many concurrent requests over worker
//! pools that were designed for one CLI invocation at a time. Rather
//! than thread new parameters through every `run_pool` caller (and
//! perturb the CLI path, which is pinned byte-identical), control
//! travels *ambiently*: [`with`] installs an [`ExecCtrl`] in a
//! thread-local, [`crate::sweep::run_pool`] captures it before spawning
//! workers, and each worker consults it — a fairness [`Gate`] bounding
//! how many of the request's tasks run at once, a cancellation flag the
//! [`crate::model::cluster::ReplicationRunner`] fast-skips on, and a
//! [`WarmHandle`] the fleet/topology builds go through. The CLI never
//! installs anything, so `current()` yields the all-`None` default and
//! every hook is a single branch.

use crate::serve::cache::WarmHandle;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Counting semaphore: the daemon gives every request's pool the same
/// gate, sized to the physical core budget, so N concurrent requests
/// share the machine instead of each spawning a full-width pool.
pub struct Gate {
    slots: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    pub fn new(slots: usize) -> Arc<Gate> {
        Arc::new(Gate { slots: Mutex::new(slots.max(1)), cv: Condvar::new() })
    }

    /// Block until a slot frees, then hold it for the permit's lifetime.
    pub fn acquire(self: &Arc<Self>) -> Permit {
        let mut n = self.slots.lock().expect("gate lock");
        while *n == 0 {
            n = self.cv.wait(n).expect("gate lock");
        }
        *n -= 1;
        Permit { gate: Arc::clone(self) }
    }

    /// Slots free right now (tests assert cancellation releases them).
    pub fn available(&self) -> usize {
        *self.slots.lock().expect("gate lock")
    }
}

/// RAII slot hold; dropping releases the slot and wakes one waiter.
pub struct Permit {
    gate: Arc<Gate>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut n = self.gate.slots.lock().expect("gate lock");
        *n += 1;
        self.gate.cv.notify_one();
    }
}

/// Per-request execution control. `Default` is all-`None`: no gating, no
/// cancellation, cold builds — exactly the standalone CLI behavior.
#[derive(Clone, Default)]
pub struct ExecCtrl {
    pub gate: Option<Arc<Gate>>,
    pub cancel: Option<Arc<AtomicBool>>,
    pub warm: Option<WarmHandle>,
}

impl ExecCtrl {
    pub fn is_cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }
}

thread_local! {
    static CURRENT: RefCell<ExecCtrl> = RefCell::new(ExecCtrl::default());
}

/// Install `ctrl` as this thread's ambient control for the duration of
/// `f`; the previous control is restored on exit (unwinds included).
pub fn with<T>(ctrl: ExecCtrl, f: impl FnOnce() -> T) -> T {
    struct Restore(ExecCtrl);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| *c.borrow_mut() = std::mem::take(&mut self.0));
        }
    }
    let prev = CURRENT.with(|c| std::mem::replace(&mut *c.borrow_mut(), ctrl));
    let _restore = Restore(prev);
    f()
}

/// The ambient control installed on this thread (all-`None` unless a
/// [`with`] frame is active).
pub fn current() -> ExecCtrl {
    CURRENT.with(|c| c.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_inert() {
        let c = current();
        assert!(c.gate.is_none() && c.cancel.is_none() && c.warm.is_none());
        assert!(!c.is_cancelled());
    }

    #[test]
    fn with_scopes_and_restores() {
        let cancel = Arc::new(AtomicBool::new(true));
        let ctrl = ExecCtrl { cancel: Some(Arc::clone(&cancel)), ..ExecCtrl::default() };
        with(ctrl, || {
            assert!(current().is_cancelled());
            // Nested frames shadow and restore.
            with(ExecCtrl::default(), || assert!(!current().is_cancelled()));
            assert!(current().is_cancelled());
        });
        assert!(!current().is_cancelled());
    }

    #[test]
    fn gate_bounds_concurrency_and_permits_release() {
        let gate = Gate::new(2);
        assert_eq!(gate.available(), 2);
        let a = gate.acquire();
        let b = gate.acquire();
        assert_eq!(gate.available(), 0);
        drop(a);
        assert_eq!(gate.available(), 1);
        // A blocked waiter wakes when a permit drops.
        let g2 = Arc::clone(&gate);
        let waiter = std::thread::spawn(move || {
            let _p = g2.acquire();
        });
        drop(b);
        waiter.join().expect("waiter finishes");
        assert_eq!(gate.available(), 2);
    }
}
