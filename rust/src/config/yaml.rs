//! Minimal YAML-subset parser for config files (the offline environment
//! carries no serde). Supports exactly what AIReSim configs need:
//!
//! ```yaml
//! # comment
//! params:
//!   recovery_time: 20          # scalar
//!   manual_repair_time: 2*1440 # arithmetic expressions (+ - * / parens)
//! sweep:
//!   kind: two_way
//!   x: { name: recovery_time, values: [10, 20, 30] }
//!   y: { name: working_pool, values: [4112, 4128, 4160, 4192] }
//! replications: 30
//! seed: 42
//! ```
//!
//! Nested maps, scalars, inline lists `[a, b, c]`, inline maps
//! `{ k: v, ... }`, block sequences of maps (the `children:` form below,
//! which `multi:` study files use), comments, and arithmetic value
//! expressions — the same surface the paper's `Params`/`config.yaml`
//! user files use (§III-D):
//!
//! ```yaml
//! children:
//!   - label: tuned               # block-sequence item: a map whose
//!     params: { recovery_time: 10 }  # entries continue on the lines
//!   - label: baseline            # indented past the dash
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// Parsed YAML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Scalar(String),
    List(Vec<Value>),
    Map(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub enum YamlError {
    Indent(usize),
    KeyValue(usize),
    Unterminated(usize),
    Expr(String),
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            YamlError::Indent(l) => write!(f, "line {l}: bad indentation"),
            YamlError::KeyValue(l) => write!(f, "line {l}: expected `key: value`"),
            YamlError::Unterminated(l) => {
                write!(f, "line {l}: unterminated inline collection")
            }
            YamlError::Expr(e) => write!(f, "expression error: {e}"),
        }
    }
}

impl std::error::Error for YamlError {}

impl Value {
    pub fn as_map(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Map(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(l) => Some(l),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_map()?.get(key)
    }

    /// Scalar as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Scalar(s) => Some(s),
            _ => None,
        }
    }

    /// Scalar as f64, evaluating arithmetic expressions (`2*1440`,
    /// `0.01/(24*60)`).
    pub fn as_f64(&self) -> Option<f64> {
        eval_expr(self.as_str()?).ok()
    }

    /// List of f64s.
    pub fn as_f64_list(&self) -> Option<Vec<f64>> {
        self.as_list()?.iter().map(|v| v.as_f64()).collect()
    }
}

/// Parse a YAML-subset document into a root map.
pub fn parse(text: &str) -> Result<Value, YamlError> {
    let lines: Vec<(usize, usize, String)> = text
        .lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            let no_comment = strip_comment(raw);
            let trimmed = no_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some((i + 1, indent, trimmed.trim_start().to_string()))
        })
        .collect();
    let (v, consumed) = parse_block(&lines, 0, 0)?;
    debug_assert_eq!(consumed, lines.len());
    Ok(v)
}

fn strip_comment(line: &str) -> String {
    // A `#` outside brackets starts a comment.
    let mut depth = 0i32;
    let mut out = String::new();
    for c in line.chars() {
        match c {
            '[' | '{' => depth += 1,
            ']' | '}' => depth -= 1,
            '#' if depth == 0 => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn parse_block(
    lines: &[(usize, usize, String)],
    start: usize,
    indent: usize,
) -> Result<(Value, usize), YamlError> {
    let mut map = BTreeMap::new();
    let mut i = start;
    while i < lines.len() {
        let (lineno, ind, ref content) = lines[i];
        if ind < indent {
            break;
        }
        if ind > indent {
            return Err(YamlError::Indent(lineno));
        }
        if is_seq_item(content) {
            // A sequence item where a map entry belongs (sequences only
            // start as the nested block of a `key:` line).
            return Err(YamlError::KeyValue(lineno));
        }
        let (key, rest) = content
            .split_once(':')
            .ok_or(YamlError::KeyValue(lineno))?;
        let key = key.trim().to_string();
        let rest = rest.trim();
        if rest.is_empty() {
            // Nested block: a map, or a block sequence when the first
            // child line leads with a dash. Sequence items may sit
            // deeper than the key (the usual form) or at the key's own
            // indent (YAML's zero-indent sequence form).
            let next = lines.get(i + 1);
            let seq_indent = next
                .filter(|(_, ci, content)| *ci >= indent && is_seq_item(content))
                .map(|&(_, ci, _)| ci);
            let map_indent =
                next.map(|&(_, ci, _)| ci).filter(|&ci| ci > indent);
            if let Some(ci) = seq_indent {
                let (child, consumed) = parse_list_block(lines, i + 1, ci)?;
                map.insert(key, child);
                i = consumed;
            } else if let Some(ci) = map_indent {
                let (child, consumed) = parse_block(lines, i + 1, ci)?;
                map.insert(key, child);
                i = consumed;
            } else {
                map.insert(key, Value::Scalar(String::new()));
                i += 1;
            }
        } else {
            map.insert(key, parse_inline(rest, lineno)?);
            i += 1;
        }
    }
    Ok((Value::Map(map), i))
}

/// Does this (trimmed) line open a block-sequence item? (`- x`, or a
/// bare `-` is rejected later — a scalar `-5` is still an item.)
fn is_seq_item(content: &str) -> bool {
    content == "-" || content.starts_with("- ")
}

/// Is `s` a `key: value` map entry rather than an inline scalar or
/// collection? (A top-level colon outside brackets, not an inline form.)
fn looks_like_map_entry(s: &str) -> bool {
    if s.starts_with('[') || s.starts_with('{') {
        return false;
    }
    let mut depth = 0i32;
    for c in s.chars() {
        match c {
            '[' | '{' => depth += 1,
            ']' | '}' => depth -= 1,
            ':' if depth == 0 => return true,
            _ => {}
        }
    }
    false
}

/// Parse a block sequence (`- item` lines at one indent level). An item
/// whose dash is followed by a `key: value` entry is a map; its further
/// entries continue on subsequent lines indented past the dash:
///
/// ```yaml
/// - label: a
///   params: { recovery_time: 10 }
/// - label: b
/// ```
fn parse_list_block(
    lines: &[(usize, usize, String)],
    start: usize,
    indent: usize,
) -> Result<(Value, usize), YamlError> {
    let mut items = Vec::new();
    let mut i = start;
    while i < lines.len() {
        let (lineno, ind, ref content) = lines[i];
        if ind < indent {
            break;
        }
        if ind == indent && !is_seq_item(content) {
            // A `key: value` line at the list's own indent ends the
            // sequence (the zero-indent form shares the parent's level).
            break;
        }
        if ind > indent {
            return Err(YamlError::Indent(lineno));
        }
        let rest = content[1..].trim_start();
        if rest.is_empty() {
            return Err(YamlError::KeyValue(lineno));
        }
        if looks_like_map_entry(rest) {
            // The item is a map: its first entry shares the dash's line,
            // the rest follow at the entry's own indent.
            let rest_indent = ind + (content.len() - rest.len());
            let mut item_lines = vec![(lineno, rest_indent, rest.to_string())];
            let mut j = i + 1;
            while j < lines.len() && lines[j].1 > ind {
                item_lines.push(lines[j].clone());
                j += 1;
            }
            let (item, consumed) = parse_block(&item_lines, 0, rest_indent)?;
            if consumed != item_lines.len() {
                // A continuation line indented between the dash and the
                // first entry — parse_block stopped early on it.
                return Err(YamlError::Indent(item_lines[consumed].0));
            }
            items.push(item);
            i = j;
        } else {
            items.push(parse_inline(rest, lineno)?);
            i += 1;
        }
    }
    Ok((Value::List(items), i))
}

fn parse_inline(s: &str, lineno: usize) -> Result<Value, YamlError> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or(YamlError::Unterminated(lineno))?;
        let items = split_top_level(inner);
        let vals = items
            .into_iter()
            .filter(|x| !x.trim().is_empty())
            .map(|x| parse_inline(x.trim(), lineno))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::List(vals));
    }
    if let Some(inner) = s.strip_prefix('{') {
        let inner = inner
            .strip_suffix('}')
            .ok_or(YamlError::Unterminated(lineno))?;
        let mut m = BTreeMap::new();
        for item in split_top_level(inner) {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (k, v) = item.split_once(':').ok_or(YamlError::KeyValue(lineno))?;
            m.insert(k.trim().to_string(), parse_inline(v.trim(), lineno)?);
        }
        return Ok(Value::Map(m));
    }
    Ok(Value::Scalar(s.trim_matches('"').trim_matches('\'').to_string()))
}

/// Split on commas not nested inside brackets.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '[' | '{' => {
                depth += 1;
                cur.push(c);
            }
            ']' | '}' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

// ------------------------------------------------------------------ //
// Arithmetic expression evaluation (Table I writes values like
// `0.01/(24*60)` and `2*1440`).
// ------------------------------------------------------------------ //

/// Evaluate `+ - * /` with parentheses and unary minus.
pub fn eval_expr(s: &str) -> Result<f64, YamlError> {
    let tokens = tokenize(s)?;
    let mut pos = 0;
    let v = parse_sum(&tokens, &mut pos)?;
    if pos != tokens.len() {
        return Err(YamlError::Expr(format!("trailing tokens in `{s}`")));
    }
    Ok(v)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(f64),
    Op(char),
}

fn tokenize(s: &str) -> Result<Vec<Tok>, YamlError> {
    let mut toks = Vec::new();
    let chars: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match c {
            ' ' | '\t' => i += 1,
            '+' | '-' | '*' | '/' | '(' | ')' => {
                toks.push(Tok::Op(c));
                i += 1;
            }
            '0'..='9' | '.' => {
                let start = i;
                while i < chars.len()
                    && (chars[i].is_ascii_digit()
                        || chars[i] == '.'
                        || chars[i] == 'e'
                        || chars[i] == 'E'
                        || ((chars[i] == '-' || chars[i] == '+')
                            && i > start
                            && (chars[i - 1] == 'e' || chars[i - 1] == 'E')))
                {
                    i += 1;
                }
                let txt: String = chars[start..i].iter().collect();
                let n = txt
                    .parse::<f64>()
                    .map_err(|_| YamlError::Expr(format!("bad number `{txt}`")))?;
                toks.push(Tok::Num(n));
            }
            _ => return Err(YamlError::Expr(format!("bad char `{c}` in `{s}`"))),
        }
    }
    Ok(toks)
}

fn parse_sum(t: &[Tok], pos: &mut usize) -> Result<f64, YamlError> {
    let mut v = parse_product(t, pos)?;
    while let Some(Tok::Op(op @ ('+' | '-'))) = t.get(*pos) {
        let op = *op;
        *pos += 1;
        let rhs = parse_product(t, pos)?;
        v = if op == '+' { v + rhs } else { v - rhs };
    }
    Ok(v)
}

fn parse_product(t: &[Tok], pos: &mut usize) -> Result<f64, YamlError> {
    let mut v = parse_atom(t, pos)?;
    while let Some(Tok::Op(op @ ('*' | '/'))) = t.get(*pos) {
        let op = *op;
        *pos += 1;
        let rhs = parse_atom(t, pos)?;
        v = if op == '*' { v * rhs } else { v / rhs };
    }
    Ok(v)
}

fn parse_atom(t: &[Tok], pos: &mut usize) -> Result<f64, YamlError> {
    match t.get(*pos) {
        Some(Tok::Num(n)) => {
            *pos += 1;
            Ok(*n)
        }
        Some(Tok::Op('-')) => {
            *pos += 1;
            Ok(-parse_atom(t, pos)?)
        }
        Some(Tok::Op('(')) => {
            *pos += 1;
            let v = parse_sum(t, pos)?;
            match t.get(*pos) {
                Some(Tok::Op(')')) => {
                    *pos += 1;
                    Ok(v)
                }
                _ => Err(YamlError::Expr("missing `)`".into())),
            }
        }
        other => Err(YamlError::Expr(format!("unexpected token {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_config() {
        let doc = "\
# AIReSim experiment
params:
  recovery_time: 20
  manual_repair_time: 2*1440
sweep:
  kind: two_way
  x: { name: recovery_time, values: [10, 20, 30] }
  y: { name: working_pool, values: [4112, 4128, 4160, 4192] }
replications: 30
seed: 42
";
        let v = parse(doc).unwrap();
        assert_eq!(
            v.get("params").unwrap().get("recovery_time").unwrap().as_f64(),
            Some(20.0)
        );
        assert_eq!(
            v.get("params")
                .unwrap()
                .get("manual_repair_time")
                .unwrap()
                .as_f64(),
            Some(2880.0)
        );
        let sweep = v.get("sweep").unwrap();
        assert_eq!(sweep.get("kind").unwrap().as_str(), Some("two_way"));
        let x = sweep.get("x").unwrap();
        assert_eq!(x.get("name").unwrap().as_str(), Some("recovery_time"));
        assert_eq!(x.get("values").unwrap().as_f64_list(), Some(vec![10.0, 20.0, 30.0]));
        assert_eq!(v.get("replications").unwrap().as_f64(), Some(30.0));
    }

    #[test]
    fn table1_rate_expression() {
        assert!((eval_expr("0.01/(24*60)").unwrap() - 0.01 / 1440.0).abs() < 1e-15);
        assert_eq!(eval_expr("2 * 1440").unwrap(), 2880.0);
        assert_eq!(eval_expr("-(3+4)/2").unwrap(), -3.5);
        assert_eq!(eval_expr("1e-3").unwrap(), 0.001);
        assert_eq!(eval_expr("2.5e2").unwrap(), 250.0);
    }

    #[test]
    fn expr_errors() {
        assert!(eval_expr("2**3").is_err());
        assert!(eval_expr("(1+2").is_err());
        assert!(eval_expr("abc").is_err());
        assert!(eval_expr("1 2").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let v = parse("a: 1 # inline\n\n# full line\nb: 2\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn hash_inside_brackets_is_not_comment() {
        // (No realistic config uses this, but the lexer must not split it.)
        let v = parse("xs: [1, 2, 3]\n").unwrap();
        assert_eq!(v.get("xs").unwrap().as_f64_list(), Some(vec![1.0, 2.0, 3.0]));
    }

    #[test]
    fn bad_indent_rejected() {
        assert!(parse("a:\n    b: 1\n  c: 2\n").is_err());
    }

    #[test]
    fn quoted_strings() {
        let v = parse("name: \"hello world\"\n").unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("hello world"));
    }

    #[test]
    fn block_sequence_of_maps() {
        let v = parse(
            "children:\n\
             \x20 - label: a\n\
             \x20   params: { recovery_time: 10 }\n\
             \x20 - label: b\n\
             seed: 7\n",
        )
        .unwrap();
        let list = v.get("children").unwrap().as_list().unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].get("label").unwrap().as_str(), Some("a"));
        assert_eq!(
            list[0].get("params").unwrap().get("recovery_time").unwrap().as_f64(),
            Some(10.0)
        );
        assert_eq!(list[1].get("label").unwrap().as_str(), Some("b"));
        assert_eq!(v.get("seed").unwrap().as_f64(), Some(7.0), "block after list parses");
    }

    #[test]
    fn zero_indent_block_sequence() {
        // YAML's common zero-indent form: items at the key's own level.
        let v = parse(
            "children:\n- label: a\n  params: { recovery_time: 10 }\n- label: b\nseed: 7\n",
        )
        .unwrap();
        let list = v.get("children").unwrap().as_list().unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(
            list[0].get("params").unwrap().get("recovery_time").unwrap().as_f64(),
            Some(10.0)
        );
        assert_eq!(list[1].get("label").unwrap().as_str(), Some("b"));
        assert_eq!(v.get("seed").unwrap().as_f64(), Some(7.0), "key after the list parses");
    }

    #[test]
    fn block_sequence_of_scalars_and_inline_maps() {
        let v = parse("xs:\n  - 1\n  - 2*3\n  - { k: 4 }\n").unwrap();
        let list = v.get("xs").unwrap().as_list().unwrap();
        assert_eq!(list[0].as_f64(), Some(1.0));
        assert_eq!(list[1].as_f64(), Some(6.0));
        assert_eq!(list[2].get("k").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn bad_block_sequences_rejected() {
        // Bare dash with nothing after it.
        assert!(parse("xs:\n  - a: 1\n  -\n").is_err());
        // Item lines at inconsistent indent.
        assert!(parse("xs:\n  - a: 1\n    - b: 2\n").is_err());
        // Continuation indented between the dash and the first entry.
        assert!(parse("xs:\n  - label: a\n   params: { x: 1 }\n").is_err());
    }
}
