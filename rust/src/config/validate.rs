//! Parameter validation: reject configurations that are ill-formed before
//! the simulation runs (probabilities outside [0,1], zero-sized jobs,
//! pools too small to ever start, …) and build [`Params`] from parsed
//! config files.

use crate::config::params::{DistKind, Params};
use crate::config::yaml::Value;
use std::fmt;

#[derive(Debug)]
pub enum ConfigError {
    Range(&'static str, f64, &'static str),
    Unknown(String),
    BadValue(String),
    Infeasible(u32, u32, u32),
    BadDist(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Range(name, v, why) => {
                write!(f, "parameter `{name}` = {v} is out of range: {why}")
            }
            ConfigError::Unknown(name) => write!(f, "unknown parameter `{name}`"),
            ConfigError::BadValue(name) => write!(f, "bad value for `{name}`"),
            ConfigError::Infeasible(w, s, j) => write!(
                f,
                "infeasible: working_pool ({w}) + spare_pool ({s}) < job_size ({j}); \
                 the job can never start"
            ),
            ConfigError::BadDist(s) => write!(
                f,
                "bad failure_dist `{s}` (expected exponential, weibull:<shape>, \
                 lognormal:<sigma>)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Validate a parameter set.
pub fn validate(p: &Params) -> Result<(), ConfigError> {
    fn prob(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(0.0..=1.0).contains(&v) {
            return Err(ConfigError::Range(name, v, "must be a probability in [0,1]"));
        }
        Ok(())
    }
    fn non_neg(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(v >= 0.0) {
            return Err(ConfigError::Range(name, v, "must be >= 0"));
        }
        Ok(())
    }
    fn pos(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(v > 0.0) {
            return Err(ConfigError::Range(name, v, "must be > 0"));
        }
        Ok(())
    }

    non_neg("random_failure_rate", p.random_failure_rate)?;
    non_neg("systematic_failure_rate", p.systematic_failure_rate)?;
    prob("systematic_fraction", p.systematic_fraction)?;
    pos("job_len", p.job_len)?;
    if p.job_size == 0 {
        return Err(ConfigError::Range("job_size", 0.0, "must be >= 1"));
    }
    if p.num_jobs == 0 {
        return Err(ConfigError::Range("num_jobs", 0.0, "must be >= 1"));
    }
    non_neg("recovery_time", p.recovery_time)?;
    non_neg("host_selection_time", p.host_selection_time)?;
    non_neg("waiting_time", p.waiting_time)?;
    prob("auto_repair_prob", p.auto_repair_prob)?;
    prob("auto_repair_fail_prob", p.auto_repair_fail_prob)?;
    prob("manual_repair_fail_prob", p.manual_repair_fail_prob)?;
    pos("auto_repair_time", p.auto_repair_time)?;
    pos("manual_repair_time", p.manual_repair_time)?;
    prob("diagnosis_prob", p.diagnosis_prob)?;
    prob("diagnosis_uncertainty", p.diagnosis_uncertainty)?;
    non_neg("retirement_window", p.retirement_window)?;
    non_neg("bad_regen_interval", p.bad_regen_interval)?;
    prob("bad_regen_fraction", p.bad_regen_fraction)?;
    non_neg("checkpoint_interval", p.checkpoint_interval)?;
    non_neg("preemption_cost", p.preemption_cost)?;
    pos("max_sim_time", p.max_sim_time)?;

    if let DistKind::Weibull { shape } = p.failure_dist {
        pos("weibull shape", shape)?;
    }
    if let DistKind::LogNormal { sigma } = p.failure_dist {
        pos("lognormal sigma", sigma)?;
    }

    if p.working_pool + p.spare_pool < p.job_size {
        return Err(ConfigError::Infeasible(p.working_pool, p.spare_pool, p.job_size));
    }
    Ok(())
}

/// Parse the dist spec strings the CLI/config accept.
pub fn parse_dist(s: &str) -> Result<DistKind, ConfigError> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("exponential") || s.eq_ignore_ascii_case("exp") {
        return Ok(DistKind::Exponential);
    }
    if let Some(rest) = s.strip_prefix("weibull:") {
        let shape: f64 =
            rest.parse().map_err(|_| ConfigError::BadDist(s.to_string()))?;
        return Ok(DistKind::Weibull { shape });
    }
    if let Some(rest) = s.strip_prefix("lognormal:") {
        let sigma: f64 =
            rest.parse().map_err(|_| ConfigError::BadDist(s.to_string()))?;
        return Ok(DistKind::LogNormal { sigma });
    }
    Err(ConfigError::BadDist(s.to_string()))
}

/// Apply a parsed config document's `params:` section onto defaults.
pub fn params_from_config(doc: &Value) -> Result<Params, ConfigError> {
    let mut p = Params::table1_defaults();
    if let Some(params) = doc.get("params") {
        let map = params
            .as_map()
            .ok_or_else(|| ConfigError::BadValue("params".into()))?;
        for (k, v) in map {
            if k == "failure_dist" {
                let s = v
                    .as_str()
                    .ok_or_else(|| ConfigError::BadValue(k.clone()))?;
                p.failure_dist = parse_dist(s)?;
                continue;
            }
            let val = v
                .as_f64()
                .ok_or_else(|| ConfigError::BadValue(k.clone()))?;
            if !p.set_by_name(k, val) {
                return Err(ConfigError::Unknown(k.clone()));
            }
        }
    }
    validate(&p)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    #[test]
    fn defaults_validate() {
        validate(&Params::table1_defaults()).unwrap();
        validate(&Params::small_test()).unwrap();
    }

    #[test]
    fn bad_probability_rejected() {
        let mut p = Params::table1_defaults();
        p.auto_repair_prob = 1.5;
        assert!(validate(&p).is_err());
        p.auto_repair_prob = -0.1;
        assert!(validate(&p).is_err());
    }

    #[test]
    fn infeasible_pools_rejected() {
        let mut p = Params::table1_defaults();
        p.working_pool = 100;
        p.spare_pool = 10;
        assert!(matches!(validate(&p), Err(ConfigError::Infeasible(..))));
    }

    #[test]
    fn dist_specs() {
        assert_eq!(parse_dist("exponential").unwrap(), DistKind::Exponential);
        assert_eq!(parse_dist("exp").unwrap(), DistKind::Exponential);
        assert_eq!(
            parse_dist("weibull:1.5").unwrap(),
            DistKind::Weibull { shape: 1.5 }
        );
        assert_eq!(
            parse_dist("lognormal:0.8").unwrap(),
            DistKind::LogNormal { sigma: 0.8 }
        );
        assert!(parse_dist("cauchy").is_err());
        assert!(parse_dist("weibull:x").is_err());
    }

    #[test]
    fn config_document_roundtrip() {
        let doc = yaml::parse(
            "params:\n  recovery_time: 30\n  random_failure_rate: 0.01/(24*60)\n  failure_dist: weibull:1.2\n",
        )
        .unwrap();
        let p = params_from_config(&doc).unwrap();
        assert_eq!(p.recovery_time, 30.0);
        assert!((p.random_failure_rate - 0.01 / 1440.0).abs() < 1e-15);
        assert_eq!(p.failure_dist, DistKind::Weibull { shape: 1.2 });
        // Untouched fields keep Table I defaults.
        assert_eq!(p.working_pool, 4160);
    }

    #[test]
    fn unknown_param_rejected() {
        let doc = yaml::parse("params:\n  bogus: 1\n").unwrap();
        assert!(matches!(params_from_config(&doc), Err(ConfigError::Unknown(_))));
    }
}
