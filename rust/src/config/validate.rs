//! Parameter validation: reject configurations that are ill-formed before
//! the simulation runs (probabilities outside [0,1], zero-sized jobs,
//! pools too small to ever start, …) and build [`Params`] from parsed
//! config files.

use crate::config::params::{DistKind, Params, TopologyLevelSpec, TopologySpec};
use crate::config::yaml::Value;
use crate::model::workload::{
    parse_empirical, parse_replay, ArrivalProcess, JobClass, WorkloadSpec,
};
use std::fmt;

#[derive(Debug)]
pub enum ConfigError {
    Range(&'static str, f64, &'static str),
    Unknown(String),
    BadValue(String),
    Infeasible(u32, u32, u32),
    BadDist(String),
    Topology(String),
    Workload(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Range(name, v, why) => {
                write!(f, "parameter `{name}` = {v} is out of range: {why}")
            }
            ConfigError::Unknown(name) => write!(f, "unknown parameter `{name}`"),
            ConfigError::BadValue(name) => write!(f, "bad value for `{name}`"),
            ConfigError::Infeasible(w, s, j) => write!(
                f,
                "infeasible: working_pool ({w}) + spare_pool ({s}) < job_size ({j}); \
                 the job can never start"
            ),
            ConfigError::BadDist(s) => write!(
                f,
                "bad failure_dist `{s}` (expected exponential, weibull:<shape>, \
                 lognormal:<sigma>)"
            ),
            ConfigError::Topology(s) => write!(f, "bad topology: {s}"),
            ConfigError::Workload(s) => write!(f, "bad workload: {s}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Sweepable params whose whole range is already enforced by their `u32`
/// storage type (counts/capacities: any value is meaningful, 0 included —
/// except `num_jobs`/`job_size`, range-checked in [`validate`]) or that are
/// derived views over other params (`systematic_rate_multiplier` writes
/// through to `systematic_failure_rate`). Listed here so `airesim-lint`'s
/// registry pass can prove that *every* sweepable name is consciously
/// covered by validation: a new param must either gain a range check in
/// [`validate`] or be added here — silently skipping validation fails CI.
pub const TYPE_ENFORCED_PARAMS: &[&str] = &[
    "systematic_rate_multiplier",
    "warm_standbys",
    "working_pool",
    "spare_pool",
    "auto_repair_capacity",
    "manual_repair_capacity",
    "retirement_threshold",
];

/// Validate a parameter set.
pub fn validate(p: &Params) -> Result<(), ConfigError> {
    fn prob(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(0.0..=1.0).contains(&v) {
            return Err(ConfigError::Range(name, v, "must be a probability in [0,1]"));
        }
        Ok(())
    }
    fn non_neg(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(v >= 0.0) {
            return Err(ConfigError::Range(name, v, "must be >= 0"));
        }
        Ok(())
    }
    fn pos(name: &'static str, v: f64) -> Result<(), ConfigError> {
        if !(v > 0.0) {
            return Err(ConfigError::Range(name, v, "must be > 0"));
        }
        Ok(())
    }

    non_neg("random_failure_rate", p.random_failure_rate)?;
    non_neg("systematic_failure_rate", p.systematic_failure_rate)?;
    prob("systematic_fraction", p.systematic_fraction)?;
    pos("job_len", p.job_len)?;
    if p.job_size == 0 {
        return Err(ConfigError::Range("job_size", 0.0, "must be >= 1"));
    }
    if p.num_jobs == 0 {
        return Err(ConfigError::Range("num_jobs", 0.0, "must be >= 1"));
    }
    non_neg("recovery_time", p.recovery_time)?;
    non_neg("host_selection_time", p.host_selection_time)?;
    non_neg("waiting_time", p.waiting_time)?;
    prob("auto_repair_prob", p.auto_repair_prob)?;
    prob("auto_repair_fail_prob", p.auto_repair_fail_prob)?;
    prob("manual_repair_fail_prob", p.manual_repair_fail_prob)?;
    pos("auto_repair_time", p.auto_repair_time)?;
    pos("manual_repair_time", p.manual_repair_time)?;
    non_neg("repair_sla_minutes", p.repair_sla_minutes)?;
    prob("repair_pool_high_water", p.repair_pool_high_water)?;
    prob("diagnosis_prob", p.diagnosis_prob)?;
    prob("diagnosis_uncertainty", p.diagnosis_uncertainty)?;
    non_neg("retirement_window", p.retirement_window)?;
    non_neg("selection_history_window", p.selection_history_window)?;
    non_neg("bad_regen_interval", p.bad_regen_interval)?;
    prob("bad_regen_fraction", p.bad_regen_fraction)?;
    non_neg("checkpoint_interval", p.checkpoint_interval)?;
    non_neg("checkpoint_cost", p.checkpoint_cost)?;
    non_neg("checkpoint_tier2_interval", p.checkpoint_tier2_interval)?;
    non_neg("checkpoint_tier2_cost", p.checkpoint_tier2_cost)?;
    non_neg("checkpoint_tier2_restore", p.checkpoint_tier2_restore)?;
    non_neg("checkpoint_cost_per_server", p.checkpoint_cost_per_server)?;
    non_neg("preemption_cost", p.preemption_cost)?;
    pos("max_sim_time", p.max_sim_time)?;

    if let DistKind::Weibull { shape } = p.failure_dist {
        pos("weibull shape", shape)?;
    }
    if let DistKind::LogNormal { sigma } = p.failure_dist {
        pos("lognormal sigma", sigma)?;
    }

    if p.working_pool + p.spare_pool < p.job_size {
        return Err(ConfigError::Infeasible(p.working_pool, p.spare_pool, p.job_size));
    }
    Ok(())
}

/// Parse the dist spec strings the CLI/config accept.
pub fn parse_dist(s: &str) -> Result<DistKind, ConfigError> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("exponential") || s.eq_ignore_ascii_case("exp") {
        return Ok(DistKind::Exponential);
    }
    if let Some(rest) = s.strip_prefix("weibull:") {
        let shape: f64 =
            rest.parse().map_err(|_| ConfigError::BadDist(s.to_string()))?;
        return Ok(DistKind::Weibull { shape });
    }
    if let Some(rest) = s.strip_prefix("lognormal:") {
        let sigma: f64 =
            rest.parse().map_err(|_| ConfigError::BadDist(s.to_string()))?;
        return Ok(DistKind::LogNormal { sigma });
    }
    Err(ConfigError::BadDist(s.to_string()))
}

/// Validate a topology spec: at least one level, no zero-sized domains,
/// non-negative outage rates, unique level names. (A fleet size that does
/// not divide a level's stride is fine — it yields a trailing partial
/// domain, see [`crate::model::topology::Topology`].)
pub fn validate_topology(spec: &TopologySpec) -> Result<(), ConfigError> {
    if spec.levels.is_empty() {
        return Err(ConfigError::Topology("needs at least one level".into()));
    }
    let mut seen = Vec::new();
    for l in &spec.levels {
        if l.name.is_empty() {
            return Err(ConfigError::Topology("level names must be non-empty".into()));
        }
        if seen.contains(&l.name.as_str()) {
            return Err(ConfigError::Topology(format!("duplicate level `{}`", l.name)));
        }
        seen.push(&l.name);
        if l.size == 0 {
            return Err(ConfigError::Topology(format!(
                "level `{}` has size 0 (zero-sized domains)",
                l.name
            )));
        }
        if !(l.outage_rate >= 0.0) {
            return Err(ConfigError::Topology(format!(
                "level `{}` outage_rate {} must be >= 0",
                l.name, l.outage_rate
            )));
        }
    }
    Ok(())
}

/// Parse the `topology:` config block. Two forms:
///
/// ```yaml
/// topology:                     # shorthand: rack (+ optional switch)
///   servers_per_rack: 8
///   racks_per_switch: 4
///   rack_outage_rate: 0.02/1440
///   switch_outage_rate: 0.01/1440
/// ```
///
/// ```yaml
/// topology:                     # general: arbitrary levels, inner first
///   levels: [ { name: rack, size: 8, outage_rate: 0.02/1440 },
///             { name: switch, size: 4, outage_rate: 0.01/1440 } ]
/// ```
pub fn topology_from_config(doc: &Value) -> Result<Option<TopologySpec>, ConfigError> {
    let Some(section) = doc.get("topology") else {
        return Ok(None);
    };
    let map = section
        .as_map()
        .ok_or_else(|| ConfigError::Topology("`topology:` must be a map".into()))?;
    let get_rate = |key: &str| -> Result<f64, ConfigError> {
        match map.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| ConfigError::BadValue(key.into())),
            None => Ok(0.0),
        }
    };
    // Domain sizes must be exact non-negative integers — a silent `as`
    // cast would truncate `8.5` to 8 and saturate `-4` to 0, running a
    // topology that differs from what was written.
    let as_size = |key: &str, v: f64| -> Result<u32, ConfigError> {
        if v.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&v) {
            return Err(ConfigError::Topology(format!(
                "`{key}` = {v} must be a non-negative integer"
            )));
        }
        Ok(v as u32)
    };
    let spec = if let Some(levels) = map.get("levels") {
        for key in map.keys() {
            if key.as_str() != "levels" {
                return Err(ConfigError::Topology(format!(
                    "`{key}` cannot be combined with `levels:`"
                )));
            }
        }
        let list = levels
            .as_list()
            .ok_or_else(|| ConfigError::Topology("`levels:` must be a list".into()))?;
        let mut out = Vec::with_capacity(list.len());
        for item in list {
            if let Some(m) = item.as_map() {
                for key in m.keys() {
                    if !["name", "size", "outage_rate"].contains(&key.as_str()) {
                        return Err(ConfigError::Topology(format!(
                            "unknown level key `{key}` (expected name, size, outage_rate)"
                        )));
                    }
                }
            }
            let name = item
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| ConfigError::Topology("every level needs `name:`".into()))?
                .to_string();
            let size = item
                .get("size")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| ConfigError::Topology(format!("level `{name}` needs `size:`")))?;
            let size = as_size(&format!("{name}.size"), size)?;
            let outage_rate = match item.get("outage_rate") {
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| ConfigError::BadValue(format!("{name}.outage_rate")))?,
                None => 0.0,
            };
            out.push(TopologyLevelSpec { name, size, outage_rate });
        }
        TopologySpec { levels: out }
    } else {
        const KNOWN: &[&str] = &[
            "servers_per_rack",
            "racks_per_switch",
            "rack_outage_rate",
            "switch_outage_rate",
        ];
        for key in map.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(ConfigError::Topology(format!(
                    "unknown key `{key}` (expected levels: or {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let spr = map
            .get("servers_per_rack")
            .ok_or_else(|| ConfigError::Topology("needs `servers_per_rack` (or `levels:`)".into()))?
            .as_f64()
            .ok_or_else(|| ConfigError::BadValue("servers_per_rack".into()))?;
        let mut levels = vec![TopologyLevelSpec {
            name: "rack".into(),
            size: as_size("servers_per_rack", spr)?,
            outage_rate: get_rate("rack_outage_rate")?,
        }];
        if let Some(rps) = map.get("racks_per_switch") {
            let rps = rps
                .as_f64()
                .ok_or_else(|| ConfigError::BadValue("racks_per_switch".into()))?;
            levels.push(TopologyLevelSpec {
                name: "switch".into(),
                size: as_size("racks_per_switch", rps)?,
                outage_rate: get_rate("switch_outage_rate")?,
            });
        } else if map.contains_key("switch_outage_rate") {
            return Err(ConfigError::Topology(
                "switch_outage_rate needs racks_per_switch".into(),
            ));
        }
        TopologySpec { levels }
    };
    validate_topology(&spec)?;
    Ok(Some(spec))
}

/// Parse the `workload:` config block (see [`crate::model::workload`]).
/// Three mutually exclusive arrival sources plus optional job-mix
/// classes:
///
/// ```yaml
/// workload:
///   poisson: { rate: 0.01 }        # arrivals/min
///   # empirical: { file: gaps.txt }  # one inter-arrival per line
///   # replay: { file: run.ndjson }   # a --trace-out capture
///   classes:                       # optional weighted job mix
///     - { weight: 3, job_size: 8, warm_standbys: 1 }
///     - { weight: 1, job_size: 32, job_len: 2880 }
/// ```
///
/// `empirical`/`replay` files are read and parsed here, so errors
/// surface at config load with the offending line named.
pub fn workload_from_config(doc: &Value) -> Result<Option<WorkloadSpec>, ConfigError> {
    let Some(section) = doc.get("workload") else {
        return Ok(None);
    };
    let err = |s: String| ConfigError::Workload(s);
    let map = section
        .as_map()
        .ok_or_else(|| err("`workload:` must be a map".into()))?;
    for key in map.keys() {
        if !["poisson", "empirical", "replay", "classes"].contains(&key.as_str()) {
            return Err(err(format!(
                "unknown key `{key}` (expected poisson, empirical, replay, classes)"
            )));
        }
    }
    let sources = ["poisson", "empirical", "replay"]
        .iter()
        .filter(|k| map.contains_key(**k))
        .count();
    if sources != 1 {
        return Err(err(
            "needs exactly one arrival source: poisson: { rate }, \
             empirical: { file }, or replay: { file }"
                .into(),
        ));
    }
    // A `{ file: ... }` sub-map with unknown-key rejection.
    let file_of = |key: &str| -> Result<String, ConfigError> {
        let sub = map
            .get(key)
            .expect("presence checked")
            .as_map()
            .ok_or_else(|| err(format!("`{key}:` must be a map with `file:`")))?;
        for k in sub.keys() {
            if k != "file" {
                return Err(err(format!("unknown `{key}` key `{k}` (expected file)")));
            }
        }
        let file = sub
            .get("file")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err(format!("`{key}:` needs `file: <path>`")))?;
        Ok(file.to_string())
    };
    let read = |file: &str| -> Result<String, ConfigError> {
        std::fs::read_to_string(file)
            .map_err(|e| err(format!("cannot read `{file}`: {e}")))
    };
    let arrival = if map.contains_key("poisson") {
        let sub = map
            .get("poisson")
            .expect("presence checked")
            .as_map()
            .ok_or_else(|| err("`poisson:` must be a map with `rate:`".into()))?;
        for k in sub.keys() {
            if k != "rate" {
                return Err(err(format!("unknown `poisson` key `{k}` (expected rate)")));
            }
        }
        let rate = sub
            .get("rate")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| err("`poisson:` needs numeric `rate:` (arrivals/min)".into()))?;
        if !rate.is_finite() || rate < 0.0 {
            return Err(err(format!("poisson rate {rate} must be finite and >= 0")));
        }
        ArrivalProcess::Poisson { rate }
    } else if map.contains_key("empirical") {
        let file = file_of("empirical")?;
        let gaps = parse_empirical(&read(&file)?)
            .map_err(|e| err(format!("empirical `{file}`: {e}")))?;
        ArrivalProcess::Empirical { file, gaps }
    } else {
        let file = file_of("replay")?;
        let (arrivals, failures) = parse_replay(&read(&file)?)
            .map_err(|e| err(format!("replay `{file}`: {e}")))?;
        if arrivals.is_empty() {
            return Err(err(format!(
                "replay `{file}` holds no job_arrival events (only workload runs \
                 record them; re-capture with a `workload:` config and --trace-out)"
            )));
        }
        ArrivalProcess::Replay { file, arrivals, failures }
    };
    let mut classes = Vec::new();
    if let Some(list) = map.get("classes") {
        let list = list
            .as_list()
            .ok_or_else(|| err("`classes:` must be a list".into()))?;
        if matches!(arrival, ArrivalProcess::Replay { .. }) {
            return Err(err(
                "`classes:` cannot be combined with replay (the capture already \
                 carries each arrival's resolved shape)"
                    .into(),
            ));
        }
        let as_count = |key: &str, v: f64| -> Result<u32, ConfigError> {
            if v.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&v) {
                return Err(err(format!("`{key}` = {v} must be a non-negative integer")));
            }
            Ok(v as u32)
        };
        for (i, item) in list.iter().enumerate() {
            let m = item
                .as_map()
                .ok_or_else(|| err(format!("class {}: must be a map", i + 1)))?;
            for k in m.keys() {
                if !["weight", "job_size", "job_len", "warm_standbys"].contains(&k.as_str()) {
                    return Err(err(format!(
                        "class {}: unknown key `{k}` (expected weight, job_size, \
                         job_len, warm_standbys)",
                        i + 1
                    )));
                }
            }
            let weight = m
                .get("weight")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| err(format!("class {}: needs numeric `weight:`", i + 1)))?;
            if !weight.is_finite() || weight <= 0.0 {
                return Err(err(format!("class {}: weight {weight} must be > 0", i + 1)));
            }
            let num = |key: &str| -> Result<Option<f64>, ConfigError> {
                match m.get(key) {
                    Some(v) => v
                        .as_f64()
                        .map(Some)
                        .ok_or_else(|| ConfigError::BadValue(format!("class {}: {key}", i + 1))),
                    None => Ok(None),
                }
            };
            let job_size = match num("job_size")? {
                Some(v) => {
                    let v = as_count(&format!("class {} job_size", i + 1), v)?;
                    if v == 0 {
                        return Err(err(format!("class {}: job_size must be >= 1", i + 1)));
                    }
                    Some(v)
                }
                None => None,
            };
            let warm_standbys = match num("warm_standbys")? {
                Some(v) => Some(as_count(&format!("class {} warm_standbys", i + 1), v)?),
                None => None,
            };
            let job_len = match num("job_len")? {
                Some(v) if v > 0.0 && v.is_finite() => Some(v),
                Some(v) => {
                    return Err(err(format!("class {}: job_len {v} must be > 0", i + 1)))
                }
                None => None,
            };
            classes.push(JobClass { weight, job_size, job_len, warm_standbys });
        }
        if classes.is_empty() {
            return Err(err("`classes:` must not be an empty list".into()));
        }
    }
    Ok(Some(WorkloadSpec { arrival, classes }))
}

/// Apply a parsed config document's `params:` section onto defaults.
pub fn params_from_config(doc: &Value) -> Result<Params, ConfigError> {
    let mut p = Params::table1_defaults();
    if let Some(params) = doc.get("params") {
        let map = params
            .as_map()
            .ok_or_else(|| ConfigError::BadValue("params".into()))?;
        for (k, v) in map {
            if k == "failure_dist" {
                let s = v
                    .as_str()
                    .ok_or_else(|| ConfigError::BadValue(k.clone()))?;
                p.failure_dist = parse_dist(s)?;
                continue;
            }
            let val = v
                .as_f64()
                .ok_or_else(|| ConfigError::BadValue(k.clone()))?;
            if !p.set_by_name(k, val) {
                return Err(ConfigError::Unknown(k.clone()));
            }
        }
    }
    p.topology = topology_from_config(doc)?;
    p.workload = workload_from_config(doc)?;
    // A replay's job count is the capture's, not `num_jobs`: keep them in
    // sync so the engine's plan/job-table sizes (and per-job policy
    // state) always agree.
    if let Some(ArrivalProcess::Replay { arrivals, .. }) =
        p.workload.as_ref().map(|w| &w.arrival)
    {
        p.num_jobs = arrivals.len() as u32;
    }
    // Replay re-schedules the *recorded* failures; live stochastic clocks
    // would fire extra failures on top and the replayed timeline would
    // diverge from the capture. Like `scenario: inject` studies, the
    // silencing is config-level: the rates must be written as 0.
    if p.workload.as_ref().is_some_and(|w| w.is_replay())
        && (p.random_failure_rate > 0.0 || p.systematic_failure_rate > 0.0)
    {
        return Err(ConfigError::Workload(
            "replay requires the stochastic failure clocks silenced: set \
             random_failure_rate: 0 and systematic_failure_rate: 0 (the \
             capture's failures are re-injected verbatim)"
                .into(),
        ));
    }
    validate(&p)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    #[test]
    fn defaults_validate() {
        validate(&Params::table1_defaults()).unwrap();
        validate(&Params::small_test()).unwrap();
    }

    #[test]
    fn bad_probability_rejected() {
        let mut p = Params::table1_defaults();
        p.auto_repair_prob = 1.5;
        assert!(validate(&p).is_err());
        p.auto_repair_prob = -0.1;
        assert!(validate(&p).is_err());
    }

    #[test]
    fn infeasible_pools_rejected() {
        let mut p = Params::table1_defaults();
        p.working_pool = 100;
        p.spare_pool = 10;
        assert!(matches!(validate(&p), Err(ConfigError::Infeasible(..))));
    }

    #[test]
    fn dist_specs() {
        assert_eq!(parse_dist("exponential").unwrap(), DistKind::Exponential);
        assert_eq!(parse_dist("exp").unwrap(), DistKind::Exponential);
        assert_eq!(
            parse_dist("weibull:1.5").unwrap(),
            DistKind::Weibull { shape: 1.5 }
        );
        assert_eq!(
            parse_dist("lognormal:0.8").unwrap(),
            DistKind::LogNormal { sigma: 0.8 }
        );
        assert!(parse_dist("cauchy").is_err());
        assert!(parse_dist("weibull:x").is_err());
    }

    #[test]
    fn config_document_roundtrip() {
        let doc = yaml::parse(
            "params:\n  recovery_time: 30\n  random_failure_rate: 0.01/(24*60)\n  failure_dist: weibull:1.2\n",
        )
        .unwrap();
        let p = params_from_config(&doc).unwrap();
        assert_eq!(p.recovery_time, 30.0);
        assert!((p.random_failure_rate - 0.01 / 1440.0).abs() < 1e-15);
        assert_eq!(p.failure_dist, DistKind::Weibull { shape: 1.2 });
        // Untouched fields keep Table I defaults.
        assert_eq!(p.working_pool, 4160);
    }

    #[test]
    fn unknown_param_rejected() {
        let doc = yaml::parse("params:\n  bogus: 1\n").unwrap();
        assert!(matches!(params_from_config(&doc), Err(ConfigError::Unknown(_))));
    }

    #[test]
    fn topology_shorthand_parses() {
        let doc = yaml::parse(
            "topology:\n  servers_per_rack: 8\n  racks_per_switch: 4\n  switch_outage_rate: 0.01/1440\n",
        )
        .unwrap();
        let p = params_from_config(&doc).unwrap();
        let t = p.topology.expect("topology parsed");
        assert_eq!(t.levels.len(), 2);
        assert_eq!(
            t.levels[0],
            TopologyLevelSpec { name: "rack".into(), size: 8, outage_rate: 0.0 }
        );
        assert_eq!(t.levels[1].name, "switch");
        assert_eq!(t.levels[1].size, 4);
        assert!((t.levels[1].outage_rate - 0.01 / 1440.0).abs() < 1e-15);
        assert!(t.has_outages());
    }

    #[test]
    fn topology_levels_form_parses() {
        let doc = yaml::parse(
            "topology:\n  levels: [ { name: rack, size: 8 }, { name: pod, size: 16, outage_rate: 1e-5 } ]\n",
        )
        .unwrap();
        let t = topology_from_config(&doc).unwrap().unwrap();
        assert_eq!(t.levels.len(), 2);
        assert_eq!(t.levels[0].outage_rate, 0.0);
        assert_eq!(t.levels[1].name, "pod");
        assert_eq!(t.levels[1].outage_rate, 1e-5);
    }

    #[test]
    fn topology_zero_sized_domains_rejected() {
        let doc = yaml::parse("topology:\n  servers_per_rack: 0\n").unwrap();
        assert!(matches!(topology_from_config(&doc), Err(ConfigError::Topology(_))));
        let doc =
            yaml::parse("topology:\n  levels: [ { name: rack, size: 0 } ]\n").unwrap();
        assert!(matches!(topology_from_config(&doc), Err(ConfigError::Topology(_))));
    }

    #[test]
    fn topology_bad_shapes_rejected() {
        // Unknown shorthand key.
        let doc = yaml::parse("topology:\n  servers_per_pod: 8\n").unwrap();
        assert!(topology_from_config(&doc).is_err());
        // levels + shorthand mixed.
        let doc = yaml::parse(
            "topology:\n  servers_per_rack: 8\n  levels: [ { name: rack, size: 8 } ]\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
        // Switch rate without a switch level.
        let doc = yaml::parse(
            "topology:\n  servers_per_rack: 8\n  switch_outage_rate: 0.1\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
        // Duplicate level names.
        let doc = yaml::parse(
            "topology:\n  levels: [ { name: rack, size: 8 }, { name: rack, size: 4 } ]\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
        // Negative rate.
        let doc = yaml::parse(
            "topology:\n  servers_per_rack: 8\n  rack_outage_rate: -1\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
        // Typoed level key (a silent default here would disarm outages).
        let doc = yaml::parse(
            "topology:\n  levels: [ { name: rack, size: 8, outage_rte: 0.1 } ]\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
        // Fractional / negative sizes are rejected, not truncated.
        let doc = yaml::parse("topology:\n  servers_per_rack: 17/2\n").unwrap();
        assert!(topology_from_config(&doc).is_err());
        let doc = yaml::parse(
            "topology:\n  servers_per_rack: 8\n  racks_per_switch: -4\n",
        )
        .unwrap();
        assert!(topology_from_config(&doc).is_err());
    }

    #[test]
    fn no_topology_block_stays_none() {
        let doc = yaml::parse("params:\n  recovery_time: 30\n").unwrap();
        let p = params_from_config(&doc).unwrap();
        assert!(p.topology.is_none());
    }

    /// A scratch file in the OS temp dir, deleted on drop.
    struct TempFile(std::path::PathBuf);

    impl TempFile {
        fn new(name: &str, contents: &str) -> TempFile {
            let path = std::env::temp_dir().join(format!(
                "airesim_validate_{}_{name}",
                std::process::id()
            ));
            std::fs::write(&path, contents).unwrap();
            TempFile(path)
        }

        fn path(&self) -> &str {
            self.0.to_str().unwrap()
        }
    }

    impl Drop for TempFile {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    #[test]
    fn no_workload_block_stays_none() {
        let doc = yaml::parse("params:\n  recovery_time: 30\n").unwrap();
        assert!(params_from_config(&doc).unwrap().workload.is_none());
    }

    #[test]
    fn workload_poisson_with_classes_parses() {
        let doc = yaml::parse(
            "workload:\n  poisson: { rate: 0.01 }\n  classes:\n    - { weight: 3, job_size: 8, warm_standbys: 1 }\n    - { weight: 1, job_size: 32, job_len: 2880 }\n",
        )
        .unwrap();
        let w = workload_from_config(&doc).unwrap().unwrap();
        assert_eq!(w.arrival, ArrivalProcess::Poisson { rate: 0.01 });
        assert_eq!(w.classes.len(), 2);
        assert_eq!(
            w.classes[0],
            JobClass { weight: 3.0, job_size: Some(8), job_len: None, warm_standbys: Some(1) }
        );
        assert_eq!(w.classes[1].job_len, Some(2880.0));
        assert!(!w.is_replay());
    }

    #[test]
    fn workload_empirical_reads_the_file() {
        let f = TempFile::new("gaps.txt", "# gaps\n10\n20\n");
        let doc = yaml::parse(&format!("workload:\n  empirical: {{ file: {} }}\n", f.path()))
            .unwrap();
        let w = workload_from_config(&doc).unwrap().unwrap();
        match w.arrival {
            ArrivalProcess::Empirical { gaps, .. } => assert_eq!(gaps, vec![10.0, 20.0]),
            other => panic!("{other:?}"),
        }
        // A parse error surfaces at config load, naming the line.
        let bad = TempFile::new("bad_gaps.txt", "10\nbogus\n");
        let doc = yaml::parse(&format!("workload:\n  empirical: {{ file: {} }}\n", bad.path()))
            .unwrap();
        let e = workload_from_config(&doc).unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn workload_replay_parses_and_requires_silenced_clocks() {
        let f = TempFile::new(
            "replay.ndjson",
            concat!(
                r#"{"type":"event","at":5,"event":"job_arrival","job":0,"size":0,"len":1440,"standbys":0}"#,
                "\n",
                r#"{"type":"event","at":9,"event":"failure","server":3,"systematic":false}"#,
                "\n",
            ),
        );
        let yaml_for = |rates: &str| {
            format!("params:\n{rates}workload:\n  replay: {{ file: {} }}\n", f.path())
        };
        // Live stochastic rates alongside replay: rejected.
        let doc = yaml::parse(&yaml_for("  num_jobs: 1\n")).unwrap();
        let e = params_from_config(&doc).unwrap_err().to_string();
        assert!(e.contains("random_failure_rate"), "{e}");
        // Zeroed rates: parses, and the capture's events are lifted.
        let doc = yaml::parse(&yaml_for(
            "  num_jobs: 1\n  random_failure_rate: 0\n  systematic_failure_rate: 0\n",
        ))
        .unwrap();
        let p = params_from_config(&doc).unwrap();
        let w = p.workload.unwrap();
        assert!(w.is_replay());
        assert_eq!(w.replay_failures().len(), 1);
    }

    #[test]
    fn workload_bad_shapes_rejected() {
        let cases = [
            // No arrival source.
            "workload:\n  classes: [ { weight: 1 } ]\n",
            // Two sources.
            "workload:\n  poisson: { rate: 1 }\n  empirical: { file: x }\n",
            // Unknown top-level key.
            "workload:\n  poison: { rate: 1 }\n",
            // Unknown poisson key.
            "workload:\n  poisson: { rte: 1 }\n",
            // Negative rate.
            "workload:\n  poisson: { rate: -1 }\n",
            // Missing file.
            "workload:\n  empirical: { }\n",
            // Unreadable file.
            "workload:\n  empirical: { file: /nonexistent/gaps.txt }\n",
            // Class without weight.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { job_size: 8 } ]\n",
            // Zero weight.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { weight: 0 } ]\n",
            // Fractional job_size.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { weight: 1, job_size: 8.5 } ]\n",
            // Zero job_size.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { weight: 1, job_size: 0 } ]\n",
            // Non-positive job_len.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { weight: 1, job_len: 0 } ]\n",
            // Unknown class key.
            "workload:\n  poisson: { rate: 1 }\n  classes: [ { weight: 1, jobsize: 8 } ]\n",
        ];
        for yaml_text in cases {
            let doc = yaml::parse(yaml_text).unwrap();
            assert!(workload_from_config(&doc).is_err(), "accepted: {yaml_text}");
        }
    }
}
