//! Configuration: the paper's input-parameter set (§III-B, Table I),
//! YAML-subset config files, and validation.

pub mod params;
pub mod validate;
pub mod yaml;

pub use params::{DistKind, Params, TopologyLevelSpec, TopologySpec};
