//! The simulation parameter set — a superset of the paper's §III-B inputs
//! with Table I defaults, plus the extension knobs the paper names in the
//! text (retirement scoring, bad-server regeneration, preemption cost,
//! repair-shop capacity).
//!
//! All times are in **minutes**, all rates in **1/minute**, matching
//! Table I (failure rates there are written per-day and divided by 24*60).

use crate::sim::dist::Dist;
use crate::sim::MIN_PER_DAY;

/// Failure inter-arrival distribution family (assumption 2: Exponential by
/// default; LogNormal and Weibull also supported).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DistKind {
    Exponential,
    /// Weibull with the given shape; the scale is chosen so the mean equals
    /// the configured 1/rate.
    Weibull { shape: f64 },
    /// LogNormal with the given sigma of the underlying normal; mu chosen
    /// so the mean equals the configured 1/rate.
    LogNormal { sigma: f64 },
}

impl DistKind {
    /// Build a duration distribution with mean `1/rate` in this family.
    /// `rate == 0` yields a never-firing clock.
    pub fn with_rate(self, rate: f64) -> Dist {
        if rate <= 0.0 {
            return Dist::exp_rate(0.0);
        }
        let mean = 1.0 / rate;
        match self {
            DistKind::Exponential => Dist::exp_rate(rate),
            DistKind::Weibull { shape } => {
                // mean = scale * Gamma(1 + 1/shape)
                let scale = mean / crate::sim::dist::gamma(1.0 + 1.0 / shape);
                Dist::Weibull { shape, scale }
            }
            DistKind::LogNormal { sigma } => {
                // mean = exp(mu + sigma^2/2)
                let mu = mean.ln() - sigma * sigma / 2.0;
                Dist::LogNormal { mu, sigma }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DistKind::Exponential => "exponential",
            DistKind::Weibull { .. } => "weibull",
            DistKind::LogNormal { .. } => "lognormal",
        }
    }
}

/// One failure-domain level of the cluster topology (declarative form).
///
/// `size` counts *units of the previous level* per domain — servers for
/// the first level, previous-level domains for every level above it. A
/// fleet whose size does not divide evenly gets a trailing partial
/// domain at every level.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologyLevelSpec {
    /// Level name (`rack`, `switch`, `pod`, …) — labels trace events.
    pub name: String,
    /// Units of the previous level per domain (>= 1).
    pub size: u32,
    /// Outage rate of *one* domain at this level, 1/min (0 = never).
    pub outage_rate: f64,
}

/// Declarative failure-domain hierarchy over the fleet (the `topology:`
/// config block). Server ids are assigned domain-contiguously, so every
/// domain is a contiguous id range; [`crate::model::topology::Topology`]
/// is the concrete per-fleet form. `None` on [`Params`] keeps every
/// legacy behavior byte-identical.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TopologySpec {
    /// Innermost level first (e.g. rack, then switch).
    pub levels: Vec<TopologyLevelSpec>,
}

impl TopologySpec {
    /// Does any level carry a positive outage rate? (Drives the `auto`
    /// failure-model resolution: outage rates imply correlated clocks.)
    pub fn has_outages(&self) -> bool {
        self.levels.iter().any(|l| l.outage_rate > 0.0)
    }
}

/// Full simulation parameter set. Construct via [`Params::table1_defaults`]
/// and override fields, or load from YAML via [`crate::config::yaml`].
#[derive(Clone, Debug)]
pub struct Params {
    // ---- failure model (inputs 1–2) ----
    /// Random failure rate per server, 1/min (Table I: 0.01/day).
    pub random_failure_rate: f64,
    /// *Additional* systematic failure rate on bad servers, 1/min
    /// (Table I: 5× the random rate).
    pub systematic_failure_rate: f64,
    /// Fraction of servers that are "bad" (systematic-prone), input 2.
    pub systematic_fraction: f64,
    /// Failure inter-arrival family (assumption 2).
    pub failure_dist: DistKind,

    // ---- job (inputs 4–6) ----
    /// Concurrent identical jobs (assumption 6 lifts to >1; default 1).
    /// All jobs share the working/spare pools and the repair shop.
    pub num_jobs: u32,
    /// Servers each job needs to run (input 4; Table I: 4096).
    pub job_size: u32,
    /// Failure-free job length in minutes (input 5; Table I: 256 days).
    pub job_len: f64,
    /// Warm standbys allotted on top of `job_size` (input 6; Table I: 16).
    pub warm_standbys: u32,

    // ---- recovery & scheduling (inputs 3, Table I rows 4/6/7) ----
    /// Checkpoint-restore recovery time after a failure, minutes (input 3).
    pub recovery_time: f64,
    /// Host-selection + job-restart time when standbys are exhausted.
    pub host_selection_time: f64,
    /// Spare-pool preemption wait (Table I "Waiting Time").
    pub waiting_time: f64,

    // ---- pools (inputs 7–8) ----
    /// Working-pool size (Table I: 4160).
    pub working_pool: u32,
    /// Spare-pool size (Table I: 200).
    pub spare_pool: u32,

    // ---- repair pipeline (inputs 9–11) ----
    /// P(automated repair resolves it — i.e. no escalation to manual).
    pub auto_repair_prob: f64,
    /// P(auto repair silently failed: status says fixed, server stays bad).
    pub auto_repair_fail_prob: f64,
    /// P(manual repair silently failed).
    pub manual_repair_fail_prob: f64,
    /// Mean automated test+repair time, minutes.
    pub auto_repair_time: f64,
    /// Mean manual repair time, minutes.
    pub manual_repair_time: f64,
    /// Concurrent automated-repair capacity; 0 = unlimited (extension:
    /// models a finite repair shop, queueing failed servers).
    pub auto_repair_capacity: u32,
    /// Concurrent manual-repair (technician) capacity; 0 = unlimited.
    pub manual_repair_capacity: u32,
    /// `repair: sla_aged` only — a queued server escalates to the head
    /// of service once it has waited this many minutes (0 = every queued
    /// server is instantly "aged": pure FIFO).
    pub repair_sla_minutes: f64,
    /// `repair: pool_aware` only — the spare-pool high-water mark as a
    /// fraction of `spare_pool` in [0, 1]. While at least this fraction
    /// of the spares sits idle in the pool, repair capacity serves only
    /// servers a job is actively waiting on; pool-bound drain-backs wait.
    /// The policy refuses to build at 0 (it would throttle nothing).
    pub repair_pool_high_water: f64,

    // ---- diagnosis (inputs 12–13) ----
    /// P(the failure is diagnosed and *some* server is identified).
    pub diagnosis_prob: f64,
    /// P(the identified server is the wrong one | diagnosed).
    pub diagnosis_uncertainty: f64,

    // ---- retirement policy (§II-B "server retirement") ----
    /// Retire a server after this many failures inside the window;
    /// 0 disables retirement (the Table I configuration).
    pub retirement_threshold: u32,
    /// Sliding window for the failure score, minutes.
    pub retirement_window: f64,

    // ---- failure-history-aware selection (`selection: history_scored`) ----
    /// Sliding window, in minutes, within which `selection: history_scored`
    /// counts a candidate server's past failures (preferring the cleanest
    /// history). 0 disables history tracking for selection; the policy
    /// itself then refuses to build, naming this knob.
    pub selection_history_window: f64,

    // ---- bad-server regeneration (assumption 1, case 2) ----
    /// Every this many minutes, new bad servers appear (aging / new
    /// hardware); 0 disables regeneration.
    pub bad_regen_interval: f64,
    /// Expected fraction of the fleet converted good→bad per regeneration.
    pub bad_regen_fraction: f64,

    // ---- checkpointing (extension; §I "restarting … from a previous
    // checkpoint") ----
    /// A checkpoint is committed every this many minutes of useful work;
    /// progress past the last checkpoint is lost on failure. 0 = the
    /// paper's continuous asynchronous checkpointing (no loss).
    pub checkpoint_interval: f64,
    /// Wall-clock cost, in minutes, of committing one checkpoint: the
    /// gang stalls this long at every commit. 0 = the legacy free-commit
    /// model (all outputs byte-identical to it). Also the `C` in the
    /// `young_daly`/`adaptive` interval √(2·C·MTBF).
    pub checkpoint_cost: f64,
    /// `checkpoint: tiered` only — interval of the expensive-rare commit
    /// tier, minutes of useful work (the cheap-frequent tier runs on
    /// `checkpoint_interval`/`checkpoint_cost`).
    pub checkpoint_tier2_interval: f64,
    /// Commit cost of the expensive tier, minutes per commit.
    pub checkpoint_tier2_cost: f64,
    /// Restore latency from an expensive-tier checkpoint; <= 0 falls
    /// back to `recovery_time` (which the cheap tier always restores at).
    pub checkpoint_tier2_restore: f64,
    /// Bandwidth-bound commit writes: extra wall minutes per *gang
    /// server* added to `checkpoint_cost` at every commit (effective
    /// cost = `checkpoint_cost + checkpoint_cost_per_server * job_size`).
    /// 0 = the flat-cost model, byte-identical to it. Applies to the
    /// single-tier policies (periodic / young_daly / adaptive); the
    /// tiered policy keeps its explicitly configured per-tier costs.
    pub checkpoint_cost_per_server: f64,

    // ---- preemption cost accounting (assumption 7) ----
    /// Fixed cost, in minutes of other-job work lost, per preempted server.
    pub preemption_cost: f64,

    // ---- simulation control ----
    /// Hard horizon: stop (mark incomplete) if the job hasn't finished.
    pub max_sim_time: f64,

    // ---- topology (failure domains; `topology:` config block) ----
    /// Failure-domain hierarchy over the fleet. `None` (the default, and
    /// the paper's configuration) keeps servers topologically anonymous
    /// and every output byte-identical to the pre-topology simulator.
    pub topology: Option<TopologySpec>,

    // ---- workload (open-loop arrivals; `workload:` config block) ----
    /// Open-loop arrival process and job-mix classes. `None` (the default,
    /// and the paper's configuration) starts all `num_jobs` jobs at t=0
    /// with zero extra RNG draws — byte-identical to the pre-workload
    /// simulator.
    pub workload: Option<crate::model::workload::WorkloadSpec>,
}

impl Params {
    /// The paper's Table I default column.
    pub fn table1_defaults() -> Params {
        let rnd = 0.01 / MIN_PER_DAY;
        Params {
            random_failure_rate: rnd,
            systematic_failure_rate: 5.0 * rnd,
            systematic_fraction: 0.15,
            failure_dist: DistKind::Exponential,
            num_jobs: 1,
            job_size: 4096,
            job_len: 256.0 * MIN_PER_DAY,
            warm_standbys: 16,
            recovery_time: 20.0,
            host_selection_time: 3.0,
            waiting_time: 20.0,
            working_pool: 4160,
            spare_pool: 200,
            auto_repair_prob: 0.80,
            auto_repair_fail_prob: 0.40,
            manual_repair_fail_prob: 0.20,
            auto_repair_time: 120.0,
            manual_repair_time: 2.0 * MIN_PER_DAY,
            auto_repair_capacity: 0,
            manual_repair_capacity: 0,
            repair_sla_minutes: MIN_PER_DAY,
            repair_pool_high_water: 0.0,
            diagnosis_prob: 0.8,
            diagnosis_uncertainty: 0.0,
            retirement_threshold: 0,
            retirement_window: 7.0 * MIN_PER_DAY,
            selection_history_window: 0.0,
            bad_regen_interval: 0.0,
            bad_regen_fraction: 0.0,
            checkpoint_interval: 0.0,
            checkpoint_cost: 0.0,
            checkpoint_tier2_interval: 0.0,
            checkpoint_tier2_cost: 0.0,
            checkpoint_tier2_restore: 0.0,
            checkpoint_cost_per_server: 0.0,
            preemption_cost: 0.0,
            max_sim_time: 10.0 * 256.0 * MIN_PER_DAY,
            topology: None,
            workload: None,
        }
    }

    /// A small configuration for fast tests: 64-server job, 1-day length.
    pub fn small_test() -> Params {
        let rnd = 0.5 / MIN_PER_DAY;
        Params {
            random_failure_rate: rnd,
            systematic_failure_rate: 5.0 * rnd,
            systematic_fraction: 0.15,
            failure_dist: DistKind::Exponential,
            num_jobs: 1,
            job_size: 64,
            job_len: 1.0 * MIN_PER_DAY,
            warm_standbys: 4,
            recovery_time: 20.0,
            host_selection_time: 3.0,
            waiting_time: 20.0,
            working_pool: 72,
            spare_pool: 16,
            auto_repair_prob: 0.80,
            auto_repair_fail_prob: 0.40,
            manual_repair_fail_prob: 0.20,
            auto_repair_time: 120.0,
            manual_repair_time: 2.0 * MIN_PER_DAY,
            auto_repair_capacity: 0,
            manual_repair_capacity: 0,
            repair_sla_minutes: MIN_PER_DAY,
            repair_pool_high_water: 0.0,
            diagnosis_prob: 0.8,
            diagnosis_uncertainty: 0.0,
            retirement_threshold: 0,
            retirement_window: 7.0 * MIN_PER_DAY,
            selection_history_window: 0.0,
            bad_regen_interval: 0.0,
            bad_regen_fraction: 0.0,
            checkpoint_interval: 0.0,
            checkpoint_cost: 0.0,
            checkpoint_tier2_interval: 0.0,
            checkpoint_tier2_cost: 0.0,
            checkpoint_tier2_restore: 0.0,
            checkpoint_cost_per_server: 0.0,
            preemption_cost: 0.0,
            max_sim_time: 100.0 * MIN_PER_DAY,
            topology: None,
            workload: None,
        }
    }

    /// Total fleet size (working + spare pools).
    pub fn total_servers(&self) -> u32 {
        self.working_pool + self.spare_pool
    }

    /// Set a parameter by its sweep name (the strings Table I uses; also
    /// the names `OneWaySweep`/`TwoWaySweep` accept). Returns false for an
    /// unknown name.
    pub fn set_by_name(&mut self, name: &str, value: f64) -> bool {
        match name {
            "random_failure_rate" => self.random_failure_rate = value,
            "systematic_failure_rate" => self.systematic_failure_rate = value,
            // Convenience: Table I expresses the systematic rate as a
            // multiple of the random rate.
            "systematic_rate_multiplier" => {
                self.systematic_failure_rate = value * self.random_failure_rate
            }
            "systematic_fraction" => self.systematic_fraction = value,
            "num_jobs" => self.num_jobs = value as u32,
            "job_size" => self.job_size = value as u32,
            "job_len" => self.job_len = value,
            "warm_standbys" => self.warm_standbys = value as u32,
            "recovery_time" => self.recovery_time = value,
            "host_selection_time" => self.host_selection_time = value,
            "waiting_time" => self.waiting_time = value,
            "working_pool" => self.working_pool = value as u32,
            "spare_pool" => self.spare_pool = value as u32,
            "auto_repair_prob" => self.auto_repair_prob = value,
            "auto_repair_fail_prob" => self.auto_repair_fail_prob = value,
            "manual_repair_fail_prob" => self.manual_repair_fail_prob = value,
            "auto_repair_time" => self.auto_repair_time = value,
            "manual_repair_time" => self.manual_repair_time = value,
            "auto_repair_capacity" => self.auto_repair_capacity = value as u32,
            "manual_repair_capacity" => self.manual_repair_capacity = value as u32,
            "repair_sla_minutes" => self.repair_sla_minutes = value,
            "repair_pool_high_water" => self.repair_pool_high_water = value,
            "diagnosis_prob" => self.diagnosis_prob = value,
            "diagnosis_uncertainty" => self.diagnosis_uncertainty = value,
            "retirement_threshold" => self.retirement_threshold = value as u32,
            "retirement_window" => self.retirement_window = value,
            "selection_history_window" => self.selection_history_window = value,
            "bad_regen_interval" => self.bad_regen_interval = value,
            "bad_regen_fraction" => self.bad_regen_fraction = value,
            "checkpoint_interval" => self.checkpoint_interval = value,
            "checkpoint_cost" => self.checkpoint_cost = value,
            "checkpoint_tier2_interval" => self.checkpoint_tier2_interval = value,
            "checkpoint_tier2_cost" => self.checkpoint_tier2_cost = value,
            "checkpoint_tier2_restore" => self.checkpoint_tier2_restore = value,
            "checkpoint_cost_per_server" => self.checkpoint_cost_per_server = value,
            "preemption_cost" => self.preemption_cost = value,
            "max_sim_time" => self.max_sim_time = value,
            _ => return false,
        }
        true
    }

    /// Read a parameter by sweep name (for report labelling).
    pub fn get_by_name(&self, name: &str) -> Option<f64> {
        Some(match name {
            "random_failure_rate" => self.random_failure_rate,
            "systematic_failure_rate" => self.systematic_failure_rate,
            "systematic_rate_multiplier" => {
                self.systematic_failure_rate / self.random_failure_rate
            }
            "systematic_fraction" => self.systematic_fraction,
            "num_jobs" => self.num_jobs as f64,
            "job_size" => self.job_size as f64,
            "job_len" => self.job_len,
            "warm_standbys" => self.warm_standbys as f64,
            "recovery_time" => self.recovery_time,
            "host_selection_time" => self.host_selection_time,
            "waiting_time" => self.waiting_time,
            "working_pool" => self.working_pool as f64,
            "spare_pool" => self.spare_pool as f64,
            "auto_repair_prob" => self.auto_repair_prob,
            "auto_repair_fail_prob" => self.auto_repair_fail_prob,
            "manual_repair_fail_prob" => self.manual_repair_fail_prob,
            "auto_repair_time" => self.auto_repair_time,
            "manual_repair_time" => self.manual_repair_time,
            "auto_repair_capacity" => self.auto_repair_capacity as f64,
            "manual_repair_capacity" => self.manual_repair_capacity as f64,
            "repair_sla_minutes" => self.repair_sla_minutes,
            "repair_pool_high_water" => self.repair_pool_high_water,
            "diagnosis_prob" => self.diagnosis_prob,
            "diagnosis_uncertainty" => self.diagnosis_uncertainty,
            "retirement_threshold" => self.retirement_threshold as f64,
            "retirement_window" => self.retirement_window,
            "selection_history_window" => self.selection_history_window,
            "bad_regen_interval" => self.bad_regen_interval,
            "bad_regen_fraction" => self.bad_regen_fraction,
            "checkpoint_interval" => self.checkpoint_interval,
            "checkpoint_cost" => self.checkpoint_cost,
            "checkpoint_tier2_interval" => self.checkpoint_tier2_interval,
            "checkpoint_tier2_cost" => self.checkpoint_tier2_cost,
            "checkpoint_tier2_restore" => self.checkpoint_tier2_restore,
            "checkpoint_cost_per_server" => self.checkpoint_cost_per_server,
            "preemption_cost" => self.preemption_cost,
            "max_sim_time" => self.max_sim_time,
            _ => return None,
        })
    }

    /// All sweepable parameter names (drives `--list-params` and docs).
    pub fn sweepable_names() -> &'static [&'static str] {
        &[
            "random_failure_rate",
            "systematic_failure_rate",
            "systematic_rate_multiplier",
            "systematic_fraction",
            "num_jobs",
            "job_size",
            "job_len",
            "warm_standbys",
            "recovery_time",
            "host_selection_time",
            "waiting_time",
            "working_pool",
            "spare_pool",
            "auto_repair_prob",
            "auto_repair_fail_prob",
            "manual_repair_fail_prob",
            "auto_repair_time",
            "manual_repair_time",
            "auto_repair_capacity",
            "manual_repair_capacity",
            "repair_sla_minutes",
            "repair_pool_high_water",
            "diagnosis_prob",
            "diagnosis_uncertainty",
            "retirement_threshold",
            "retirement_window",
            "selection_history_window",
            "bad_regen_interval",
            "bad_regen_fraction",
            "checkpoint_interval",
            "checkpoint_cost",
            "checkpoint_tier2_interval",
            "checkpoint_tier2_cost",
            "checkpoint_tier2_restore",
            "checkpoint_cost_per_server",
            "preemption_cost",
            "max_sim_time",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults_match_paper() {
        let p = Params::table1_defaults();
        assert!((p.random_failure_rate - 0.01 / 1440.0).abs() < 1e-12);
        assert!((p.systematic_failure_rate - 0.05 / 1440.0).abs() < 1e-12);
        assert_eq!(p.job_size, 4096);
        assert_eq!(p.warm_standbys, 16);
        assert_eq!(p.working_pool, 4160);
        assert_eq!(p.spare_pool, 200);
        assert_eq!(p.recovery_time, 20.0);
        assert_eq!(p.manual_repair_time, 2880.0);
    }

    #[test]
    fn set_get_roundtrip_every_name() {
        for &name in Params::sweepable_names() {
            let mut p = Params::table1_defaults();
            assert!(p.set_by_name(name, 7.0), "set {name}");
            if name == "systematic_rate_multiplier" {
                assert!((p.get_by_name(name).unwrap() - 7.0).abs() < 1e-9);
            } else {
                assert_eq!(p.get_by_name(name), Some(7.0), "get {name}");
            }
        }
    }

    #[test]
    fn unknown_name_rejected() {
        let mut p = Params::table1_defaults();
        assert!(!p.set_by_name("nope", 1.0));
        assert_eq!(p.get_by_name("nope"), None);
    }

    #[test]
    fn dist_kind_mean_preserved() {
        let rate = 0.01 / 1440.0;
        for kind in [
            DistKind::Exponential,
            DistKind::Weibull { shape: 1.7 },
            DistKind::LogNormal { sigma: 0.8 },
        ] {
            let d = kind.with_rate(rate);
            let mean = d.mean();
            assert!(
                (mean - 1.0 / rate).abs() / (1.0 / rate) < 1e-9,
                "{kind:?} mean {mean}"
            );
        }
    }

    #[test]
    fn zero_rate_never_fires() {
        let d = DistKind::Weibull { shape: 2.0 }.with_rate(0.0);
        assert_eq!(d.mean(), f64::INFINITY);
    }
}
