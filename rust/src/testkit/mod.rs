//! Mini property-testing harness (the offline environment carries no
//! proptest). Provides seeded random-case generation with failure
//! reporting of the offending seed; tests use it for the coordinator
//! invariants (conservation, monotonicity, determinism).
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath)
//! use airesim::testkit::{Gen, check};
//! check("sum is commutative", 100, |g| {
//!     let a = g.f64_in(0.0, 10.0);
//!     let b = g.f64_in(0.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::sim::rng::Rng;

/// Random-value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.next_below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn prob(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    /// A fresh seed (for seeding simulations inside properties).
    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `cases` random cases of a property. Panics (with the case seed in
/// the message) on the first failing case, so failures are reproducible
/// by plugging the printed seed into [`Gen::new`].
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    // Base seed is fixed: property runs are deterministic in CI.
    for case in 0..cases {
        let case_seed = 0x5EED_0000 + case;
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            let x = g.f64_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&x));
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 9);
            assert!(x < 5, "x={x}");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let xs = [10, 20, 30];
        for _ in 0..100 {
            assert!(xs.contains(g.choose(&xs)));
        }
    }
}
