//! Mini property-testing harness (the offline environment carries no
//! proptest). Provides seeded random-case generation with failure
//! reporting of the offending seed; tests use it for the coordinator
//! invariants (conservation, monotonicity, determinism).
//!
//! Also hosts [`parse_json`], a strict RFC 8259 reader used by the
//! output-API tests to prove the hand-rolled JSON/NDJSON sinks emit
//! valid, round-trippable documents (no serde offline).
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath)
//! use airesim::testkit::{Gen, check};
//! check("sum is commutative", 100, |g| {
//!     let a = g.f64_in(0.0, 10.0);
//!     let b = g.f64_in(0.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::report::json::Json;
use crate::sim::rng::Rng;

/// Parse one JSON document (strict: trailing garbage is an error).
/// Returns the same [`Json`] model the sinks build, so round-trip tests
/// can compare structurally.
pub fn parse_json(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing input at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if b.get(*pos) == Some(&c) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at byte {pos}", c as char))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}")),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                let value = parse_value(b, pos)?;
                fields.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}")),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or_else(|| format!("bad \\u escape at byte {pos}"))?;
                        // Surrogate pairs are not emitted by our writer;
                        // map lone surrogates to the replacement char.
                        s.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 character (multi-byte safe).
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| "invalid utf-8".to_string())?;
                let c = rest.chars().next().expect("non-empty");
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

/// Random-value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.next_below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn prob(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    /// A fresh seed (for seeding simulations inside properties).
    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `cases` random cases of a property. Panics (with the case seed in
/// the message) on the first failing case, so failures are reproducible
/// by plugging the printed seed into [`Gen::new`].
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    // Base seed is fixed: property runs are deterministic in CI.
    for case in 0..cases {
        let case_seed = 0x5EED_0000 + case;
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            let x = g.f64_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&x));
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 9);
            assert!(x < 5, "x={x}");
        });
    }

    #[test]
    fn json_parser_reads_documents() {
        let j = parse_json(r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5e2}}"#).unwrap();
        let Json::Obj(fields) = &j else { panic!("expected object") };
        assert_eq!(fields[0], ("a".to_string(), Json::Num(1.0)));
        assert_eq!(
            fields[1].1,
            Json::Arr(vec![Json::Bool(true), Json::Null, Json::str("x\n")])
        );
        assert_eq!(fields[2].1, Json::obj([("d", Json::Num(-250.0))]));
        assert!(parse_json("{").is_err());
        assert!(parse_json("1 2").is_err());
        assert!(parse_json("{\"a\":}").is_err());
    }

    #[test]
    fn json_writer_parser_round_trip() {
        let original = Json::obj([
            ("num", Json::Num(1.25)),
            ("int", Json::Num(42.0)),
            ("s", Json::str("quote \" slash \\ nl \n")),
            ("arr", Json::Arr(vec![Json::Null, Json::Bool(false)])),
        ]);
        let parsed = parse_json(&original.render()).unwrap();
        assert_eq!(parsed, original);
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let xs = [10, 20, 30];
        for _ in 0..100 {
            assert!(xs.contains(g.choose(&xs)));
        }
    }
}
