//! PJRT runtime: load the AOT-compiled analytical artifact and execute it
//! from Rust. Python never runs here — `artifacts/analytic.hlo.txt` was
//! produced once at build time by `make artifacts`.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` once → `execute` per batch.
//!
//! The `xla` bindings are heavyweight and not part of the offline vendor
//! set, so the whole PJRT path is gated behind the `pjrt` cargo feature.
//! Without it, [`AnalyticModel::load`] reports itself unavailable and
//! every caller (CLI `analytic`/`prescreen`, the cross-layer tests, the
//! examples) degrades to the pure-Rust mirror in [`crate::analytical`].

/// Static batch size of the artifact (must match `model.BATCH`).
pub const BATCH: usize = 64;
/// Parameter columns (must match `model.N_PARAMS`).
pub const N_PARAMS: usize = 16;
/// Output columns (must match `model.N_OUTPUTS`).
pub const N_OUTPUTS: usize = 8;

impl AnalyticModel {
    /// Default artifact location relative to the repo root.
    pub fn default_path() -> &'static str {
        "artifacts/analytic.hlo.txt"
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::AnalyticModel;

#[cfg(not(feature = "pjrt"))]
pub use stub_impl::AnalyticModel;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{BATCH, N_OUTPUTS, N_PARAMS};
    use crate::analytical::{param_vector, AnalyticOutputs};
    use crate::bail;
    use crate::config::Params;
    use crate::util::err::{Context, Result};

    /// A loaded, compiled analytical estimator.
    pub struct AnalyticModel {
        exe: xla::PjRtLoadedExecutable,
        platform: String,
    }

    impl AnalyticModel {
        /// Load and compile `artifacts/analytic.hlo.txt` on the CPU PJRT
        /// client. Compilation happens once; `run_batch` is then pure
        /// execute.
        pub fn load(path: &str) -> Result<AnalyticModel> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let platform = client.platform_name();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text at {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("compiling analytic module")?;
            Ok(AnalyticModel { exe, platform })
        }

        pub fn platform(&self) -> &str {
            &self.platform
        }

        /// Execute one batch: `params_rows` is up to [`BATCH`] rows of
        /// [`N_PARAMS`] f32 columns; short batches are padded by repeating
        /// the last row. Returns one [`AnalyticOutputs`] per input row.
        pub fn run_batch(
            &self,
            params_rows: &[[f32; N_PARAMS]],
        ) -> Result<Vec<AnalyticOutputs>> {
            if params_rows.is_empty() {
                return Ok(Vec::new());
            }
            if params_rows.len() > BATCH {
                bail!("batch too large: {} > {}", params_rows.len(), BATCH);
            }
            let mut flat = Vec::with_capacity(BATCH * N_PARAMS);
            for row in params_rows {
                flat.extend_from_slice(row);
            }
            let last = *params_rows.last().unwrap();
            for _ in params_rows.len()..BATCH {
                flat.extend_from_slice(&last);
            }
            let input = xla::Literal::vec1(&flat)
                .reshape(&[BATCH as i64, N_PARAMS as i64])
                .context("reshaping input literal")?;
            let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // Lowered with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1().context("unwrapping result tuple")?;
            let values = out.to_vec::<f32>().context("reading result values")?;
            if values.len() != BATCH * N_OUTPUTS {
                bail!(
                    "unexpected output size {} != {}",
                    values.len(),
                    BATCH * N_OUTPUTS
                );
            }
            Ok(params_rows
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    let row: Vec<f64> = values[i * N_OUTPUTS..(i + 1) * N_OUTPUTS]
                        .iter()
                        .map(|&v| v as f64)
                        .collect();
                    AnalyticOutputs::from_array(&row)
                })
                .collect())
        }

        /// Analyze a slice of [`Params`] configurations, splitting into
        /// batches as needed.
        pub fn analyze_many(&self, configs: &[Params]) -> Result<Vec<AnalyticOutputs>> {
            let mut out = Vec::with_capacity(configs.len());
            for chunk in configs.chunks(BATCH) {
                let rows: Vec<[f32; N_PARAMS]> = chunk
                    .iter()
                    .map(|p| {
                        let v = param_vector(p);
                        let mut row = [0f32; N_PARAMS];
                        for (d, s) in row.iter_mut().zip(v.iter()) {
                            *d = *s as f32;
                        }
                        row
                    })
                    .collect();
                out.extend(self.run_batch(&rows)?);
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::N_PARAMS;
    use crate::analytical::AnalyticOutputs;
    use crate::bail;
    use crate::config::Params;
    use crate::util::err::Result;

    /// Stub used when the crate is built without the `pjrt` feature: it
    /// can never be constructed, so the methods besides [`load`] exist
    /// only to keep call sites compiling.
    ///
    /// [`load`]: AnalyticModel::load
    pub struct AnalyticModel {
        never: std::convert::Infallible,
    }

    impl AnalyticModel {
        /// Always fails: the PJRT runtime was not compiled in.
        pub fn load(path: &str) -> Result<AnalyticModel> {
            bail!(
                "PJRT runtime unavailable: built without the `pjrt` cargo feature \
                 (artifact {path} not loaded); use the pure-Rust analytical mirror"
            );
        }

        pub fn platform(&self) -> &str {
            match self.never {}
        }

        pub fn run_batch(
            &self,
            _params_rows: &[[f32; N_PARAMS]],
        ) -> Result<Vec<AnalyticOutputs>> {
            match self.never {}
        }

        pub fn analyze_many(&self, _configs: &[Params]) -> Result<Vec<AnalyticOutputs>> {
            match self.never {}
        }
    }
}
