//! AIReSim CLI — the Layer-3 leader entrypoint.
//!
//! ```text
//! airesim run      [--config f.yaml] [--seed N] [--set name=value,...]
//!                  [--policy axis=name,...] [--trace] [--trace-out f]
//!                  [--format text|json|csv|ndjson]
//! airesim sweep    [--config f.yaml] [--param name] [--values a,b,c]
//!                  [--param2 name] [--values2 ...] [--reps N] [--metric m]
//!                  [--policy axis=name,...] [--csv] [--format ...]
//! airesim scenario --config scenario.yaml [--seed N] [--threads N]
//!                  [--set ...] [--policy ...] [--format ...] [--trace-out f]
//!                  [--best-out f]
//! airesim serve    [--threads N] [--fleet-cache N] [--http addr:port]
//! airesim analytic [--config f.yaml] [--artifact path] [--set name=value,...]
//! airesim whatif   [--config f.yaml] --param name --factor F [--reps N]
//!                  [--format ...]
//! airesim list-params | list-policies | list-metrics
//! ```
//!
//! Every command body lives in [`airesim::serve::cli`] so that the
//! binary and the serve daemon share one execution path; this file is
//! only the argv → function dispatch table.

use airesim::bail;
use airesim::serve::cli;
use airesim::util::err::Result;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "run" => cli::cmd_run(rest),
        "sweep" => cli::cmd_sweep(rest),
        "scenario" => cli::cmd_scenario(rest),
        "serve" => cli::cmd_serve(rest),
        "analytic" => cli::cmd_analytic(rest),
        "prescreen" => cli::cmd_prescreen(rest),
        "whatif" => cli::cmd_whatif(rest),
        "list-params" => cli::cmd_list_params(),
        "list-policies" => cli::cmd_list_policies(),
        "list-metrics" => cli::cmd_list_metrics(),
        "help" | "--help" | "-h" => {
            cli::print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `airesim help`)"),
    }
}
