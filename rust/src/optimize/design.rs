//! Two-level factorial screening design (`mode: screen`).
//!
//! Knob importance is estimated with a fold-over of a two-level
//! orthogonal design built from the Sylvester Hadamard matrix: column
//! `j` of row `i` is `+1` when `popcount(i & j)` is even. Taking `N` =
//! the smallest power of two ≥ `k + 1` gives `k` mutually orthogonal
//! ±1 columns over `N` runs (the power-of-two Plackett–Burman
//! construction); appending the `N` sign-flipped rows (the fold-over)
//! lifts the design to resolution IV, so main effects are clear of
//! two-factor interactions. Total runs: `2N × replications`.
//!
//! Every run rides the shared CRN streams, so the per-replication
//! effect estimates are paired and the CI comes from the
//! between-replication spread of the *effect*, not of the raw
//! objective.

use crate::config::Params;
use crate::model::PolicySpec;
use crate::optimize::stats::mean_ci;
use crate::optimize::Optimize;
use crate::report::record::{OptimizeRecord, ScreenEffect};
use crate::sim::rng::Rng;
use crate::stats::metrics;
use crate::sweep::{run_pool_ordered, AxisValue, CRN_STREAM};

/// Sylvester Hadamard sign: +1 when `popcount(i & j)` is even.
fn sign(i: usize, j: usize) -> i8 {
    if (i & j).count_ones() % 2 == 0 {
        1
    } else {
        -1
    }
}

/// The fold-over design for `k` knobs: `2N` rows of `k` signs, where
/// `N` is the smallest power of two ≥ `k + 1`. Rows `0..N` are Hadamard
/// columns `1..=k`; rows `N..2N` are their negation.
pub fn fold_over_design(k: usize) -> Vec<Vec<i8>> {
    let n = (k + 1).next_power_of_two();
    let mut rows = Vec::with_capacity(2 * n);
    for i in 0..n {
        rows.push((1..=k).map(|j| sign(i, j)).collect());
    }
    for i in 0..n {
        rows.push((1..=k).map(|j| -sign(i, j)).collect());
    }
    rows
}

/// Main effects from a design matrix and one replication's objective
/// values: `e_j = (2/R) Σ_i s_ij y_i` — the mean objective at the high
/// level minus the mean at the low level.
pub fn main_effects(design: &[Vec<i8>], y: &[f64]) -> Vec<f64> {
    assert_eq!(design.len(), y.len());
    let k = design.first().map(|r| r.len()).unwrap_or(0);
    let r = design.len() as f64;
    (0..k)
        .map(|j| {
            2.0 / r
                * design
                    .iter()
                    .zip(y)
                    .map(|(row, &yi)| f64::from(row[j]) * yi)
                    .sum::<f64>()
        })
        .collect()
}

/// Run the factorial screen: every design row × replication through the
/// shared pool on CRN streams, then the ranked main-effects table.
pub fn run_screen(
    base: &Params,
    policies: &PolicySpec,
    opt: &Optimize,
    seed: u64,
    threads: usize,
) -> Result<OptimizeRecord, String> {
    let metric = metrics::resolve(&opt.objective)?;
    let design = fold_over_design(opt.knobs.len());
    let reps = opt.replications.max(1);
    let total_runs = design.len() * reps;
    if opt.budget > 0 && total_runs > opt.budget {
        return Err(format!(
            "screen needs {} runs ({} design rows x {reps} replications) but \
             optimize.budget is {} — raise the budget or drop knobs",
            total_runs,
            design.len(),
            opt.budget
        ));
    }

    // Low level = first declared value, high level = last.
    let level = |knob: usize, s: i8| -> AxisValue {
        let values = &opt.knobs[knob].values;
        if s > 0 { values[values.len() - 1].clone() } else { values[0].clone() }
    };
    let mut resolved = Vec::with_capacity(design.len());
    for row in &design {
        let overrides: Vec<(String, AxisValue)> = opt
            .knobs
            .iter()
            .enumerate()
            .map(|(j, knob)| (knob.name.clone(), level(j, row[j])))
            .collect();
        resolved.push(super::resolve_point(base, policies, &overrides)?);
    }

    let results = run_pool_ordered(design.len(), reps, threads, |runner, row, rep| {
        let (p, spec) = &resolved[row];
        let rng = Rng::derived(seed, &[CRN_STREAM, rep as u64]);
        let out = runner.run(p, spec, rng);
        (p.clone(), out)
    });
    // y[row][rep] on the objective metric.
    let y: Vec<Vec<f64>> = results
        .iter()
        .map(|(p, outs)| outs.iter().map(|o| (metric.extract)(p, o)).collect())
        .collect();

    // One effect estimate per replication (CRN-paired across rows), CI
    // from their spread. A single replication has no between-rep spread,
    // so fall back to the row-contrast series `2 s_ij y_i` (its mean is
    // exactly the effect; its spread is the classic contrast variance).
    let mut effects = Vec::with_capacity(opt.knobs.len());
    for (j, knob) in opt.knobs.iter().enumerate() {
        let ci = if reps > 1 {
            let per_rep: Vec<f64> = (0..reps)
                .map(|r| {
                    let y_r: Vec<f64> = (0..design.len()).map(|i| y[i][r]).collect();
                    main_effects(&design, &y_r)[j]
                })
                .collect();
            mean_ci(&per_rep)
        } else {
            let contrasts: Vec<f64> = design
                .iter()
                .enumerate()
                .map(|(i, row)| 2.0 * f64::from(row[j]) * y[i][0])
                .collect();
            mean_ci(&contrasts)
        }
        .expect("screen always has runs");
        effects.push(ScreenEffect {
            knob: knob.name.clone(),
            lo: knob.values[0].to_string(),
            hi: knob.values[knob.values.len() - 1].to_string(),
            effect: ci.mean,
            ci95: ci.half,
            n: ci.n,
            rank: 0,
            significant: ci.significant(),
        });
    }
    // Rank by |effect| descending; the sort is stable, so ties keep knob
    // declaration order (deterministic across runs and thread counts).
    let mut order: Vec<usize> = (0..effects.len()).collect();
    order.sort_by(|&a, &b| {
        effects[b]
            .effect
            .abs()
            .partial_cmp(&effects[a].effect.abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut ranked = Vec::with_capacity(effects.len());
    for (rank, &idx) in order.iter().enumerate() {
        let mut e = effects[idx].clone();
        e.rank = rank + 1;
        ranked.push(e);
    }

    Ok(OptimizeRecord {
        mode: "screen".to_string(),
        objective: metric.name.to_string(),
        objective_unit: metric.unit.to_string(),
        direction: opt.direction.name().to_string(),
        replications: reps,
        total_runs,
        budget: opt.budget,
        effects: ranked,
        trail: Vec::new(),
        best: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_columns_are_balanced_and_orthogonal() {
        for k in 1..=9 {
            let d = fold_over_design(k);
            let n = (k + 1).next_power_of_two();
            assert_eq!(d.len(), 2 * n, "k={k}");
            for j in 0..k {
                let sum: i32 = d.iter().map(|r| i32::from(r[j])).sum();
                assert_eq!(sum, 0, "k={k} column {j} unbalanced");
                for l in (j + 1)..k {
                    let dot: i32 = d.iter().map(|r| i32::from(r[j]) * i32::from(r[l])).sum();
                    assert_eq!(dot, 0, "k={k} columns {j},{l} not orthogonal");
                }
            }
        }
    }

    #[test]
    fn fold_over_rows_negate_the_first_half() {
        let d = fold_over_design(3);
        let n = d.len() / 2;
        for i in 0..n {
            for j in 0..3 {
                assert_eq!(d[i][j], -d[i + n][j]);
            }
        }
    }

    #[test]
    fn main_effects_recover_a_planted_linear_model() {
        // y = 10 + 3*s1 - 1*s2 (+ 0*s3): effects are the hi-vs-lo
        // differences 2a = [6, -2, 0].
        let d = fold_over_design(3);
        let y: Vec<f64> = d
            .iter()
            .map(|r| 10.0 + 3.0 * f64::from(r[0]) - 1.0 * f64::from(r[1]))
            .collect();
        let e = main_effects(&d, &y);
        assert!((e[0] - 6.0).abs() < 1e-12, "{e:?}");
        assert!((e[1] + 2.0).abs() < 1e-12, "{e:?}");
        assert!(e[2].abs() < 1e-12, "{e:?}");
    }
}
