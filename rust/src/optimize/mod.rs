//! Optimization subsystem: the `scenario: optimize` kind.
//!
//! Three layers on top of the existing CRN/run-pool substrate, turning
//! the simulator from a report generator into a recommendation engine:
//!
//! * [`stats`] — paired-CRN confidence intervals (t-based paired deltas;
//!   Welch fallback for unpaired studies). Also powers the
//!   `delta_ci`/`significant` columns in `scenario: multi`.
//! * [`design`] — `mode: screen`: a declared `knobs:` block is expanded
//!   into a two-level fold-over (resolution IV) factorial design, run on
//!   common random numbers, and reported as a ranked main-effects table
//!   ("which knobs matter").
//! * [`search`] — `mode: tune`: successive halving over the full knob
//!   grid with CRN-paired elimination (a config is pruned only when its
//!   paired CI against the incumbent excludes zero), emitting the winner
//!   as a runnable `scenario: single` YAML (`--best-out`).
//!
//! ```yaml
//! scenario: optimize
//! replications: 8
//! optimize:
//!   mode: screen            # or tune
//!   objective: makespan_hours
//!   direction: min          # or max (e.g. goodput_fraction)
//!   budget: 64              # max total simulator runs
//!   knobs:
//!     - param: checkpoint_interval
//!       values: [15, 120, 2880]
//!     - param: policies.selection
//!       values: [first_fit, history_scored]
//! ```
//!
//! Seed discipline: every replication `r` rides the shared CRN stream
//! `Rng::derived(seed, &[CRN_STREAM, r])` — the same streams a CRN
//! sweep or study uses, and zero extra draws for every other kind.

pub mod design;
pub mod search;
pub mod stats;

use crate::config::yaml::Value;
use crate::config::Params;
use crate::model::PolicySpec;
use crate::report::record::OptimizeRecord;
use crate::stats::metrics;
use crate::sweep::{AxisValue, SweepPoint};

/// What to do with the declared knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Factorial main-effects screen: rank knobs by impact.
    Screen,
    /// Successive-halving search: find the best grid point.
    Tune,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Screen => "screen",
            Mode::Tune => "tune",
        }
    }
}

/// Whether a smaller or larger objective is better.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Min,
    Max,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::Min => "min",
            Direction::Max => "max",
        }
    }
}

/// One declared knob: a numeric registry parameter or a `policies.*`
/// axis, with the candidate values to explore (declaration order; the
/// screen uses first = low level, last = high level).
#[derive(Clone, Debug)]
pub struct Knob {
    pub name: String,
    pub values: Vec<AxisValue>,
}

/// A parsed, validated `optimize:` block.
#[derive(Clone, Debug)]
pub struct Optimize {
    pub mode: Mode,
    /// Objective metric (a registry name).
    pub objective: String,
    pub direction: Direction,
    pub knobs: Vec<Knob>,
    /// Max total simulator runs (0 = derived default; see each mode).
    pub budget: usize,
    pub replications: usize,
}

/// Parse and validate the `optimize:` section of a scenario document.
/// Every knob value is checked against the registries at parse time so
/// errors name the offender, not a worker thread.
pub fn optimize_from_doc(
    doc: &Value,
    base: &Params,
    _policies: &PolicySpec,
    replications: usize,
) -> Result<Optimize, String> {
    let section = doc
        .get("optimize")
        .ok_or("scenario kind `optimize` needs an `optimize:` section")?;
    let map = section.as_map().ok_or("`optimize:` must be a map")?;
    for (key, _) in map {
        match key.as_str() {
            "mode" | "objective" | "direction" | "budget" | "knobs" => {}
            other => {
                return Err(format!(
                    "unknown `optimize:` key `{other}` (expected mode, objective, \
                     direction, budget, or knobs)"
                ))
            }
        }
    }
    let mode = match section.get("mode").and_then(|v| v.as_str()) {
        Some("screen") => Mode::Screen,
        Some("tune") => Mode::Tune,
        Some(other) => {
            return Err(format!("unknown optimize mode `{other}` (expected screen or tune)"))
        }
        None => return Err("optimize.mode missing (expected screen or tune)".into()),
    };
    let objective = section
        .get("objective")
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or("optimize.objective must be a metric name".to_string())
        })
        .unwrap_or_else(|| Ok(metrics::DEFAULT_METRIC.to_string()))?;
    metrics::resolve(&objective)?;
    let direction = match section.get("direction").and_then(|v| v.as_str()) {
        None | Some("min") => Direction::Min,
        Some("max") => Direction::Max,
        Some(other) => {
            return Err(format!(
                "unknown optimize direction `{other}` (expected min or max)"
            ))
        }
    };
    let budget = match section.get("budget") {
        None => 0,
        Some(v) => {
            let b = v
                .as_f64()
                .ok_or("optimize.budget must be a number of simulator runs")?;
            if b < 1.0 {
                return Err("optimize.budget must be >= 1".into());
            }
            b as usize
        }
    };

    let knob_list = section
        .get("knobs")
        .ok_or("optimize.knobs missing (declare at least one knob)")?
        .as_list()
        .ok_or("optimize.knobs must be a list")?;
    if knob_list.is_empty() {
        return Err("optimize.knobs must declare at least one knob".into());
    }
    let mut knobs = Vec::with_capacity(knob_list.len());
    for (i, item) in knob_list.iter().enumerate() {
        let item_map = item
            .as_map()
            .ok_or_else(|| format!("optimize.knobs[{i}] must be a map"))?;
        for (key, _) in item_map {
            match key.as_str() {
                "param" | "values" => {}
                other => {
                    return Err(format!(
                        "optimize.knobs[{i}]: unknown key `{other}` (expected param, values)"
                    ))
                }
            }
        }
        let name = item
            .get("param")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("optimize.knobs[{i}].param missing"))?
            .to_string();
        if knobs.iter().any(|k: &Knob| k.name == name) {
            return Err(format!("optimize.knobs: duplicate knob `{name}`"));
        }
        let raw = item
            .get("values")
            .ok_or_else(|| format!("optimize.knobs[{i}] ({name}): values missing"))?;
        let values = match name.strip_prefix("policies.") {
            Some(axis) => {
                let list = raw
                    .as_list()
                    .ok_or_else(|| format!("knob `{name}`: values must be a list of names"))?;
                let mut out = Vec::with_capacity(list.len());
                for v in list {
                    let s = v
                        .as_str()
                        .ok_or_else(|| format!("knob `{name}`: expected policy names"))?;
                    PolicySpec::default()
                        .set(axis, s)
                        .map_err(|e| format!("knob `{name}`: {e}"))?;
                    out.push(AxisValue::Name(s.to_string()));
                }
                out
            }
            None => {
                if base.get_by_name(&name).is_none() {
                    return Err(format!(
                        "knob `{name}` is not a sweepable parameter (see `airesim list-params`)"
                    ));
                }
                raw.as_f64_list()
                    .ok_or_else(|| format!("knob `{name}`: values must be a list of numbers"))?
                    .into_iter()
                    .map(AxisValue::Num)
                    .collect()
            }
        };
        if values.len() < 2 {
            return Err(format!(
                "knob `{name}` needs at least 2 values (got {})",
                values.len()
            ));
        }
        knobs.push(Knob { name, values });
    }

    Ok(Optimize { mode, objective, direction, knobs, budget, replications: replications.max(1) })
}

/// Resolve one candidate point — apply knob overrides, then run the full
/// config validation and policy build so worker threads never see an
/// error.
pub(crate) fn resolve_point(
    base: &Params,
    policies: &PolicySpec,
    overrides: &[(String, AxisValue)],
) -> Result<(Params, PolicySpec), String> {
    let point = SweepPoint { overrides: overrides.to_vec() };
    let label = if overrides.is_empty() { "base".to_string() } else { point.label() };
    let (p, spec) = point
        .apply_full(base, policies)
        .map_err(|e| format!("optimize point `{label}`: {e}"))?;
    crate::config::validate::validate(&p)
        .map_err(|e| format!("optimize point `{label}`: {e}"))?;
    spec.build(&p)
        .map_err(|e| format!("optimize point `{label}`: {e}"))?;
    Ok((p, spec))
}

/// Run the optimize scenario: dispatch on mode.
pub fn run_optimize(
    base: &Params,
    policies: &PolicySpec,
    opt: &Optimize,
    seed: u64,
    threads: usize,
) -> Result<OptimizeRecord, String> {
    match opt.mode {
        Mode::Screen => design::run_screen(base, policies, opt, seed, threads),
        Mode::Tune => search::run_tune(base, policies, opt, seed, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    fn base() -> Params {
        Params::small_test()
    }

    fn parse(optimize_block: &str) -> Result<Optimize, String> {
        let doc = yaml::parse(optimize_block).unwrap();
        optimize_from_doc(&doc, &base(), &PolicySpec::default(), 4)
    }

    const GOOD: &str = "optimize:\n  mode: screen\n  objective: makespan_hours\n  \
                        direction: min\n  budget: 64\n  knobs:\n    - param: checkpoint_interval\n      \
                        values: [15, 120]\n    - param: policies.selection\n      \
                        values: [first_fit, locality]\n";

    #[test]
    fn parses_a_full_block() {
        let opt = parse(GOOD).unwrap();
        assert_eq!(opt.mode, Mode::Screen);
        assert_eq!(opt.objective, "makespan_hours");
        assert_eq!(opt.direction, Direction::Min);
        assert_eq!(opt.budget, 64);
        assert_eq!(opt.replications, 4);
        assert_eq!(opt.knobs.len(), 2);
        assert_eq!(opt.knobs[0].name, "checkpoint_interval");
        assert_eq!(opt.knobs[1].values[1], AxisValue::Name("locality".into()));
    }

    #[test]
    fn defaults_objective_and_direction() {
        let opt = parse(
            "optimize:\n  mode: tune\n  knobs:\n    - param: recovery_time\n      values: [10, 30]\n",
        )
        .unwrap();
        assert_eq!(opt.objective, metrics::DEFAULT_METRIC);
        assert_eq!(opt.direction, Direction::Min);
        assert_eq!(opt.budget, 0, "budget defaults per mode");
    }

    #[test]
    fn rejects_offenders_by_name() {
        let err = parse("optimize:\n  knobs:\n    - param: recovery_time\n      values: [10, 30]\n")
            .unwrap_err();
        assert!(err.contains("mode"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  knobs:\n    - param: not_a_param\n      values: [1, 2]\n",
        )
        .unwrap_err();
        assert!(err.contains("not_a_param"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  knobs:\n    - param: policies.selection\n      values: [bogus, locality]\n",
        )
        .unwrap_err();
        assert!(err.contains("bogus"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  objective: not_a_metric\n  knobs:\n    - param: recovery_time\n      values: [10, 30]\n",
        )
        .unwrap_err();
        assert!(err.contains("not_a_metric"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  knobs:\n    - param: recovery_time\n      values: [10]\n",
        )
        .unwrap_err();
        assert!(err.contains("at least 2"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  surprise: 1\n  knobs:\n    - param: recovery_time\n      values: [10, 30]\n",
        )
        .unwrap_err();
        assert!(err.contains("surprise"), "{err}");

        let err = parse(
            "optimize:\n  mode: screen\n  knobs:\n    - param: recovery_time\n      values: [10, 30]\n    - param: recovery_time\n      values: [5, 15]\n",
        )
        .unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn resolve_point_names_bad_points() {
        let overrides = vec![("recovery_time".to_string(), AxisValue::Num(-5.0))];
        let err = resolve_point(&base(), &PolicySpec::default(), &overrides).unwrap_err();
        assert!(err.contains("recovery_time=-5"), "{err}");
    }
}
