//! Confidence-interval machinery for the optimization subsystem.
//!
//! Two inference paths, both returning a [`Ci`]:
//!
//! * [`paired_delta_ci`] — replication-level paired deltas between two
//!   CRN-matched variants (rep *r* of A and rep *r* of B share the same
//!   random-number stream, so their difference cancels the common noise).
//!   The t-interval is computed on the paired differences.
//! * [`welch_delta_ci`] — unpaired (Welch) interval for studies run
//!   without CRN, where replication indices carry no pairing.
//!
//! Both are exact small-sample t-intervals: the critical value comes
//! from a fixed two-sided 97.5% table (no incomplete-beta evaluation,
//! keeping the crate dependency-free), conservative for the df gaps.

/// A two-sided 95% confidence interval on a mean: `mean ± half`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ci {
    /// Number of observations the interval was computed from.
    pub n: usize,
    /// Point estimate.
    pub mean: f64,
    /// 95% half-width (`INFINITY` when n < 2 — one observation carries
    /// no variance information; `0.0` for degenerate zero variance).
    pub half: f64,
}

impl Ci {
    pub fn lo(&self) -> f64 {
        self.mean - self.half
    }

    pub fn hi(&self) -> f64 {
        self.mean + self.half
    }

    /// True when the interval excludes zero (the paired delta is
    /// distinguishable from "no difference" at the 95% level).
    pub fn significant(&self) -> bool {
        self.half.is_finite() && (self.lo() > 0.0 || self.hi() < 0.0)
    }
}

/// Two-sided 97.5% Student-t critical value for `df` degrees of
/// freedom. Exact for df 1–30, then the standard coarse table
/// (40/60/120/∞) — conservative in the gaps (uses the smaller df's
/// larger critical value).
pub fn t975(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        1..=30 => TABLE[df - 1],
        31..=40 => 2.021,
        41..=60 => 2.000,
        61..=120 => 1.980,
        _ => 1.960,
    }
}

/// t-based 95% CI on the mean of `values`. `None` when empty; a single
/// value yields an infinite half-width; zero sample variance yields a
/// zero half-width (never NaN).
pub fn mean_ci(values: &[f64]) -> Option<Ci> {
    let n = values.len();
    if n == 0 {
        return None;
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return Some(Ci { n, mean, half: f64::INFINITY });
    }
    let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
    let half = if var <= 0.0 {
        0.0
    } else {
        t975(n - 1) * (var / n as f64).sqrt()
    };
    Some(Ci { n, mean, half })
}

/// Paired 95% CI on the mean of `b - a`, replication by replication.
/// Requires equal lengths (the CRN pairing is positional); `None` when
/// the series are empty or mismatched.
pub fn paired_delta_ci(a: &[f64], b: &[f64]) -> Option<Ci> {
    if a.len() != b.len() || a.is_empty() {
        return None;
    }
    let deltas: Vec<f64> = a.iter().zip(b).map(|(x, y)| y - x).collect();
    mean_ci(&deltas)
}

/// Unpaired Welch 95% CI on `mean(b) - mean(a)` with the
/// Welch–Satterthwaite degrees of freedom (floored, min 1). Used when
/// the study ran without CRN so replication indices carry no pairing.
pub fn welch_delta_ci(a: &[f64], b: &[f64]) -> Option<Ci> {
    if a.is_empty() || b.is_empty() {
        return None;
    }
    let (na, nb) = (a.len(), b.len());
    let ma = a.iter().sum::<f64>() / na as f64;
    let mb = b.iter().sum::<f64>() / nb as f64;
    let mean = mb - ma;
    if na < 2 || nb < 2 {
        return Some(Ci { n: na.min(nb), mean, half: f64::INFINITY });
    }
    let va = a.iter().map(|v| (v - ma).powi(2)).sum::<f64>() / (na - 1) as f64;
    let vb = b.iter().map(|v| (v - mb).powi(2)).sum::<f64>() / (nb - 1) as f64;
    let (sa, sb) = (va / na as f64, vb / nb as f64);
    let se2 = sa + sb;
    if se2 <= 0.0 {
        return Some(Ci { n: na.min(nb), mean, half: 0.0 });
    }
    let df_num = se2 * se2;
    let df_den = sa * sa / (na - 1) as f64 + sb * sb / (nb - 1) as f64;
    let df = if df_den > 0.0 {
        ((df_num / df_den).floor() as usize).max(1)
    } else {
        1
    };
    Some(Ci { n: na.min(nb), mean, half: t975(df) * se2.sqrt() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_table_monotone_and_anchored() {
        assert_eq!(t975(1), 12.706);
        assert_eq!(t975(4), 2.776);
        assert_eq!(t975(30), 2.042);
        assert_eq!(t975(1000), 1.960);
        assert!(t975(0).is_infinite());
        for df in 1..200 {
            assert!(t975(df + 1) <= t975(df), "t975 must be non-increasing");
        }
    }

    #[test]
    fn paired_ci_matches_hand_computed_fixture() {
        // deltas = [1, 2, 3, 4, 5]: mean 3, sample var 2.5, df 4.
        let a = [10.0, 10.0, 10.0, 10.0, 10.0];
        let b = [11.0, 12.0, 13.0, 14.0, 15.0];
        let ci = paired_delta_ci(&a, &b).unwrap();
        assert_eq!(ci.n, 5);
        assert!((ci.mean - 3.0).abs() < 1e-12);
        let expected_half = 2.776 * (2.5f64 / 5.0).sqrt();
        assert!((ci.half - expected_half).abs() < 1e-9, "{} vs {expected_half}", ci.half);
        assert!(ci.significant(), "interval [1.04, 4.96] excludes zero");
    }

    #[test]
    fn degenerate_variance_yields_zero_width_not_nan() {
        let a = [5.0, 5.0, 5.0];
        let b = [7.0, 7.0, 7.0];
        let ci = paired_delta_ci(&a, &b).unwrap();
        assert_eq!(ci.mean, 2.0);
        assert_eq!(ci.half, 0.0);
        assert!(!ci.half.is_nan());
        assert!(ci.significant());
        // All-equal deltas with zero mean: zero-width, not significant.
        let ci = paired_delta_ci(&a, &a).unwrap();
        assert_eq!(ci.mean, 0.0);
        assert_eq!(ci.half, 0.0);
        assert!(!ci.significant());
    }

    #[test]
    fn single_pair_is_infinite_width() {
        let ci = paired_delta_ci(&[1.0], &[4.0]).unwrap();
        assert_eq!(ci.mean, 3.0);
        assert!(ci.half.is_infinite());
        assert!(!ci.significant());
    }

    #[test]
    fn mismatched_or_empty_series_yield_none() {
        assert!(paired_delta_ci(&[1.0, 2.0], &[1.0]).is_none());
        assert!(paired_delta_ci(&[], &[]).is_none());
        assert!(welch_delta_ci(&[], &[1.0]).is_none());
        assert!(mean_ci(&[]).is_none());
    }

    #[test]
    fn welch_matches_hand_computed_fixture() {
        // a = [1,2,3], b = [5,7,9]: ma=2 va=1, mb=7 vb=4, delta 5,
        // se2 = 1/3 + 4/3 = 5/3, df = (5/3)^2 / ((1/9)/2 + (16/9)/2)
        //     = (25/9)/(17/18) = 50/17 ≈ 2.94 → floor 2 → t=4.303.
        let ci = welch_delta_ci(&[1.0, 2.0, 3.0], &[5.0, 7.0, 9.0]).unwrap();
        assert!((ci.mean - 5.0).abs() < 1e-12);
        let expected = 4.303 * (5.0f64 / 3.0).sqrt();
        assert!((ci.half - expected).abs() < 1e-9, "{} vs {expected}", ci.half);
    }

    #[test]
    fn welch_single_observation_is_infinite() {
        let ci = welch_delta_ci(&[1.0], &[2.0, 3.0]).unwrap();
        assert!(ci.half.is_infinite());
    }

    #[test]
    fn paired_beats_welch_when_noise_is_shared() {
        // Same per-rep noise on both arms plus a fixed offset: paired
        // deltas are constant (zero-width CI) while Welch sees the full
        // between-rep variance.
        let noise = [0.0, 3.0, -2.0, 5.0, 1.0, -4.0];
        let a: Vec<f64> = noise.iter().map(|z| 100.0 + z).collect();
        let b: Vec<f64> = noise.iter().map(|z| 102.0 + z).collect();
        let paired = paired_delta_ci(&a, &b).unwrap();
        let welch = welch_delta_ci(&a, &b).unwrap();
        assert_eq!(paired.half, 0.0);
        assert!(welch.half > 1.0, "welch sees the shared noise: {}", welch.half);
        assert!(paired.half < welch.half);
    }
}
