//! Successive-halving search over the knob grid (`mode: tune`).
//!
//! Candidates are the base configuration plus every point of the full
//! cartesian knob grid (declaration order, first knob outermost). All
//! surviving candidates are always evaluated to the *same* replication
//! count on the shared CRN streams, so every pairwise comparison is a
//! paired comparison. Each round doubles the replication count, ranks
//! by the direction-adjusted mean, and keeps the best half
//! unconditionally; a worse-half candidate is pruned **only when its
//! paired CI against the incumbent excludes zero** on the worse side —
//! a noisy loser is never eliminated on a coin flip. The base
//! configuration is exempt from pruning: it is the control arm the
//! final winner-vs-base verdict pairs against, so it always runs to the
//! full replication count.
//!
//! Tie handling is deterministic by construction: ranking sorts by
//! (adjusted mean, candidate declaration index) with a stable sort, and
//! all bookkeeping is indexed by candidate id — never by map iteration
//! order. Output is byte-identical across runs and thread counts.

use crate::config::Params;
use crate::model::PolicySpec;
use crate::optimize::stats::{mean_ci, paired_delta_ci, Ci};
use crate::optimize::{Direction, Optimize};
use crate::report::record::{BestConfig, OptimizeRecord, TunePoint};
use crate::sim::rng::Rng;
use crate::stats::metrics;
use crate::sweep::{run_pool_ordered, AxisValue, CRN_STREAM};

/// One search candidate: its grid overrides and resolved config.
struct Candidate {
    label: String,
    overrides: Vec<(String, AxisValue)>,
    params: Params,
    spec: PolicySpec,
    /// Objective values in replication order (CRN stream `r` at index `r`).
    values: Vec<f64>,
    pruned_round: Option<usize>,
}

/// The base point plus the full cartesian grid, in declaration order
/// (first knob outermost — matches sweep axis order).
fn candidates(opt: &Optimize) -> Vec<Vec<(String, AxisValue)>> {
    let mut grid: Vec<Vec<(String, AxisValue)>> = vec![Vec::new()];
    for knob in &opt.knobs {
        let mut next = Vec::with_capacity(grid.len() * knob.values.len());
        for stem in &grid {
            for v in &knob.values {
                let mut overrides = stem.clone();
                overrides.push((knob.name.clone(), v.clone()));
                next.push(overrides);
            }
        }
        grid = next;
    }
    let mut all = Vec::with_capacity(grid.len() + 1);
    all.push(Vec::new()); // candidate 0: the base configuration
    all.extend(grid);
    all
}

/// Render the winning configuration as a runnable `scenario: single`
/// document (every sweepable parameter pinned, plus the resolved policy
/// selection). `systematic_rate_multiplier` is omitted — it is derived
/// from the two rates already emitted and would double-apply.
fn best_yaml(label: &str, seed: u64, p: &Params, spec: &PolicySpec) -> String {
    let mut s = String::new();
    s.push_str("# Emitted by `scenario: optimize` (mode: tune): the winning configuration.\n");
    s.push_str(&format!("# Winner: {label}\n"));
    s.push_str("scenario: single\n");
    s.push_str(&format!("title: tuned {label}\n"));
    s.push_str(&format!("seed: {seed}\n"));
    s.push_str("params:\n");
    for &name in Params::sweepable_names() {
        if name == "systematic_rate_multiplier" {
            continue;
        }
        let v = p.get_by_name(name).expect("sweepable names readable");
        s.push_str(&format!("  {name}: {v}\n"));
    }
    match p.failure_dist {
        crate::config::DistKind::Exponential => {}
        crate::config::DistKind::Weibull { shape } => {
            s.push_str(&format!("  failure_dist: weibull:{shape}\n"));
        }
        crate::config::DistKind::LogNormal { sigma } => {
            s.push_str(&format!("  failure_dist: lognormal:{sigma}\n"));
        }
    }
    s.push_str("policies:\n");
    s.push_str(&format!("  selection: {}\n", spec.selection));
    s.push_str(&format!("  repair: {}\n", spec.repair));
    s.push_str(&format!("  checkpoint: {}\n", spec.checkpoint));
    s.push_str(&format!("  failure: {}\n", spec.failure));
    s
}

/// Run the successive-halving search.
pub fn run_tune(
    base: &Params,
    policies: &PolicySpec,
    opt: &Optimize,
    seed: u64,
    threads: usize,
) -> Result<OptimizeRecord, String> {
    let metric = metrics::resolve(&opt.objective)?;
    let reps_cap = opt.replications.max(1);
    let mut cands: Vec<Candidate> = Vec::new();
    for overrides in candidates(opt) {
        let (params, spec) = super::resolve_point(base, policies, &overrides)?;
        let label = if overrides.is_empty() {
            "base".to_string()
        } else {
            crate::sweep::SweepPoint { overrides: overrides.clone() }.label()
        };
        cands.push(Candidate { label, overrides, params, spec, values: Vec::new(), pruned_round: None });
    }

    let initial_reps = reps_cap.min(2);
    let budget = if opt.budget == 0 { cands.len() * reps_cap } else { opt.budget };
    if budget < cands.len() * initial_reps {
        return Err(format!(
            "optimize.budget {} cannot cover the first round ({} candidates x \
             {initial_reps} replications = {} runs)",
            budget,
            cands.len(),
            cands.len() * initial_reps
        ));
    }

    // Direction-adjusted mean: smaller is always better internally.
    let adj = |mean: f64| match opt.direction {
        Direction::Min => mean,
        Direction::Max => -mean,
    };
    let mean_of = |c: &Candidate| c.values.iter().sum::<f64>() / c.values.len().max(1) as f64;

    let mut alive: Vec<usize> = (0..cands.len()).collect();
    let mut have = 0usize;
    let mut target = initial_reps;
    let mut total_runs = 0usize;
    let mut round = 0usize;
    loop {
        let new = target - have;
        if new == 0 || total_runs + alive.len() * new > budget {
            break;
        }
        // Run the missing replications for every surviving candidate.
        // Replication `have + r` rides CRN stream `have + r` for every
        // candidate — pairing holds across rounds.
        let results = run_pool_ordered(alive.len(), new, threads, |runner, ai, rep| {
            let c = &cands[alive[ai]];
            let rng = Rng::derived(seed, &[CRN_STREAM, (have + rep) as u64]);
            let out = runner.run(&c.params, &c.spec, rng);
            (c.params.clone(), out)
        });
        for (ai, (p, outs)) in results.into_iter().enumerate() {
            let c = &mut cands[alive[ai]];
            c.values.extend(outs.iter().map(|o| (metric.extract)(&p, o)));
        }
        total_runs += alive.len() * new;
        have = target;
        round += 1;

        // Rank survivors: adjusted mean, ties by declaration index
        // (stable — never map iteration order).
        alive.sort_by(|&a, &b| {
            adj(mean_of(&cands[a]))
                .partial_cmp(&adj(mean_of(&cands[b])))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        if alive.len() > 1 {
            let keep = alive.len().div_ceil(2);
            let incumbent = alive[0];
            let mut survivors: Vec<usize> = alive[..keep].to_vec();
            for &c in &alive[keep..] {
                // The base configuration is the control arm the final
                // winner-vs-base verdict pairs against: it always rides
                // to the full replication count, so the verdict's CI is
                // never starved down to a first-round sample.
                if c == 0 {
                    survivors.push(c);
                    continue;
                }
                let ci = paired_delta_ci(&cands[incumbent].values, &cands[c].values)
                    .expect("equal-length CRN series");
                // Delta is candidate - incumbent; prune only when the CI
                // puts the candidate strictly on the worse side of zero.
                let provably_worse = match opt.direction {
                    Direction::Min => ci.lo() > 0.0,
                    Direction::Max => ci.hi() < 0.0,
                };
                if provably_worse {
                    cands[c].pruned_round = Some(round);
                } else {
                    survivors.push(c);
                }
            }
            alive = survivors;
        }
        if have >= reps_cap || alive.len() == 1 {
            break;
        }
        target = (have * 2).min(reps_cap);
    }

    // Winner: the best-ranked survivor (alive is sorted best-first after
    // at least one round; guard the degenerate zero-round case anyway).
    let winner = alive
        .iter()
        .copied()
        .min_by(|&a, &b| {
            adj(mean_of(&cands[a]))
                .partial_cmp(&adj(mean_of(&cands[b])))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        })
        .expect("at least the base candidate survives");

    // Final paired verdict vs the base config, over the replications
    // both actually ran (a prefix — CRN streams are positional).
    let common = cands[0].values.len().min(cands[winner].values.len());
    let delta = if winner == 0 || common == 0 {
        Ci { n: common, mean: 0.0, half: 0.0 }
    } else {
        paired_delta_ci(&cands[0].values[..common], &cands[winner].values[..common])
            .expect("equal-length prefixes")
    };
    let w = &cands[winner];
    let best = BestConfig {
        label: w.label.clone(),
        overrides: w.overrides.clone(),
        mean: mean_of(w),
        delta_mean: delta.mean,
        delta_ci95: delta.half,
        delta_n: delta.n,
        significant: winner != 0 && delta.significant(),
        yaml: best_yaml(&w.label, seed, &w.params, &w.spec),
    };

    let trail = cands
        .iter()
        .enumerate()
        .map(|(i, c)| TunePoint {
            label: c.label.clone(),
            overrides: c.overrides.clone(),
            n: c.values.len(),
            mean: mean_of(c),
            ci95: mean_ci(&c.values).map(|ci| ci.half).unwrap_or(f64::INFINITY),
            pruned_round: c.pruned_round,
            winner: i == winner,
        })
        .collect();

    Ok(OptimizeRecord {
        mode: "tune".to_string(),
        objective: metric.name.to_string(),
        objective_unit: metric.unit.to_string(),
        direction: opt.direction.name().to_string(),
        replications: reps_cap,
        total_runs,
        budget,
        effects: Vec::new(),
        trail,
        best: Some(best),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::{Knob, Mode};

    fn opt(knobs: Vec<Knob>) -> Optimize {
        Optimize {
            mode: Mode::Tune,
            objective: "makespan_hours".to_string(),
            direction: Direction::Min,
            knobs,
            budget: 0,
            replications: 4,
        }
    }

    #[test]
    fn grid_includes_base_and_is_declaration_ordered() {
        let o = opt(vec![
            Knob { name: "recovery_time".into(), values: vec![10.0.into(), 30.0.into()] },
            Knob {
                name: "policies.selection".into(),
                values: vec!["first_fit".into(), "locality".into()],
            },
        ]);
        let c = candidates(&o);
        assert_eq!(c.len(), 5); // base + 2x2 grid
        assert!(c[0].is_empty());
        assert_eq!(c[1][0], ("recovery_time".to_string(), AxisValue::Num(10.0)));
        assert_eq!(c[1][1], ("policies.selection".to_string(), AxisValue::Name("first_fit".into())));
        assert_eq!(c[4][0], ("recovery_time".to_string(), AxisValue::Num(30.0)));
        assert_eq!(c[4][1], ("policies.selection".to_string(), AxisValue::Name("locality".into())));
    }

    #[test]
    fn best_yaml_reparses_as_a_single_scenario() {
        let p = Params::small_test();
        let spec = PolicySpec::default();
        let y = best_yaml("recovery_time=10", 42, &p, &spec);
        let doc = crate::config::yaml::parse(&y).expect("emitted YAML parses");
        assert_eq!(doc.get("scenario").and_then(|v| v.as_str()), Some("single"));
        let parsed = crate::config::validate::params_from_config(&doc).expect("params valid");
        for &name in Params::sweepable_names() {
            let a = parsed.get_by_name(name).unwrap();
            let b = p.get_by_name(name).unwrap();
            assert!(
                (a - b).abs() <= 1e-12 * b.abs().max(1.0),
                "{name}: emitted {a} != source {b}"
            );
        }
    }

    #[test]
    fn best_yaml_round_trips_non_exponential_dists() {
        let mut p = Params::small_test();
        p.failure_dist = crate::config::DistKind::Weibull { shape: 1.5 };
        let y = best_yaml("base", 1, &p, &PolicySpec::default());
        let doc = crate::config::yaml::parse(&y).unwrap();
        let parsed = crate::config::validate::params_from_config(&doc).unwrap();
        assert_eq!(parsed.failure_dist, p.failure_dist);
    }
}
