//! Diagnosis (inputs 12–13): after a job-killing failure, root-cause
//! analysis identifies a culprit server — maybe, and maybe the wrong one.
//!
//! * With probability `diagnosis_prob` a server is identified at all;
//!   otherwise the failed server is restarted in place with no repair
//!   (the failure was never attributed, as happens with e.g. NCCL timeouts
//!   whose origin is ambiguous).
//! * Given a diagnosis, with probability `diagnosis_uncertainty` the
//!   *wrong* server is blamed: an innocent peer is pulled for repair while
//!   the true culprit keeps running.

use crate::config::Params;
use crate::model::events::ServerId;
use crate::sim::rng::Rng;

/// The outcome of diagnosing one failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Diagnosis {
    /// Nothing identified: restart the failed server in place.
    Undiagnosed,
    /// The true culprit was identified and goes to repair.
    Correct(ServerId),
    /// An innocent peer was blamed; the culprit stays in service.
    Wrong { blamed: ServerId, culprit: ServerId },
}

/// Run the diagnosis policy for a failure of `failed` among `peers`
/// (the other active servers in the gang).
pub fn diagnose(
    p: &Params,
    failed: ServerId,
    peers: &[ServerId],
    rng: &mut Rng,
) -> Diagnosis {
    if !rng.bernoulli(p.diagnosis_prob) {
        return Diagnosis::Undiagnosed;
    }
    if p.diagnosis_uncertainty > 0.0
        && !peers.is_empty()
        && rng.bernoulli(p.diagnosis_uncertainty)
    {
        let blamed = peers[rng.next_below(peers.len() as u64) as usize];
        debug_assert_ne!(blamed, failed);
        return Diagnosis::Wrong { blamed, culprit: failed };
    }
    Diagnosis::Correct(failed)
}

/// Allocation-free variant for the hot path: `gang` is the full active
/// list *including* `failed`; a wrong blame is rejection-sampled directly
/// from it (no peers vector is materialized).
pub fn diagnose_in_gang(
    p: &Params,
    failed: ServerId,
    gang: &[ServerId],
    rng: &mut Rng,
) -> Diagnosis {
    if !rng.bernoulli(p.diagnosis_prob) {
        return Diagnosis::Undiagnosed;
    }
    if p.diagnosis_uncertainty > 0.0
        && gang.len() > 1
        && rng.bernoulli(p.diagnosis_uncertainty)
    {
        // Uniform over gang \ {failed} by rejection (E[draws] ≤ 1 + 1/n).
        loop {
            let blamed = gang[rng.next_below(gang.len() as u64) as usize];
            if blamed != failed {
                return Diagnosis::Wrong { blamed, culprit: failed };
            }
        }
    }
    Diagnosis::Correct(failed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn peers() -> Vec<ServerId> {
        (1..100).collect()
    }

    #[test]
    fn always_diagnosed_when_prob_one() {
        let mut p = Params::small_test();
        p.diagnosis_prob = 1.0;
        p.diagnosis_uncertainty = 0.0;
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert_eq!(diagnose(&p, 0, &peers(), &mut rng), Diagnosis::Correct(0));
        }
    }

    #[test]
    fn never_diagnosed_when_prob_zero() {
        let mut p = Params::small_test();
        p.diagnosis_prob = 0.0;
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            assert_eq!(diagnose(&p, 0, &peers(), &mut rng), Diagnosis::Undiagnosed);
        }
    }

    #[test]
    fn uncertainty_blames_a_peer() {
        let mut p = Params::small_test();
        p.diagnosis_prob = 1.0;
        p.diagnosis_uncertainty = 1.0;
        let mut rng = Rng::new(3);
        let ps = peers();
        for _ in 0..1000 {
            match diagnose(&p, 0, &ps, &mut rng) {
                Diagnosis::Wrong { blamed, culprit } => {
                    assert_eq!(culprit, 0);
                    assert!(ps.contains(&blamed));
                }
                other => panic!("expected Wrong, got {other:?}"),
            }
        }
    }

    #[test]
    fn uncertainty_with_no_peers_falls_back_to_correct() {
        let mut p = Params::small_test();
        p.diagnosis_prob = 1.0;
        p.diagnosis_uncertainty = 1.0;
        let mut rng = Rng::new(4);
        assert_eq!(diagnose(&p, 7, &[], &mut rng), Diagnosis::Correct(7));
    }

    #[test]
    fn rates_match_probabilities() {
        let mut p = Params::small_test();
        p.diagnosis_prob = 0.8;
        p.diagnosis_uncertainty = 0.25;
        let mut rng = Rng::new(5);
        let ps = peers();
        let n = 100_000;
        let mut undiag = 0;
        let mut wrong = 0;
        for _ in 0..n {
            match diagnose(&p, 0, &ps, &mut rng) {
                Diagnosis::Undiagnosed => undiag += 1,
                Diagnosis::Wrong { .. } => wrong += 1,
                Diagnosis::Correct(_) => {}
            }
        }
        let f_undiag = undiag as f64 / n as f64;
        let f_wrong = wrong as f64 / n as f64;
        assert!((f_undiag - 0.2).abs() < 0.01, "undiag={f_undiag}");
        assert!((f_wrong - 0.8 * 0.25).abs() < 0.01, "wrong={f_wrong}");
    }
}
