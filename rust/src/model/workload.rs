//! The workload subsystem: open-loop job arrivals, job-mix classes, and
//! NDJSON trace replay (the `workload:` config block).
//!
//! The paper's assumption 6 runs a fixed job set that all exists at t=0;
//! real clusters serve a *stream* where jobs arrive, queue for admission,
//! and contend for the spare pool. This module turns `Params::num_jobs`
//! into an arrival plan:
//!
//! * [`ArrivalProcess::Poisson`] — exponential inter-arrivals at `rate`
//!   (1/min), the open-loop M/·/· workload.
//! * [`ArrivalProcess::Empirical`] — inter-arrivals read from a file,
//!   one gap per line (cycled when the file holds fewer gaps than jobs).
//! * [`ArrivalProcess::Replay`] — re-schedule `job_arrival` and
//!   `failure` events from a previously captured `--trace-out` NDJSON
//!   timeline; the stochastic failure clocks are silenced the way
//!   `scenario: inject` already does, so the replayed run reproduces the
//!   recorded timeline exactly — under whatever *policies* the replaying
//!   config selects (record an incident, replay it under a different
//!   repair discipline).
//!
//! Arrivals optionally draw a heterogeneous job shape from weighted
//! [`JobClass`]es; the resolved shape is stamped onto the `Job` (see
//! `Job::shape`) and carried in `job_arrival` trace events so replays
//! keep the mix.
//!
//! Determinism: the arrival plan is drawn from a dedicated
//! [`Rng::derived`] stream (key [`WORKLOAD_STREAM`]), seeded by a single
//! `next_u64` taken from the run's master RNG *only when a workload is
//! configured* — configs without `workload:` perform zero extra draws
//! and stay byte-identical.

use crate::config::Params;
use crate::model::events::ServerId;
use crate::report::json::Json;
use crate::sim::dist::Dist;
use crate::sim::rng::Rng;
use crate::sim::Time;

/// Derivation key for the arrival-plan RNG stream (`Rng::derived`),
/// chosen to collide with no other derived stream in the crate.
pub const WORKLOAD_STREAM: u64 = 0x574f_524b_4c4f_4144; // "WORKLOAD"

/// One weighted job class: overrides the Table-I job shape for arrivals
/// that draw it. Unset fields fall back to the corresponding `Params`.
#[derive(Clone, Debug, PartialEq)]
pub struct JobClass {
    /// Relative draw weight (> 0).
    pub weight: f64,
    pub job_size: Option<u32>,
    pub job_len: Option<Time>,
    pub warm_standbys: Option<u32>,
}

/// A `job_arrival` event lifted from a replayed trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplayArrival {
    pub at: Time,
    pub size: u32,
    pub len: Time,
    pub standbys: u32,
}

/// A `failure` event lifted from a replayed trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplayFailure {
    pub at: Time,
    pub server: ServerId,
    pub systematic: bool,
}

/// Where inter-arrival times come from.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential inter-arrivals at `rate` jobs/min (`rate <= 0` puts
    /// every job at t=0, the degenerate open-loop limit).
    Poisson { rate: f64 },
    /// Inter-arrivals from `file`, one per line (`#` comments and blank
    /// lines skipped), parsed into `gaps` at config load. Cycled when
    /// the run needs more arrivals than the file holds.
    Empirical { file: String, gaps: Vec<Time> },
    /// Events from a `--trace-out` NDJSON capture, parsed at config
    /// load. `arrivals` drive the job plan (empty = the legacy all-at-
    /// t=0 init); `failures` become server-targeted injections.
    Replay { file: String, arrivals: Vec<ReplayArrival>, failures: Vec<ReplayFailure> },
}

/// The `workload:` config block.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub arrival: ArrivalProcess,
    /// Weighted job-mix classes; empty = every arrival uses the
    /// homogeneous Table-I shape.
    pub classes: Vec<JobClass>,
}

/// One planned arrival: job `j` of the run arrives at `at` with this
/// resolved shape. `size == 0` means "use `Params`" (see `Job::shape`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobSpec {
    pub at: Time,
    pub size: u32,
    pub len: Time,
    pub standbys: u32,
}

impl WorkloadSpec {
    /// Draw the run's arrival plan. `rng` must be the dedicated
    /// workload stream; per arrival the draw order is *gap, then class*
    /// (classes only drawn when `classes` is non-empty). Replay ignores
    /// `rng` entirely. An empty plan means "legacy init": all
    /// `num_jobs` jobs present and started at t=0.
    pub fn plan(&self, p: &Params, rng: &mut Rng) -> Vec<JobSpec> {
        match &self.arrival {
            ArrivalProcess::Poisson { rate } => {
                let gap_dist = Dist::exp_rate(*rate);
                let mut t = 0.0;
                (0..p.num_jobs)
                    .map(|_| {
                        let gap = if *rate > 0.0 { gap_dist.sample(rng) } else { 0.0 };
                        t += gap;
                        self.draw_class(p, rng, t)
                    })
                    .collect()
            }
            ArrivalProcess::Empirical { gaps, .. } => {
                let mut t = 0.0;
                (0..p.num_jobs as usize)
                    .map(|j| {
                        t += gaps[j % gaps.len()];
                        self.draw_class(p, rng, t)
                    })
                    .collect()
            }
            ArrivalProcess::Replay { arrivals, .. } => arrivals
                .iter()
                .map(|a| JobSpec { at: a.at, size: a.size, len: a.len, standbys: a.standbys })
                .collect(),
        }
    }

    /// The failure injections a replay carries (empty for live arrival
    /// processes).
    pub fn replay_failures(&self) -> &[ReplayFailure] {
        match &self.arrival {
            ArrivalProcess::Replay { failures, .. } => failures,
            _ => &[],
        }
    }

    /// Is this a replay workload? (Drives the stochastic-clock
    /// silencing in config validation.)
    pub fn is_replay(&self) -> bool {
        matches!(self.arrival, ArrivalProcess::Replay { .. })
    }

    /// Resolve the shape of one arrival at `at`: a weighted class draw
    /// when classes are configured, else the `size == 0` sentinel that
    /// makes `Job::shape` read `Params` (bit-identical arithmetic to
    /// the homogeneous path).
    fn draw_class(&self, p: &Params, rng: &mut Rng, at: Time) -> JobSpec {
        if self.classes.is_empty() {
            return JobSpec { at, size: 0, len: p.job_len, standbys: 0 };
        }
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut x = rng.next_f64() * total;
        let mut chosen = &self.classes[self.classes.len() - 1];
        for c in &self.classes {
            if x < c.weight {
                chosen = c;
                break;
            }
            x -= c.weight;
        }
        JobSpec {
            at,
            size: chosen.job_size.unwrap_or(p.job_size).max(1),
            len: chosen.job_len.unwrap_or(p.job_len),
            standbys: chosen.warm_standbys.unwrap_or(p.warm_standbys),
        }
    }
}

/// Parse an empirical inter-arrival file: one non-negative gap (minutes)
/// per line; blank lines and `#` comments are skipped. Errors name the
/// offending 1-based line.
pub fn parse_empirical(text: &str) -> Result<Vec<Time>, String> {
    let mut gaps = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let gap: f64 = line
            .parse()
            .map_err(|_| format!("line {}: not a number: `{line}`", i + 1))?;
        if !gap.is_finite() || gap < 0.0 {
            return Err(format!("line {}: inter-arrival must be finite and >= 0, got {gap}", i + 1));
        }
        gaps.push(gap);
    }
    if gaps.is_empty() {
        return Err("empirical inter-arrival file holds no samples".into());
    }
    Ok(gaps)
}

/// Parse a `--trace-out` NDJSON capture into replayable events: every
/// `job_arrival` and `failure` line is lifted, all other events are
/// ignored (they are *consequences* the replayed run re-derives). Errors
/// name the offending 1-based line.
pub fn parse_replay(text: &str) -> Result<(Vec<ReplayArrival>, Vec<ReplayFailure>), String> {
    let mut arrivals = Vec::new();
    let mut failures = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let doc = crate::testkit::parse_json(line)
            .map_err(|e| format!("line {}: {e}", i + 1))?;
        let Json::Obj(fields) = &doc else {
            return Err(format!("line {}: expected a JSON object", i + 1));
        };
        let get = |key: &str| fields.iter().find(|(k, _)| k == key).map(|(_, v)| v);
        let num = |key: &str| -> Result<f64, String> {
            match get(key) {
                Some(Json::Num(n)) => Ok(*n),
                _ => Err(format!("line {}: missing numeric `{key}`", i + 1)),
            }
        };
        let Some(Json::Str(event)) = get("event") else {
            continue; // summary/header lines of --format ndjson
        };
        match event.as_str() {
            "job_arrival" => arrivals.push(ReplayArrival {
                at: num("at")?,
                size: num("size")? as u32,
                len: num("len")?,
                standbys: num("standbys")? as u32,
            }),
            "failure" => failures.push(ReplayFailure {
                at: num("at")?,
                server: num("server")? as ServerId,
                systematic: matches!(get("systematic"), Some(Json::Bool(true))),
            }),
            _ => {}
        }
    }
    arrivals.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    failures.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    Ok((arrivals, failures))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate: f64) -> WorkloadSpec {
        WorkloadSpec { arrival: ArrivalProcess::Poisson { rate }, classes: vec![] }
    }

    #[test]
    fn poisson_plan_is_sorted_and_sized() {
        let mut p = Params::small_test();
        p.num_jobs = 20;
        let mut rng = Rng::new(1);
        let plan = poisson(0.01).plan(&p, &mut rng);
        assert_eq!(plan.len(), 20);
        for w in plan.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(plan[0].at > 0.0, "first gap is drawn too");
        assert!(plan.iter().all(|s| s.size == 0 && s.len == p.job_len));
    }

    #[test]
    fn poisson_gaps_have_the_right_mean() {
        let mut p = Params::small_test();
        p.num_jobs = 20_000;
        let rate = 0.05;
        let mut rng = Rng::new(2);
        let plan = poisson(rate).plan(&p, &mut rng);
        let mean_gap = plan.last().unwrap().at / plan.len() as f64;
        assert!((mean_gap - 1.0 / rate).abs() / (1.0 / rate) < 0.03, "mean {mean_gap}");
    }

    #[test]
    fn zero_rate_means_all_at_t0() {
        let mut p = Params::small_test();
        p.num_jobs = 5;
        let mut rng = Rng::new(3);
        let plan = poisson(0.0).plan(&p, &mut rng);
        assert!(plan.iter().all(|s| s.at == 0.0));
    }

    #[test]
    fn empirical_gaps_cycle() {
        let mut p = Params::small_test();
        p.num_jobs = 5;
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Empirical {
                file: "gaps.txt".into(),
                gaps: vec![10.0, 20.0],
            },
            classes: vec![],
        };
        let mut rng = Rng::new(4);
        let plan = spec.plan(&p, &mut rng);
        let ats: Vec<f64> = plan.iter().map(|s| s.at).collect();
        assert_eq!(ats, vec![10.0, 30.0, 40.0, 60.0, 70.0]);
    }

    #[test]
    fn classes_are_drawn_by_weight() {
        let mut p = Params::small_test();
        p.num_jobs = 10_000;
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Poisson { rate: 1.0 },
            classes: vec![
                JobClass {
                    weight: 3.0,
                    job_size: Some(8),
                    job_len: None,
                    warm_standbys: Some(1),
                },
                JobClass { weight: 1.0, job_size: Some(32), job_len: Some(99.0), warm_standbys: None },
            ],
        };
        let mut rng = Rng::new(5);
        let plan = spec.plan(&p, &mut rng);
        let small = plan.iter().filter(|s| s.size == 8).count();
        let big = plan.iter().filter(|s| s.size == 32).count();
        assert_eq!(small + big, plan.len());
        let frac = small as f64 / plan.len() as f64;
        assert!((frac - 0.75).abs() < 0.02, "weight-3 class frac {frac}");
        // Unset fields fall back to Params.
        let s8 = plan.iter().find(|s| s.size == 8).unwrap();
        assert_eq!((s8.len, s8.standbys), (p.job_len, 1));
        let s32 = plan.iter().find(|s| s.size == 32).unwrap();
        assert_eq!((s32.len, s32.standbys), (99.0, p.warm_standbys));
    }

    #[test]
    fn plan_is_deterministic_per_seed() {
        let mut p = Params::small_test();
        p.num_jobs = 50;
        let spec = poisson(0.02);
        let a = spec.plan(&p, &mut Rng::derived(9, &[WORKLOAD_STREAM]));
        let b = spec.plan(&p, &mut Rng::derived(9, &[WORKLOAD_STREAM]));
        let c = spec.plan(&p, &mut Rng::derived(10, &[WORKLOAD_STREAM]));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn parse_empirical_skips_comments_and_names_bad_lines() {
        let gaps = parse_empirical("# trace\n10\n\n 2.5 \n0\n").unwrap();
        assert_eq!(gaps, vec![10.0, 2.5, 0.0]);
        let err = parse_empirical("1\nbogus\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_empirical("1\n2\n-3\n").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        assert!(parse_empirical("# only comments\n").is_err());
    }

    #[test]
    fn parse_replay_lifts_arrivals_and_failures() {
        let ndjson = concat!(
            r#"{"type":"event","at":0,"event":"job_started"}"#, "\n",
            r#"{"type":"event","at":5,"event":"job_arrival","job":1,"size":8,"len":100,"standbys":2}"#, "\n",
            r#"{"type":"event","at":9.5,"event":"failure","server":3,"systematic":true}"#, "\n",
            r#"{"type":"event","at":2,"event":"failure","server":1,"systematic":false}"#, "\n",
            r#"{"type":"run","seed":42}"#, "\n",
        );
        let (arr, fail) = parse_replay(ndjson).unwrap();
        assert_eq!(arr, vec![ReplayArrival { at: 5.0, size: 8, len: 100.0, standbys: 2 }]);
        // Failures come back time-sorted.
        assert_eq!(
            fail,
            vec![
                ReplayFailure { at: 2.0, server: 1, systematic: false },
                ReplayFailure { at: 9.5, server: 3, systematic: true },
            ]
        );
    }

    #[test]
    fn parse_replay_errors_name_the_line() {
        let err = parse_replay("{\"at\":1}\nnot json\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_replay(r#"{"event":"failure","at":1}"#).unwrap_err();
        assert!(err.contains("server"), "{err}");
    }

    #[test]
    fn replay_plan_ignores_rng() {
        let mut p = Params::small_test();
        p.num_jobs = 1;
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Replay {
                file: "t.ndjson".into(),
                arrivals: vec![ReplayArrival { at: 7.0, size: 4, len: 50.0, standbys: 0 }],
                failures: vec![ReplayFailure { at: 9.0, server: 0, systematic: false }],
            },
            classes: vec![],
        };
        let plan = spec.plan(&p, &mut Rng::new(1));
        assert_eq!(plan, vec![JobSpec { at: 7.0, size: 4, len: 50.0, standbys: 0 }]);
        assert_eq!(spec.replay_failures().len(), 1);
        assert!(spec.is_replay());
    }
}
