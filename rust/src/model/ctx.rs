//! [`SimCtx`] — the shared simulation state every policy subsystem
//! operates through.
//!
//! The context owns the *mechanism* (engine, fleet, pools, jobs, repair
//! shop, RNG, outputs, trace); the *policy* lives in the trait objects of
//! [`crate::model::policy::PolicySet`]. Keeping the two in separate
//! structs is what lets a `&mut dyn` policy borrow the whole context
//! mutably while the event loop in [`crate::model::cluster`] stays thin.
//!
//! `SimCtx::reset` re-initializes a context *in place*, reusing the
//! event-heap, fleet, pool free-list, and job allocations — the batched
//! replication runner ([`crate::model::cluster::ReplicationRunner`])
//! leans on this to amortize allocations across thousands of sweep
//! replications.

use crate::config::Params;
use crate::model::events::Ev;
use crate::model::job::{Job, JobPhase};
use crate::model::outputs::RunOutputs;
use crate::model::pool::Pools;
use crate::model::repair::RepairShop;
use crate::model::server::{build_fleet_into, Server, ServerState};
use crate::serve::cache::WarmHandle;
use crate::model::topology::Topology;
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::Time;
use crate::stats::quantile::P2Quantile;
use crate::trace::{Observer, Trace, TraceKind};

/// Shared mutable state of one simulation run.
pub struct SimCtx {
    pub p: Params,
    pub engine: Engine<Ev>,
    pub rng: Rng,
    pub fleet: Vec<Server>,
    pub pools: Pools,
    pub jobs: Vec<Job>,
    pub shop: RepairShop,
    pub out: RunOutputs,
    /// The fleet's failure-domain hierarchy, when `params.topology` is
    /// configured (consumed by topology-aware selection policies and the
    /// correlated failure model). `None` = topologically anonymous fleet.
    pub topo: Option<Topology>,
    pub trace: Option<Trace>,
    /// Pluggable event observer ([`crate::trace::Observer`]): sees every
    /// traced decision point as it happens. `None` by default — the hot
    /// path pays one branch, no allocation, no draw-order impact.
    pub observer: Option<Box<dyn Observer>>,
    /// Sum of running-burst lengths (drives `avg_run_duration`).
    pub burst_sum: Time,
    /// Number of running bursts observed.
    pub burst_count: u64,
    /// Scratch id buffer reused by fleet construction.
    pub scratch_ids: Vec<u32>,

    // ---- admission-queue scratch (workload subsystem) ----
    /// Jobs arrived but not yet admitted (current queue depth).
    pub queued_now: u64,
    /// Streaming median of admission waits (copied out in `finalize`).
    pub wait_p50: P2Quantile,
    /// Streaming p99 of admission waits.
    pub wait_p99: P2Quantile,
}

impl SimCtx {
    /// Build a fresh context for `p`, seeded with `rng`.
    pub fn new(p: &Params, rng: Rng) -> SimCtx {
        Self::new_warm(p, rng, None)
    }

    /// Build a fresh context, routing fleet/topology construction through
    /// a serve-layer warm cache when one is supplied (`None` = cold build,
    /// the CLI path — byte-identical either way).
    pub fn new_warm(p: &Params, rng: Rng, warm: Option<&WarmHandle>) -> SimCtx {
        let mut ctx = SimCtx {
            p: p.clone(),
            engine: Engine::new(),
            rng: Rng::new(0),
            fleet: Vec::new(),
            pools: Pools::default(),
            jobs: Vec::new(),
            shop: RepairShop::new(),
            out: RunOutputs::default(),
            topo: None,
            trace: None,
            observer: None,
            burst_sum: 0.0,
            burst_count: 0,
            scratch_ids: Vec::new(),
            queued_now: 0,
            wait_p50: P2Quantile::new(0.5),
            wait_p99: P2Quantile::new(0.99),
        };
        ctx.reset_warm(p, rng, warm);
        ctx
    }

    /// Re-initialize in place for a new run, reusing every allocation the
    /// previous run left behind (event heap, fleet vector, pool
    /// free-lists, job server-lists, repair queues).
    pub fn reset(&mut self, p: &Params, rng: Rng) {
        self.reset_warm(p, rng, None)
    }

    /// [`SimCtx::reset`] with the fleet and topology builds routed
    /// through a warm cache when one is supplied. A fleet-cache hit
    /// restores both the fleet and the RNG's stream position, so warm
    /// runs continue byte-identically to cold ones.
    pub fn reset_warm(&mut self, p: &Params, mut rng: Rng, warm: Option<&WarmHandle>) {
        // Same draw order as a fresh construction: the fleet's bad-set
        // shuffle consumes the stream first.
        match warm {
            Some(h) => h.fetch_fleet(p, &mut rng, &mut self.fleet, &mut self.scratch_ids),
            None => build_fleet_into(p, &mut rng, &mut self.fleet, &mut self.scratch_ids),
        }
        self.pools.rebuild(&self.fleet);
        let n_jobs = p.num_jobs.max(1) as usize;
        self.jobs.truncate(n_jobs);
        for (j, job) in self.jobs.iter_mut().enumerate() {
            job.reset(j as u32, p.job_len);
        }
        for j in self.jobs.len()..n_jobs {
            self.jobs.push(Job::with_id(j as u32, p.job_len));
        }
        self.engine.reset(p.job_size as usize + 64);
        self.shop.reset();
        self.topo = match warm {
            Some(h) => h.fetch_topology(p),
            None => p.topology.as_ref().map(|s| Topology::build(s, p.total_servers())),
        };
        self.out = RunOutputs::default();
        self.trace = None;
        self.observer = None;
        self.burst_sum = 0.0;
        self.burst_count = 0;
        self.queued_now = 0;
        self.wait_p50 = P2Quantile::new(0.5);
        self.wait_p99 = P2Quantile::new(0.99);
        self.rng = rng;
        self.p = p.clone();
    }

    /// Emit one traced decision point at the current simulation time to
    /// the trace buffer and/or the installed observer (no-op when both
    /// are off — two branches on the hot path, nothing else).
    #[inline]
    pub fn tr(&mut self, kind: TraceKind) {
        if self.trace.is_none() && self.observer.is_none() {
            return;
        }
        let at = self.engine.now();
        if let Some(o) = &mut self.observer {
            o.observe(at, &kind);
        }
        if let Some(t) = &mut self.trace {
            t.push(at, kind);
        }
    }

    /// Current simulation time.
    #[inline]
    pub fn now(&self) -> Time {
        self.engine.now()
    }

    /// Have all jobs finished?
    pub fn all_done(&self) -> bool {
        self.jobs.iter().all(|j| j.phase == JobPhase::Done)
    }

    /// Fill the derived output fields at end of run.
    pub fn finalize(&mut self) {
        if self.all_done() {
            self.out.completed = true;
            self.out.makespan = self
                .out
                .per_job_makespans
                .iter()
                .cloned()
                .fold(0.0f64, f64::max);
        } else {
            // Horizon hit with at least one job unfinished.
            self.out.completed = false;
            self.out.makespan = self.p.max_sim_time;
            for j in &self.jobs {
                // Jobs that never arrived are not in the system: no stall.
                if j.phase == JobPhase::Stalled && j.arrived {
                    self.out.stall_time += self.p.max_sim_time - j.stalled_since;
                }
                // Horizon cut for still-queued arrivals: their censored
                // wait counts, so `queue_wait_total` stays the exact
                // time-integral of the queue depth (Little's law).
                if j.arrived && !j.admitted {
                    self.out.queue_wait_total += self.p.max_sim_time - j.arrived_at;
                }
                // Still down from a correlated outage at the horizon.
                if let Some(t) = j.domain_down_since {
                    self.out.domain_downtime += self.p.max_sim_time - t;
                }
            }
            self.tr(TraceKind::Horizon);
        }
        self.out.work_done = self
            .jobs
            .iter()
            .map(|j| (j.len - j.remaining).max(0.0))
            .sum();
        self.out.queue_wait_p50 = self.wait_p50.value();
        self.out.queue_wait_p99 = self.wait_p99.value();
        self.out.preemptions = self.pools.preemptions;
        self.out.preemption_cost = self.pools.preemption_cost_total;
        self.out.repairs_auto = self.shop.completed_auto;
        self.out.repairs_manual = self.shop.completed_manual;
        self.out.avg_run_duration = if self.burst_count > 0 {
            self.burst_sum / self.burst_count as f64
        } else {
            0.0
        };
        self.out.events_delivered = self.engine.delivered();
        self.out.events_scheduled = self.engine.scheduled();
    }

    /// Server-conservation invariant: every server is in exactly one
    /// logical place and the counts add up to the fleet size.
    pub fn conservation_ok(&self) -> bool {
        let mut counts = [0usize; 9];
        for s in &self.fleet {
            let i = match s.state {
                ServerState::WorkingIdle => 0,
                ServerState::JobActive => 1,
                ServerState::JobStandby => 2,
                ServerState::SparePool => 3,
                ServerState::SpareTransit => 4,
                ServerState::AutoRepair => 5,
                ServerState::ManualRepair => 6,
                ServerState::RepairQueued => 7,
                ServerState::Retired => 8,
            };
            counts[i] += 1;
        }
        let total: usize = counts.iter().sum();
        let active: usize = self.jobs.iter().map(|j| j.active.len()).sum();
        let standby: usize = self.jobs.iter().map(|j| j.standbys.len()).sum();
        total == self.fleet.len()
            && counts[0] == self.pools.idle_count()
            && counts[3] == self.pools.spare_count()
            && counts[4] == self.pools.in_transit as usize
            && counts[1] == active
            && counts[2] == standby
            && counts[5] + counts[6] + counts[7] == self.shop.population()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_is_equivalent_to_fresh_construction() {
        let p = Params::small_test();
        let fresh = SimCtx::new(&p, Rng::new(9));

        // Dirty a context with a different configuration, then reset.
        let mut q = Params::small_test();
        q.working_pool = 100;
        q.num_jobs = 3;
        let mut reused = SimCtx::new(&q, Rng::new(1));
        reused.burst_sum = 123.0;
        reused.burst_count = 5;
        // Dirty per-server state the in-place fleet rebuild must scrub.
        reused.fleet[0].failure_times.extend([1.0, 2.0]);
        reused.fleet[0].run_age = 77.0;
        reused.fleet[0].total_failures = 4;
        reused.reset(&p, Rng::new(9));

        assert_eq!(reused.fleet.len(), fresh.fleet.len());
        for (a, b) in reused.fleet.iter().zip(&fresh.fleet) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.is_bad, b.is_bad, "bad set differs at {}", a.id);
            assert_eq!(a.state, b.state);
            assert_eq!(a.home, b.home);
            assert_eq!(a.gen, b.gen);
            assert_eq!(a.assigned_job, b.assigned_job);
            assert_eq!(a.run_age, b.run_age);
            assert_eq!(a.failure_times, b.failure_times);
            assert_eq!(a.total_failures, b.total_failures);
        }
        assert_eq!(reused.jobs.len(), fresh.jobs.len());
        assert_eq!(reused.pools.idle_count(), fresh.pools.idle_count());
        assert_eq!(reused.pools.spare_count(), fresh.pools.spare_count());
        assert_eq!(reused.burst_count, 0);
        assert_eq!(reused.engine.delivered(), 0);
        assert_eq!(reused.engine.pending(), 0);
        // The reset stream continues identically to the fresh one.
        let mut a = reused.rng.clone();
        let mut b = fresh.rng.clone();
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn warm_reset_is_byte_identical_to_cold() {
        let p = Params::small_test();
        let cold = SimCtx::new(&p, Rng::new(9));
        let warm = WarmHandle::new(8);
        let first = SimCtx::new_warm(&p, Rng::new(9), Some(&warm)); // miss
        let hit = SimCtx::new_warm(&p, Rng::new(9), Some(&warm)); // hit
        assert_eq!(warm.stats().fleet_hits, 1);
        assert_eq!(warm.stats().fleet_misses, 1);
        for ctx in [&first, &hit] {
            assert_eq!(ctx.fleet.len(), cold.fleet.len());
            for (a, b) in ctx.fleet.iter().zip(&cold.fleet) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.is_bad, b.is_bad);
                assert_eq!(a.state, b.state);
            }
            // The post-build stream position matches: subsequent draws —
            // i.e. the whole rest of the run — are identical.
            let mut x = ctx.rng.clone();
            let mut y = cold.rng.clone();
            for _ in 0..16 {
                assert_eq!(x.next_u64(), y.next_u64());
            }
        }
    }

    #[test]
    fn conservation_holds_at_rest() {
        let p = Params::small_test();
        let ctx = SimCtx::new(&p, Rng::new(3));
        assert!(ctx.conservation_ok());
        assert!(!ctx.all_done());
    }
}
