//! Paper module 3 — **Scheduler**: host selection and warm standbys.
//!
//! Implements the allocation step of Figure 1: gather the job's surviving
//! allotment, top it up to `job_size + warm_standbys` from working-pool
//! idle servers, and if the *active* requirement still cannot be met,
//! request spare-pool preemptions (the pool charges `waiting_time` before
//! those arrive). The job can start as soon as `job_size` servers are on
//! hand — standbys trickle in later.
//!
//! *Which* idle servers are taken is delegated to the pluggable
//! [`SelectionPolicy`](crate::model::selection::SelectionPolicy)
//! (the paper: "implements different methods of choosing servers").

use crate::config::Params;
use crate::model::events::ServerId;
use crate::model::job::Job;
use crate::model::pool::Pools;
use crate::model::selection::SelectionPolicy;
use crate::model::server::{Server, ServerState};
use crate::model::topology::Topology;
use crate::sim::rng::Rng;

/// Result of one allocation attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllocOutcome {
    /// Servers to preempt from the spare pool (already marked in transit;
    /// the caller schedules their `PreemptArrive` events).
    pub preempted: Vec<ServerId>,
    /// True if the job now has at least `job_size` servers allotted and
    /// can proceed to host selection / recovery.
    pub can_start: bool,
}

/// Top the job's allotment up toward `job_size + warm_standbys`.
///
/// Every taken server enters the job as a *standby*; the caller promotes
/// standbys to active at start-of-run. Preempted spares join on arrival.
pub fn allocate(
    p: &Params,
    policy: &mut dyn SelectionPolicy,
    job: &mut Job,
    pools: &mut Pools,
    fleet: &mut [Server],
    topo: Option<&Topology>,
    rng: &mut Rng,
) -> AllocOutcome {
    let (size, standbys) = job.shape(p);
    let target = (size + standbys) as usize;

    // 1. Working-pool idle servers, chosen by the selection policy.
    while job.allotted() < target {
        match policy.take_idle(job, pools, fleet, topo, rng) {
            Some(id) => {
                let s = &mut fleet[id as usize];
                s.state = ServerState::JobStandby;
                s.assigned_job = Some(job.id);
                job.standbys.push(id);
            }
            None => break,
        }
    }

    // 2. Spare-pool preemptions for the remaining shortfall (incl. what is
    //    already in transit toward us).
    // (`start_preempt` marks each one in-transit, so `in_transit` already
    // covers both earlier requests and the ones issued in this loop.)
    let mut preempted = Vec::new();
    while job.allotted() + (pools.in_transit as usize) < target {
        match pools.start_preempt(fleet, p.preemption_cost) {
            Some(id) => preempted.push(id),
            None => break, // spare pool exhausted: run degraded
        }
    }

    let can_start = job.allotted() >= size as usize;
    AllocOutcome { preempted, can_start }
}

/// Promote standbys until `job_size` servers are active (start-of-run).
/// Returns false if there were not enough.
pub fn activate(p: &Params, job: &mut Job, fleet: &mut [Server]) -> bool {
    let size = job.shape(p).0;
    while job.active.len() < size as usize {
        match job.promote_standby() {
            Some(id) => fleet[id as usize].state = ServerState::JobActive,
            None => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::selection::{FirstFit, Random};
    use crate::model::server::build_fleet;

    fn setup(p: &Params) -> (Job, Pools, Vec<Server>, Rng) {
        let mut rng = Rng::new(42);
        let fleet = build_fleet(p, &mut rng);
        let pools = Pools::from_fleet(&fleet);
        (Job::new(p.job_len), pools, fleet, rng)
    }

    #[test]
    fn initial_allocation_fills_from_working_pool() {
        let p = Params::small_test(); // job 64 + 4 standby, pool 72
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        let out =
            allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert!(out.can_start);
        assert!(out.preempted.is_empty());
        assert_eq!(job.allotted(), 68);
        assert_eq!(pools.idle_count(), 72 - 68);
        for &id in &job.standbys {
            assert_eq!(fleet[id as usize].state, ServerState::JobStandby);
            assert_eq!(fleet[id as usize].assigned_job, Some(0));
        }
    }

    #[test]
    fn shortfall_triggers_preemption() {
        let mut p = Params::small_test();
        p.working_pool = 60; // less than job_size=64
        p.spare_pool = 16;
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        let out =
            allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        // 60 idle taken, 8 preemptions requested (target 68), can't start
        // yet: only 60 on hand < 64.
        assert!(!out.can_start);
        assert_eq!(out.preempted.len(), 8);
        assert_eq!(pools.preemptions, 8);
        assert_eq!(job.allotted(), 60);
    }

    #[test]
    fn degraded_when_everything_exhausted() {
        let mut p = Params::small_test();
        p.working_pool = 50;
        p.spare_pool = 4;
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        let out =
            allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert!(!out.can_start);
        assert_eq!(out.preempted.len(), 4); // all spares taken
        assert_eq!(pools.spare_count(), 0);
    }

    #[test]
    fn no_double_preempt_for_in_transit() {
        let mut p = Params::small_test();
        p.working_pool = 60;
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        let first =
            allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert_eq!(first.preempted.len(), 8);
        // Re-running allocation while 8 are in transit must not preempt more.
        let second =
            allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert!(second.preempted.is_empty());
    }

    #[test]
    fn activate_promotes_to_job_size() {
        let p = Params::small_test();
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        allocate(&p, &mut FirstFit, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert!(activate(&p, &mut job, &mut fleet));
        assert_eq!(job.active.len(), 64);
        assert_eq!(job.standbys.len(), 4);
        for &id in &job.active {
            assert_eq!(fleet[id as usize].state, ServerState::JobActive);
        }
    }

    #[test]
    fn random_policy_allocates_same_count() {
        let p = Params::small_test();
        let (mut job, mut pools, mut fleet, mut rng) = setup(&p);
        let out = allocate(&p, &mut Random, &mut job, &mut pools, &mut fleet, None, &mut rng);
        assert!(out.can_start);
        assert_eq!(job.allotted(), 68);
    }
}
