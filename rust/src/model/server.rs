//! Paper module 1 — **Server**: per-server identity, state machine, and
//! failure clocks.
//!
//! A server is either *good* (random failures only) or *bad*
//! (additional systematic failure process, assumption 1); which one it is
//! is hidden from every policy — only the failure events reveal it, which
//! is exactly the paper's observability model.

use crate::config::Params;
use crate::model::events::{FailureKind, ServerId};
use crate::sim::event::Generation;
use crate::sim::rng::Rng;
use crate::sim::Time;

/// Where a server lives when it is not doing anything for the job.
/// Repaired servers are routed back to their home pool when the job does
/// not reclaim them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Home {
    Working,
    Spare,
}

/// The server state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerState {
    /// In the working pool, idle, immediately allocatable.
    WorkingIdle,
    /// Allocated to the job and actively computing (failure clocks armed).
    JobActive,
    /// Allocated to the job as a warm standby (powered, not computing —
    /// assumption 7: no failure clocks).
    JobStandby,
    /// In the spare pool, running other (unmodeled) workloads.
    SparePool,
    /// Being preempted from the spare pool; arrives after `waiting_time`.
    SpareTransit,
    /// Undergoing automated test & repair.
    AutoRepair,
    /// Undergoing manual repair.
    ManualRepair,
    /// Queued for a repair stage (finite repair-shop capacity extension).
    RepairQueued,
    /// Permanently removed from the cluster (§II-B retirement).
    Retired,
}

/// One server in the fleet.
#[derive(Clone, Debug)]
pub struct Server {
    pub id: ServerId,
    /// Hidden systematic-failure-prone identity.
    pub is_bad: bool,
    pub state: ServerState,
    pub home: Home,
    /// Generation for lazy cancellation of in-flight failure events.
    pub gen: Generation,
    /// The job this server is allotted to (active or standby), if any;
    /// repaired servers return to *their* job without host selection
    /// (§II-B "a server is returned to the job after repair if it was
    /// originally assigned to the same job").
    pub assigned_job: Option<u32>,
    /// Accumulated *running* age since the last repair/renewal — drives
    /// age-conditional sampling for non-exponential failure clocks.
    pub run_age: Time,
    /// When the server last transitioned to JobActive (to accumulate age).
    pub active_since: Time,
    /// Failure timestamps inside the retirement window (module
    /// `retirement` maintains it).
    pub failure_times: Vec<Time>,
    /// Lifetime failure count (stats).
    pub total_failures: u32,
    /// Repair duration drawn at queue-entry time when the active repair
    /// policy ranks by expected repair length (`shortest_first`); taken
    /// by `start_stage` instead of drawing fresh. Always `None` under
    /// policies that do not pre-draw, so their RNG order is untouched.
    pub predrawn_repair: Option<Time>,
}

impl Server {
    pub fn new(id: ServerId, is_bad: bool, home: Home) -> Self {
        let state = match home {
            Home::Working => ServerState::WorkingIdle,
            Home::Spare => ServerState::SparePool,
        };
        Server {
            id,
            is_bad,
            state,
            home,
            gen: Generation::default(),
            assigned_job: None,
            run_age: 0.0,
            active_since: 0.0,
            failure_times: Vec::new(),
            total_failures: 0,
            predrawn_repair: None,
        }
    }

    /// Sample the time-to-next-failure and its kind for a server that just
    /// started computing: the race between the random clock (all servers)
    /// and the systematic clock (bad servers only).
    ///
    /// For non-exponential families the draw is conditioned on the
    /// accumulated running age (renewal at repair).
    pub fn sample_failure(&self, p: &Params, rng: &mut Rng) -> (Time, FailureKind) {
        let d_rand = p.failure_dist.with_rate(p.random_failure_rate);
        let t_rand = d_rand.sample_remaining(rng, self.run_age);
        if self.is_bad {
            let d_sys = p.failure_dist.with_rate(p.systematic_failure_rate);
            let t_sys = d_sys.sample_remaining(rng, self.run_age);
            if t_sys < t_rand {
                return (t_sys, FailureKind::Systematic);
            }
        }
        (t_rand, FailureKind::Random)
    }

    /// Is the server currently armed with failure clocks?
    pub fn is_computing(&self) -> bool {
        self.state == ServerState::JobActive
    }

    /// Renewal after a completed repair: age resets (tests/repairs restore
    /// the server to a known-fresh condition at the abstraction level of
    /// assumption 3).
    pub fn renew(&mut self) {
        self.run_age = 0.0;
    }

    /// Re-stamp this server as factory-fresh in place, keeping the
    /// `failure_times` allocation — the fleet-build fast path for batched
    /// replication runs.
    fn reset(&mut self, id: ServerId, home: Home) {
        self.id = id;
        self.is_bad = false;
        self.state = match home {
            Home::Working => ServerState::WorkingIdle,
            Home::Spare => ServerState::SparePool,
        };
        self.home = home;
        self.gen = Generation::default();
        self.assigned_job = None;
        self.run_age = 0.0;
        self.active_since = 0.0;
        self.failure_times.clear();
        self.total_failures = 0;
        self.predrawn_repair = None;
    }
}

/// Home pool of server `id` under `p`'s pool split.
fn home_of(p: &Params, id: u32) -> Home {
    if id < p.working_pool {
        Home::Working
    } else {
        Home::Spare
    }
}

/// Build the initial fleet: `working_pool` servers homed Working plus
/// `spare_pool` homed Spare, with `systematic_fraction` of the whole fleet
/// marked bad, chosen uniformly at random (hidden identity).
pub fn build_fleet(p: &Params, rng: &mut Rng) -> Vec<Server> {
    let mut fleet = Vec::new();
    let mut scratch = Vec::new();
    build_fleet_into(p, rng, &mut fleet, &mut scratch);
    fleet
}

/// [`build_fleet`] into caller-owned buffers: `fleet` is cleared and
/// refilled, `scratch` is the id buffer for the bad-set shuffle. The
/// batched replication runner reuses both across runs; the RNG draw
/// order is identical to [`build_fleet`].
///
/// Fast path: servers surviving from the previous run are reset in
/// place — their `failure_times` allocations (the only per-server heap
/// memory) are kept, so a steady-state replication loop allocates
/// nothing here. Field-for-field equivalence with a fresh build is
/// pinned by `rebuild_in_place_equals_fresh_build` below.
pub fn build_fleet_into(
    p: &Params,
    rng: &mut Rng,
    fleet: &mut Vec<Server>,
    scratch: &mut Vec<u32>,
) {
    let total = p.total_servers() as usize;
    let n_bad = ((total as f64) * p.systematic_fraction).round() as usize;
    // Choose the bad set by shuffling ids (drawn before any fleet work so
    // the stream order matches the original implementation exactly).
    scratch.clear();
    scratch.extend(0..total as u32);
    rng.shuffle(scratch);
    fleet.truncate(total);
    for (id, s) in fleet.iter_mut().enumerate() {
        let id = id as u32;
        s.reset(id, home_of(p, id));
    }
    let reused = fleet.len() as u32;
    fleet.extend(
        (reused..total as u32).map(|id| Server::new(id, false, home_of(p, id))),
    );
    for &id in scratch.iter().take(n_bad) {
        fleet[id as usize].is_bad = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_sizes_and_homes() {
        let p = Params::small_test();
        let mut rng = Rng::new(1);
        let fleet = build_fleet(&p, &mut rng);
        assert_eq!(fleet.len(), p.total_servers() as usize);
        let working = fleet.iter().filter(|s| s.home == Home::Working).count();
        let spare = fleet.iter().filter(|s| s.home == Home::Spare).count();
        assert_eq!(working, p.working_pool as usize);
        assert_eq!(spare, p.spare_pool as usize);
        for s in &fleet {
            match s.home {
                Home::Working => assert_eq!(s.state, ServerState::WorkingIdle),
                Home::Spare => assert_eq!(s.state, ServerState::SparePool),
            }
        }
    }

    #[test]
    fn bad_fraction_is_exact_count() {
        let mut p = Params::small_test();
        p.systematic_fraction = 0.25;
        let mut rng = Rng::new(2);
        let fleet = build_fleet(&p, &mut rng);
        let bad = fleet.iter().filter(|s| s.is_bad).count();
        let want = ((p.total_servers() as f64) * 0.25).round() as usize;
        assert_eq!(bad, want);
    }

    #[test]
    fn bad_set_varies_with_seed() {
        let mut p = Params::small_test();
        p.systematic_fraction = 0.3;
        let f1 = build_fleet(&p, &mut Rng::new(1));
        let f2 = build_fleet(&p, &mut Rng::new(2));
        let b1: Vec<u32> = f1.iter().filter(|s| s.is_bad).map(|s| s.id).collect();
        let b2: Vec<u32> = f2.iter().filter(|s| s.is_bad).map(|s| s.id).collect();
        assert_ne!(b1, b2);
    }

    #[test]
    fn good_servers_never_fail_systematically() {
        let p = Params::small_test();
        let s = Server::new(0, false, Home::Working);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let (_, kind) = s.sample_failure(&p, &mut rng);
            assert_eq!(kind, FailureKind::Random);
        }
    }

    #[test]
    fn bad_servers_fail_mostly_systematically() {
        let p = Params::small_test(); // systematic rate = 5x random
        let s = Server::new(0, true, Home::Working);
        let mut rng = Rng::new(4);
        let n = 10_000;
        let sys = (0..n)
            .filter(|_| {
                matches!(s.sample_failure(&p, &mut rng).1, FailureKind::Systematic)
            })
            .count();
        // Race of Exp(r) vs Exp(5r): P(systematic wins) = 5/6.
        let frac = sys as f64 / n as f64;
        assert!((frac - 5.0 / 6.0).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn failure_race_mean_rate() {
        // Bad server: min of the two exponential clocks ~ Exp(r_r + r_s).
        let p = Params::small_test();
        let s = Server::new(0, true, Home::Working);
        let mut rng = Rng::new(5);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| s.sample_failure(&p, &mut rng).0).sum::<f64>() / n as f64;
        let want = 1.0 / (p.random_failure_rate + p.systematic_failure_rate);
        assert!((mean - want).abs() / want < 0.03, "mean={mean} want={want}");
    }

    #[test]
    fn zero_rates_never_fire() {
        let mut p = Params::small_test();
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        let s = Server::new(0, true, Home::Working);
        let mut rng = Rng::new(6);
        let (t, _) = s.sample_failure(&p, &mut rng);
        assert_eq!(t, f64::INFINITY);
    }

    #[test]
    fn rebuild_in_place_equals_fresh_build() {
        // Dirty every reusable field, then rebuild into the same buffers
        // (including a pool-size change) and compare against a fresh
        // build with the same RNG seed, field by field.
        let mut p = Params::small_test();
        p.systematic_fraction = 0.2;
        let mut fleet = Vec::new();
        let mut scratch = Vec::new();
        build_fleet_into(&p, &mut Rng::new(11), &mut fleet, &mut scratch);
        for s in &mut fleet {
            s.state = ServerState::ManualRepair;
            s.gen.bump();
            s.assigned_job = Some(3);
            s.run_age = 123.0;
            s.active_since = 45.0;
            s.failure_times.extend([1.0, 2.0, 3.0]);
            s.total_failures = 9;
            s.predrawn_repair = Some(42.0);
        }
        p.spare_pool += 4; // grow: exercises the extend tail
        build_fleet_into(&p, &mut Rng::new(12), &mut fleet, &mut scratch);
        let fresh = build_fleet(&p, &mut Rng::new(12));
        assert_eq!(fleet.len(), fresh.len());
        for (a, b) in fleet.iter().zip(&fresh) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.is_bad, b.is_bad, "server {}", a.id);
            assert_eq!(a.state, b.state);
            assert_eq!(a.home, b.home);
            assert_eq!(a.gen, b.gen);
            assert_eq!(a.assigned_job, b.assigned_job);
            assert_eq!(a.run_age, b.run_age);
            assert_eq!(a.active_since, b.active_since);
            assert_eq!(a.failure_times, b.failure_times);
            assert_eq!(a.total_failures, b.total_failures);
            assert_eq!(a.predrawn_repair, b.predrawn_repair);
        }
        // Shrink path too.
        p.spare_pool -= 6;
        build_fleet_into(&p, &mut Rng::new(13), &mut fleet, &mut scratch);
        let fresh = build_fleet(&p, &mut Rng::new(13));
        assert_eq!(fleet.len(), fresh.len());
        let bad = |f: &[Server]| f.iter().filter(|s| s.is_bad).count();
        assert_eq!(bad(&fleet), bad(&fresh));
    }

    #[test]
    fn renew_resets_age() {
        let mut s = Server::new(0, false, Home::Working);
        s.run_age = 500.0;
        s.renew();
        assert_eq!(s.run_age, 0.0);
    }
}
