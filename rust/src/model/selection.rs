//! Host-selection policies ("implements different methods of choosing
//! servers", paper module 3).
//!
//! A [`SelectionPolicy`] decides *which* idle working-pool server the
//! scheduler takes next when topping a job's allotment up. Policies are
//! selected by name ([`crate::model::policy`]) so scenarios and sweeps
//! can compare them without code changes:
//!
//! | name | policy |
//! |---|---|
//! | `first_fit` | [`FirstFit`] — LIFO free-list (cache-warm, default) |
//! | `random`    | [`Random`] — uniform over the idle list |
//! | `locality`  | [`Locality`] — nearest id to the job's gang (rack proxy) |

use crate::model::events::ServerId;
use crate::model::job::Job;
use crate::model::pool::Pools;
use crate::model::server::Server;
use crate::sim::rng::Rng;

/// Pick-one-idle-server policy over the working pool's free-list.
pub trait SelectionPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Pick and remove one idle working-pool server for `job`.
    /// Returns `None` when the idle list is empty.
    fn take_idle(
        &mut self,
        job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        rng: &mut Rng,
    ) -> Option<ServerId>;
}

/// Take idle servers in LIFO order (cheapest; the default). The most
/// recently freed server is the most likely to still be cache/NCCL-warm.
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstFit;

impl SelectionPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first_fit"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        pools.take_idle(fleet)
    }
}

/// Sample idle servers uniformly (spreads load over the fleet — relevant
/// with retirement/regeneration, where placement history correlates with
/// badness).
#[derive(Clone, Copy, Debug, Default)]
pub struct Random;

impl SelectionPolicy for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        rng: &mut Rng,
    ) -> Option<ServerId> {
        // Uniform choice = swap a random element to the back, then pop.
        let n = pools.idle_count();
        if n == 0 {
            return None;
        }
        let k = rng.next_below(n as u64) as usize;
        pools.swap_idle_to_back(k);
        pools.take_idle(fleet)
    }
}

/// Prefer the idle server whose id is numerically closest to the job's
/// existing gang. Server ids are assigned rack-contiguously at fleet
/// construction, so id distance is a locality proxy: a tight id range
/// approximates fewer network hops for the gang's collectives.
#[derive(Clone, Copy, Debug, Default)]
pub struct Locality;

impl SelectionPolicy for Locality {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn take_idle(
        &mut self,
        job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        // Anchor on the job's first allotted server; with no allotment yet
        // fall back to LIFO (the first pick seeds the neighborhood).
        let anchor = match job.active.first().or_else(|| job.standbys.first()) {
            Some(&id) => id,
            None => return pools.take_idle(fleet),
        };
        let idle = pools.idle_ids();
        if idle.is_empty() {
            return None;
        }
        let mut best = 0usize;
        let mut best_d = u32::MAX;
        for (k, &id) in idle.iter().enumerate() {
            let d = id.abs_diff(anchor);
            if d < best_d {
                best = k;
                best_d = d;
            }
        }
        pools.swap_idle_to_back(best);
        pools.take_idle(fleet)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Params;
    use crate::model::server::build_fleet;

    fn setup() -> (Job, Pools, Vec<Server>, Rng) {
        let p = Params::small_test();
        let mut rng = Rng::new(42);
        let fleet = build_fleet(&p, &mut rng);
        let pools = Pools::from_fleet(&fleet);
        (Job::new(p.job_len), pools, fleet, rng)
    }

    #[test]
    fn first_fit_takes_lifo() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let top = *pools.idle_ids().last().unwrap();
        let got = FirstFit.take_idle(&job, &mut pools, &mut fleet, &mut rng);
        assert_eq!(got, Some(top));
    }

    #[test]
    fn random_takes_every_server_eventually() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let n = pools.idle_count();
        let mut seen = Vec::new();
        let mut pol = Random;
        while let Some(id) = pol.take_idle(&job, &mut pools, &mut fleet, &mut rng) {
            seen.push(id);
        }
        assert_eq!(seen.len(), n);
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n, "a server was taken twice");
    }

    #[test]
    fn locality_prefers_nearest_id() {
        let (mut job, mut pools, mut fleet, mut rng) = setup();
        // Seed the gang with server 30: the nearest idle id must be next.
        let mut pol = Locality;
        job.active.push(30);
        // Remove 30 from the idle list so distances are well-defined.
        let k = pools.idle_ids().iter().position(|&id| id == 30).unwrap();
        pools.swap_idle_to_back(k);
        assert_eq!(pools.take_idle(&mut fleet), Some(30));

        let got = pol.take_idle(&job, &mut pools, &mut fleet, &mut rng).unwrap();
        assert!(got == 29 || got == 31, "nearest to 30, got {got}");
    }

    #[test]
    fn locality_without_anchor_falls_back_to_lifo() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let top = *pools.idle_ids().last().unwrap();
        let got = Locality.take_idle(&job, &mut pools, &mut fleet, &mut rng);
        assert_eq!(got, Some(top));
    }

    #[test]
    fn exhausted_pool_returns_none() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        while pools.take_idle(&mut fleet).is_some() {}
        assert!(FirstFit.take_idle(&job, &mut pools, &mut fleet, &mut rng).is_none());
        assert!(Random.take_idle(&job, &mut pools, &mut fleet, &mut rng).is_none());
        assert!(Locality.take_idle(&job, &mut pools, &mut fleet, &mut rng).is_none());
    }
}
