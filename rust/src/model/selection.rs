//! Host-selection policies ("implements different methods of choosing
//! servers", paper module 3).
//!
//! A [`SelectionPolicy`] decides *which* idle working-pool server the
//! scheduler takes next when topping a job's allotment up. Policies are
//! selected by name ([`crate::model::policy`]) so scenarios and sweeps
//! can compare them without code changes:
//!
//! | name | policy |
//! |---|---|
//! | `first_fit` | [`FirstFit`] — LIFO free-list (cache-warm, default) |
//! | `random`    | [`Random`] — uniform over the idle list |
//! | `locality`  | [`Locality`] — pack within failure domains (id proximity when no topology) |
//! | `anti_affinity` | [`AntiAffinity`] — spread the gang across failure domains |
//! | `power_of_two_choices` | [`PowerOfTwoChoices`] — sample 2, keep the less failure-prone |
//! | `history_scored` | [`HistoryScored`] — fewest failures within `selection_history_window` |
//!
//! Topology-aware policies read the fleet's failure-domain hierarchy
//! ([`crate::model::topology::Topology`], threaded through from
//! [`crate::model::ctx::SimCtx`]); with no `topology:` configured they
//! degrade exactly to their pre-topology behavior (`locality`) or are
//! rejected at build time (`anti_affinity`).

use crate::model::events::ServerId;
use crate::model::job::Job;
use crate::model::pool::Pools;
use crate::model::server::Server;
use crate::model::topology::Topology;
use crate::sim::rng::Rng;

/// Pick-one-idle-server policy over the working pool's free-list.
pub trait SelectionPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Pick and remove one idle working-pool server for `job`.
    /// Returns `None` when the idle list is empty.
    fn take_idle(
        &mut self,
        job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        topo: Option<&Topology>,
        rng: &mut Rng,
    ) -> Option<ServerId>;
}

/// Take idle servers in LIFO order (cheapest; the default). The most
/// recently freed server is the most likely to still be cache/NCCL-warm.
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstFit;

impl SelectionPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first_fit"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _topo: Option<&Topology>,
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        pools.take_idle(fleet)
    }
}

/// Sample idle servers uniformly (spreads load over the fleet — relevant
/// with retirement/regeneration, where placement history correlates with
/// badness).
#[derive(Clone, Copy, Debug, Default)]
pub struct Random;

impl SelectionPolicy for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _topo: Option<&Topology>,
        rng: &mut Rng,
    ) -> Option<ServerId> {
        // Uniform choice = swap a random element to the back, then pop.
        let n = pools.idle_count();
        if n == 0 {
            return None;
        }
        let k = rng.next_below(n as u64) as usize;
        pools.swap_idle_to_back(k);
        pools.take_idle(fleet)
    }
}

/// Pack the gang: prefer the idle server topologically closest to the
/// job's existing allotment — same rack first, then same switch, and so
/// on up the domain hierarchy (ties broken by id proximity). Tight
/// packing means fewer network hops for the gang's collectives — and the
/// maximum exposure to a single domain outage (the comparison
/// `anti_affinity` exists to make).
///
/// With no `topology:` configured this is exactly the pre-topology
/// id-proximity policy (server ids are assigned domain-contiguously, so
/// id distance was always a domain proxy): byte-identical picks.
#[derive(Clone, Copy, Debug, Default)]
pub struct Locality;

impl SelectionPolicy for Locality {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn take_idle(
        &mut self,
        job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        topo: Option<&Topology>,
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        // Anchor on the job's first allotted server; with no allotment yet
        // fall back to LIFO (the first pick seeds the neighborhood).
        let anchor = match job.active.first().or_else(|| job.standbys.first()) {
            Some(&id) => id,
            None => return pools.take_idle(fleet),
        };
        let idle = pools.idle_ids();
        if idle.is_empty() {
            return None;
        }
        // Minimize (domain distance, id distance); first minimum wins.
        // Without a topology every domain distance is 0 and the key
        // reduces to the legacy id-proximity scan.
        let mut best = 0usize;
        let mut best_key = (usize::MAX, u32::MAX);
        for (k, &id) in idle.iter().enumerate() {
            let dist = topo.map_or(0, |t| t.distance(id, anchor));
            let key = (dist, id.abs_diff(anchor));
            if key < best_key {
                best = k;
                best_key = key;
            }
        }
        pools.swap_idle_to_back(best);
        pools.take_idle(fleet)
    }
}

/// Spread the gang: prefer the idle server whose failure domains hold the
/// fewest of the job's current allotment, comparing the *largest* blast
/// radius first (topmost level, e.g. switch) and descending to racks on
/// ties. Decorrelates the gang from single-domain outages: a struck
/// domain hits few enough of the job's servers that warm standbys absorb
/// the blast. Requires a configured `topology:` (enforced at policy
/// build); ties break in idle-list order, so picks stay deterministic.
#[derive(Clone, Copy, Debug, Default)]
pub struct AntiAffinity;

impl SelectionPolicy for AntiAffinity {
    fn name(&self) -> &'static str {
        "anti_affinity"
    }

    fn take_idle(
        &mut self,
        job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        topo: Option<&Topology>,
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        let Some(t) = topo else {
            // Unreachable via the policy registry (build requires a
            // topology); LIFO keeps direct construction total.
            return pools.take_idle(fleet);
        };
        let idle = pools.idle_ids();
        if idle.is_empty() {
            return None;
        }
        // Per-level occupancy of the job's current allotment (active +
        // standbys), computed once per pick: O(gang × levels + idle).
        let counts: Vec<Vec<u32>> = t
            .levels()
            .iter()
            .enumerate()
            .map(|(l, level)| {
                let mut c = vec![0u32; level.n_domains as usize];
                for &id in job.active.iter().chain(job.standbys.iter()) {
                    c[t.domain_of(l, id) as usize] += 1;
                }
                c
            })
            .collect();
        // Least-loaded domain chain, compared top level down; the first
        // strictly-better candidate in idle-list order wins.
        let strictly_better = |a: ServerId, b: ServerId| -> bool {
            for l in (0..t.n_levels()).rev() {
                let ca = counts[l][t.domain_of(l, a) as usize];
                let cb = counts[l][t.domain_of(l, b) as usize];
                if ca != cb {
                    return ca < cb;
                }
            }
            false
        };
        let mut best = 0usize;
        for (k, &id) in idle.iter().enumerate().skip(1) {
            if strictly_better(id, idle[best]) {
                best = k;
            }
        }
        pools.swap_idle_to_back(best);
        pools.take_idle(fleet)
    }
}

/// Power of two choices: sample two idle servers uniformly and keep the
/// one with fewer lifetime failures (ties keep the first sample). The
/// classic load-balancing trick applied to reliability — most of
/// `random`'s spreading, plus a cheap bias away from failure-prone
/// hardware (pairs with retirement and regeneration, where failure
/// history predicts badness). Always consumes exactly two draws, so the
/// stream stays aligned regardless of the pick.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerOfTwoChoices;

impl SelectionPolicy for PowerOfTwoChoices {
    fn name(&self) -> &'static str {
        "power_of_two_choices"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _topo: Option<&Topology>,
        rng: &mut Rng,
    ) -> Option<ServerId> {
        let n = pools.idle_count();
        if n == 0 {
            return None;
        }
        let k1 = rng.next_below(n as u64) as usize;
        let k2 = rng.next_below(n as u64) as usize;
        let idle = pools.idle_ids();
        let pick = if fleet[idle[k2] as usize].total_failures
            < fleet[idle[k1] as usize].total_failures
        {
            k2
        } else {
            k1
        };
        pools.swap_idle_to_back(pick);
        pools.take_idle(fleet)
    }
}

/// Scan the whole idle list and take the server with the fewest recorded
/// failures inside the sliding `selection_history_window` (the same
/// per-server `failure_times` log retirement counts over, pruned as
/// failures land; with retirement also enabled the log is pruned to the
/// larger of the two windows). Ties keep the most recently freed candidate
/// — so a fresh fleet behaves exactly like `first_fit` (LIFO,
/// cache-warm) and the bias only kicks in once history accumulates.
/// Deterministic and draw-free: the RNG stream position is untouched,
/// so runs pair exactly with `first_fit` under CRN.
///
/// Requires `selection_history_window > 0` (enforced at policy build):
/// with a zero window no failures are ever retained and the scan would
/// silently degrade to LIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistoryScored;

impl SelectionPolicy for HistoryScored {
    fn name(&self) -> &'static str {
        "history_scored"
    }

    fn take_idle(
        &mut self,
        _job: &Job,
        pools: &mut Pools,
        fleet: &mut [Server],
        _topo: Option<&Topology>,
        _rng: &mut Rng,
    ) -> Option<ServerId> {
        let idle = pools.idle_ids();
        if idle.is_empty() {
            return None;
        }
        // Back-to-front scan with a strict `<`: the last (most recently
        // freed) holder of the minimum score wins ties.
        let mut best = idle.len() - 1;
        let mut best_score = fleet[idle[best] as usize].failure_times.len();
        for k in (0..idle.len() - 1).rev() {
            let score = fleet[idle[k] as usize].failure_times.len();
            if score < best_score {
                best = k;
                best_score = score;
            }
        }
        pools.swap_idle_to_back(best);
        pools.take_idle(fleet)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Params, TopologyLevelSpec, TopologySpec};
    use crate::model::server::build_fleet;

    fn setup() -> (Job, Pools, Vec<Server>, Rng) {
        let p = Params::small_test();
        let mut rng = Rng::new(42);
        let fleet = build_fleet(&p, &mut rng);
        let pools = Pools::from_fleet(&fleet);
        (Job::new(p.job_len), pools, fleet, rng)
    }

    fn rack_switch_topo(total: u32) -> Topology {
        let spec = TopologySpec {
            levels: vec![
                TopologyLevelSpec { name: "rack".into(), size: 4, outage_rate: 0.0 },
                TopologyLevelSpec { name: "switch".into(), size: 2, outage_rate: 0.0 },
            ],
        };
        Topology::build(&spec, total)
    }

    #[test]
    fn first_fit_takes_lifo() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let top = *pools.idle_ids().last().unwrap();
        let got = FirstFit.take_idle(&job, &mut pools, &mut fleet, None, &mut rng);
        assert_eq!(got, Some(top));
    }

    #[test]
    fn random_takes_every_server_eventually() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let n = pools.idle_count();
        let mut seen = Vec::new();
        let mut pol = Random;
        while let Some(id) = pol.take_idle(&job, &mut pools, &mut fleet, None, &mut rng) {
            seen.push(id);
        }
        assert_eq!(seen.len(), n);
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n, "a server was taken twice");
    }

    #[test]
    fn locality_prefers_nearest_id() {
        let (mut job, mut pools, mut fleet, mut rng) = setup();
        // Seed the gang with server 30: the nearest idle id must be next.
        let mut pol = Locality;
        job.active.push(30);
        // Remove 30 from the idle list so distances are well-defined.
        let k = pools.idle_ids().iter().position(|&id| id == 30).unwrap();
        pools.swap_idle_to_back(k);
        assert_eq!(pools.take_idle(&mut fleet), Some(30));

        let got = pol.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).unwrap();
        assert!(got == 29 || got == 31, "nearest to 30, got {got}");
    }

    #[test]
    fn locality_with_topology_prefers_same_rack_over_nearer_id() {
        let (mut job, mut pools, mut fleet, mut rng) = setup();
        let topo = rack_switch_topo(fleet.len() as u32);
        // Anchor in rack 1 (ids 4..8). Leave exactly ids 3 and 7 idle:
        // id 3 is numerically closer to the anchor 4, but id 7 shares the
        // rack — the domain-true policy must take 7.
        let mut pol = Locality;
        job.active.push(4);
        let keep = [3u32, 7u32];
        let all: Vec<ServerId> = pools.idle_ids().to_vec();
        for id in all {
            if !keep.contains(&id) {
                assert!(pools.remove_idle(id));
            }
        }
        let got =
            pol.take_idle(&job, &mut pools, &mut fleet, Some(&topo), &mut rng).unwrap();
        assert_eq!(got, 7, "same-rack beats nearer id");
        // Without the topology, the same layout picks the nearer id 3.
        let (mut job2, mut pools2, mut fleet2, mut rng2) = setup();
        job2.active.push(4);
        let all: Vec<ServerId> = pools2.idle_ids().to_vec();
        for id in all {
            if !keep.contains(&id) {
                assert!(pools2.remove_idle(id));
            }
        }
        let got = pol.take_idle(&job2, &mut pools2, &mut fleet2, None, &mut rng2).unwrap();
        assert_eq!(got, 3, "legacy id proximity without topology");
    }

    #[test]
    fn locality_without_anchor_falls_back_to_lifo() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let top = *pools.idle_ids().last().unwrap();
        let got = Locality.take_idle(&job, &mut pools, &mut fleet, None, &mut rng);
        assert_eq!(got, Some(top));
    }

    #[test]
    fn anti_affinity_spreads_across_top_domains() {
        let (mut job, mut pools, mut fleet, mut rng) = setup();
        let topo = rack_switch_topo(fleet.len() as u32);
        let mut pol = AntiAffinity;
        // Successive picks must land in distinct switch domains until
        // every domain with an idle server is occupied once (spare-pool
        // servers are not idle, so count reachable domains, not all).
        let mut reachable: Vec<u32> =
            pools.idle_ids().iter().map(|&id| topo.domain_of(1, id)).collect();
        reachable.sort_unstable();
        reachable.dedup();
        let mut seen_domains = Vec::new();
        for _ in 0..reachable.len() {
            let id = pol
                .take_idle(&job, &mut pools, &mut fleet, Some(&topo), &mut rng)
                .unwrap();
            let dom = topo.domain_of(1, id);
            assert!(
                !seen_domains.contains(&dom),
                "pick {id} revisited switch domain {dom} before spreading"
            );
            seen_domains.push(dom);
            job.standbys.push(id);
        }
        // One more pick wraps around to an already-used domain, but the
        // least-occupied one at the rack level.
        let id = pol
            .take_idle(&job, &mut pools, &mut fleet, Some(&topo), &mut rng)
            .unwrap();
        assert_eq!(
            job.standbys
                .iter()
                .filter(|&&s| topo.domain_of(1, s) == topo.domain_of(1, id))
                .count(),
            1,
            "wrap-around joins a singly-occupied domain"
        );
    }

    #[test]
    fn power_of_two_choices_prefers_fewer_failures() {
        // Two idle servers, one failure-free: the clean one wins unless
        // both samples land on the failed one, so P(clean first) = 3/4
        // against 1/2 for uniform random. 200 trials put the two far
        // apart (>5 sigma) for any seed.
        let (job, _, mut fleet, mut rng) = setup();
        let (clean, failed) = (3u32, 20u32);
        fleet[failed as usize].total_failures = 10;
        let mut pol = PowerOfTwoChoices;
        let mut clean_first = 0;
        for _ in 0..200 {
            let mut pools = Pools::from_fleet(&fleet);
            let all: Vec<ServerId> = pools.idle_ids().to_vec();
            for id in all {
                if id != clean && id != failed {
                    assert!(pools.remove_idle(id));
                }
            }
            let first =
                pol.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).unwrap();
            if first == clean {
                clean_first += 1;
            }
        }
        assert!(
            clean_first > 120,
            "clean server first in {clean_first}/200 trials (uniform would be ~100)"
        );
    }

    #[test]
    fn power_of_two_choices_ties_keep_the_first_sample() {
        // Equal failure counts: the pick must be the first sample, i.e.
        // exactly `random`'s distribution — and always two draws, so the
        // downstream stream position is pick-independent.
        let (job, mut pools, mut fleet, _) = setup();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let k1 = a.next_below(pools.idle_count() as u64) as usize;
        let _k2 = a.next_below(pools.idle_count() as u64);
        let expect = pools.idle_ids()[k1];
        let got = PowerOfTwoChoices
            .take_idle(&job, &mut pools, &mut fleet, None, &mut b)
            .unwrap();
        assert_eq!(got, expect);
        assert_eq!(a.next_u64(), b.next_u64(), "stream stays aligned");
    }

    #[test]
    fn history_scored_prefers_the_cleanest_server() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        // Every idle server but one carries recent-failure history: the
        // clean one must win regardless of its free-list position.
        let clean = pools.idle_ids()[0];
        for &id in pools.idle_ids() {
            if id != clean {
                fleet[id as usize].failure_times.push(100.0);
            }
        }
        let got =
            HistoryScored.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).unwrap();
        assert_eq!(got, clean);
    }

    #[test]
    fn history_scored_ties_fall_back_to_lifo_and_draw_nothing() {
        // A fresh fleet has no history anywhere: the pick must match
        // first_fit exactly (LIFO top) and consume zero RNG draws, so
        // CRN runs pair against first_fit stream-for-stream.
        let (job, mut pools, mut fleet, mut rng) = setup();
        let mut untouched = rng.clone();
        let top = *pools.idle_ids().last().unwrap();
        let got =
            HistoryScored.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).unwrap();
        assert_eq!(got, top, "fresh fleet behaves like first_fit");
        assert_eq!(rng.next_u64(), untouched.next_u64(), "stream position untouched");
    }

    #[test]
    fn exhausted_pool_returns_none() {
        let (job, mut pools, mut fleet, mut rng) = setup();
        let topo = rack_switch_topo(fleet.len() as u32);
        while pools.take_idle(&mut fleet).is_some() {}
        assert!(FirstFit.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).is_none());
        assert!(Random.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).is_none());
        assert!(Locality.take_idle(&job, &mut pools, &mut fleet, None, &mut rng).is_none());
        assert!(AntiAffinity
            .take_idle(&job, &mut pools, &mut fleet, Some(&topo), &mut rng)
            .is_none());
        assert!(PowerOfTwoChoices
            .take_idle(&job, &mut pools, &mut fleet, None, &mut rng)
            .is_none());
        assert!(HistoryScored
            .take_idle(&job, &mut pools, &mut fleet, None, &mut rng)
            .is_none());
    }
}
