//! The simulation's event vocabulary.

/// Server identifier: index into the fleet vector.
pub type ServerId = u32;

/// What kind of failure fired (determined by which clock won the race).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    Random,
    Systematic,
}

/// Which repair stage completed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepairStage {
    Automated,
    Manual,
}

/// All events the cluster simulation exchanges.
///
/// `gen` fields implement lazy cancellation: the handler drops the event if
/// the carried generation no longer matches the entity's current one (the
/// coordinator bumps generations when it interrupts the gang).
#[derive(Clone, Copy, Debug)]
pub enum Ev {
    /// A running server's failure clock fired (per-server path; used for
    /// age-dependent non-exponential distributions).
    Fail { server: ServerId, gen: u64, kind: FailureKind },
    /// A gang's *first* failure clock fired (exponential fast path: the
    /// minimum of N exponential clocks is Exp(sum of rates) and the victim
    /// is rate-proportional, so one event replaces N). `gang_gen` guards
    /// staleness across interrupts and composition changes (regen).
    GangFail { job: u32, gang_gen: u64 },
    /// The job ran failure-free to completion.
    JobComplete { job: u32, gen: u64 },
    /// Checkpoint-restore recovery finished; the job may start running.
    RecoveryDone { job: u32, gen: u64 },
    /// Host selection finished; recovery starts next.
    SelectionDone { job: u32, gen: u64 },
    /// A preempted spare-pool server arrived in the working pool.
    PreemptArrive { server: ServerId },
    /// A repair stage completed for a server.
    RepairDone { server: ServerId, stage: RepairStage },
    /// Periodic bad-server regeneration tick (assumption 1, case 2).
    BadRegen,
    /// The aggregate domain-outage clock fired (correlated failure model:
    /// the superposition of every domain's exponential outage process is
    /// one clock; the struck level/domain is resolved rate-proportionally
    /// at delivery, mirroring the `GangFail` fast path). Always current —
    /// domains never change composition, so no generation guard.
    DomainOutage,
    /// A scripted failure injection (see [`crate::trace::inject`]);
    /// carries the index into the injection plan.
    Inject { idx: usize },
    /// A job arrives (open-loop workload, [`crate::model::workload`]):
    /// it joins the admission queue and attempts its first host
    /// selection. Only scheduled when a `workload:` is configured — the
    /// legacy all-jobs-at-t=0 path never sees this event.
    JobArrival { job: u32 },
}
