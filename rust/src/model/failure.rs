//! Failure models (paper module 1's clocks): *how* failure events are
//! generated for a running gang.
//!
//! | name | model |
//! |---|---|
//! | `gang`       | [`GangExponential`] — one aggregate clock per gang (exponential only) |
//! | `per_server` | [`PerServerClocks`] — one clock per active server (any distribution) |
//! | `thinned`    | [`ThinnedClocks`] — one aggregate clock per gang via hazard thinning (non-decreasing hazards) |
//! | `correlated` | [`CorrelatedFailures`] — any of the above *plus* domain-outage clocks |
//! | `auto`       | `gang` (exponential) / `thinned` (thinnable families) / `per_server` (rest), wrapped `correlated` when the topology carries outage rates |
//!
//! [`GangExponential`] exploits memorylessness: the minimum of N
//! exponential clocks is `Exp(sum of rates)`, so one event replaces N and
//! the victim is resolved rate-proportionally when the clock fires — the
//! headline event-count optimization. [`PerServerClocks`] arms every
//! active server individually with age-conditional sampling, which is
//! what non-exponential families (Weibull, LogNormal) require.
//! [`ThinnedClocks`] extends the aggregate trick to those families by
//! Lewis–Shedler thinning: one candidate clock paced by a majorizing
//! hazard envelope, accepted or rejected against the gang's true
//! age-conditional hazard at fire time.
//!
//! All models implement [`FailureModel`] and are draw-for-draw
//! deterministic: the dispatch refactor preserves the exact RNG
//! consumption order of the pre-refactor `Simulation`.

use crate::config::Params;
use crate::model::coordinator;
use crate::model::ctx::SimCtx;
use crate::model::events::{Ev, FailureKind, ServerId};
use crate::sim::dist::Dist;
use crate::sim::Time;

/// Stochastic failure-clock subsystem for the running gangs.
pub trait FailureModel {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Stop job `j`'s running gang at `now`: commit progress and retire
    /// whatever clocks the model keeps. Returns the interrupted burst
    /// length.
    fn interrupt(&mut self, ctx: &mut SimCtx, j: usize, now: Time) -> Time;

    /// Bookkeeping when job `j` (re-)enters Running at `now` (per-server
    /// models stamp `active_since`; aggregate models need nothing).
    fn mark_running(&mut self, ctx: &mut SimCtx, j: usize, now: Time);

    /// Arm the failure clocks for job `j`, which just entered Running.
    fn arm(&mut self, ctx: &mut SimCtx, j: usize);

    /// Resolve an [`Ev::GangFail`] for job `j`: `Some((victim, kind))` if
    /// the clock is current, `None` if stale (lazy cancellation) or the
    /// model does not use aggregate clocks.
    fn resolve_gang_fail(
        &mut self,
        ctx: &mut SimCtx,
        j: usize,
        gang_gen: u64,
    ) -> Option<(ServerId, FailureKind)>;

    /// The blamed server just left job `j`'s gang (standby-swap hot path
    /// maintains cached composition incrementally).
    fn note_removed(&mut self, j: usize, was_bad: bool);

    /// A standby was just promoted into job `j`'s gang.
    fn note_promoted(&mut self, j: usize, is_bad: bool);

    /// Recount cached gang composition from scratch (selection, regen,
    /// and completion paths).
    fn recount(&mut self, ctx: &SimCtx, j: usize);

    /// Re-arm after a regeneration tick converted servers while job `j`
    /// is Running.
    fn regen_rearm(&mut self, ctx: &mut SimCtx, j: usize);

    /// One-time hook before the initial host selection: models with
    /// *global* clocks (correlated domain outages) arm them here. The
    /// default is a no-op and draws nothing, so plain models keep every
    /// legacy stream byte-identical.
    fn on_sim_start(&mut self, _ctx: &mut SimCtx) {}

    /// Resolve an [`Ev::DomainOutage`]: pick the struck (level index,
    /// domain id) and re-arm the aggregate outage clock. `None` for
    /// models without domain clocks (which never schedule the event).
    fn resolve_domain_outage(&mut self, _ctx: &mut SimCtx) -> Option<(usize, u32)> {
        debug_assert!(false, "model without domain clocks got a DomainOutage");
        None
    }
}

/// Count of bad servers among job `j`'s active gang.
fn count_bad_active(ctx: &SimCtx, j: usize) -> usize {
    ctx.jobs[j]
        .active
        .iter()
        .filter(|&&id| ctx.fleet[id as usize].is_bad)
        .count()
}

/// Exponential fast path: one clock for the whole gang.
///
/// Valid only for the memoryless Exponential family; results are
/// distribution-identical but not draw-identical to [`PerServerClocks`].
#[derive(Clone, Debug, Default)]
pub struct GangExponential {
    /// Per-job clock generation (bumped on every interrupt and on every
    /// gang-composition change).
    gens: Vec<u64>,
    /// Per-job cached count of bad servers among the active gang.
    n_bads: Vec<usize>,
}

impl GangExponential {
    pub fn new(n_jobs: usize) -> Self {
        GangExponential { gens: vec![0; n_jobs], n_bads: vec![0; n_jobs] }
    }

    /// Draw and schedule the aggregate clock for job `j` (retiring any
    /// in-flight one via the generation bump).
    fn schedule_clock(&mut self, ctx: &mut SimCtx, j: usize) {
        self.gens[j] += 1;
        let n_active = ctx.jobs[j].active.len();
        let n_bad = self.n_bads[j];
        debug_assert_eq!(n_bad, count_bad_active(ctx, j), "gang n_bad drifted");
        let total_rate = n_active as f64 * ctx.p.random_failure_rate
            + n_bad as f64 * ctx.p.systematic_failure_rate;
        if total_rate <= 0.0 {
            return; // failure-free configuration
        }
        let dt = -ctx.rng.next_open_f64().ln() / total_rate;
        ctx.engine
            .schedule_in(dt, Ev::GangFail { job: j as u32, gang_gen: self.gens[j] });
    }
}

impl FailureModel for GangExponential {
    fn name(&self) -> &'static str {
        "gang"
    }

    fn interrupt(&mut self, ctx: &mut SimCtx, j: usize, now: Time) -> Time {
        // No per-server clocks exist: per-server gen bumps / age banking
        // would be dead work. Pausing the job is enough; the aggregate
        // clock is retired by the next generation bump.
        ctx.jobs[j].pause(now)
    }

    fn mark_running(&mut self, _ctx: &mut SimCtx, _j: usize, _now: Time) {}

    fn arm(&mut self, ctx: &mut SimCtx, j: usize) {
        self.schedule_clock(ctx, j);
    }

    fn resolve_gang_fail(
        &mut self,
        ctx: &mut SimCtx,
        j: usize,
        gang_gen: u64,
    ) -> Option<(ServerId, FailureKind)> {
        if gang_gen != self.gens[j] {
            return None; // stale clock (lazy cancellation)
        }
        // Resolve victim + kind rate-proportionally.
        let n_active = ctx.jobs[j].active.len();
        let n_bad = self.n_bads[j];
        let rate_random = n_active as f64 * ctx.p.random_failure_rate;
        let rate_sys = n_bad as f64 * ctx.p.systematic_failure_rate;
        let total = rate_random + rate_sys;
        debug_assert!(total > 0.0);
        let (victim, kind) = if ctx.rng.next_f64() * total < rate_random {
            // A random clock fired: uniform victim over all active.
            let k = ctx.rng.next_below(n_active as u64) as usize;
            (ctx.jobs[j].active[k], FailureKind::Random)
        } else {
            // A systematic clock fired: uniform victim over bad actives.
            let k = ctx.rng.next_below(n_bad as u64) as usize;
            let victim = ctx.jobs[j]
                .active
                .iter()
                .copied()
                .filter(|&id| ctx.fleet[id as usize].is_bad)
                .nth(k)
                .expect("bad-active count changed under us");
            (victim, FailureKind::Systematic)
        };
        self.gens[j] += 1; // retire this clock before the interrupt
        Some((victim, kind))
    }

    fn note_removed(&mut self, j: usize, was_bad: bool) {
        if was_bad {
            self.n_bads[j] -= 1;
        }
    }

    fn note_promoted(&mut self, j: usize, is_bad: bool) {
        if is_bad {
            self.n_bads[j] += 1;
        }
    }

    fn recount(&mut self, ctx: &SimCtx, j: usize) {
        self.n_bads[j] = count_bad_active(ctx, j);
    }

    fn regen_rearm(&mut self, ctx: &mut SimCtx, j: usize) {
        // Memoryless: re-draw the aggregate clock against the new
        // composition (the old one is retired by the gen bump).
        self.schedule_clock(ctx, j);
    }
}

/// General per-server clocks: every active server is armed individually,
/// with age-conditional sampling for non-exponential families (renewal at
/// repair).
#[derive(Clone, Copy, Debug, Default)]
pub struct PerServerClocks;

impl FailureModel for PerServerClocks {
    fn name(&self) -> &'static str {
        "per_server"
    }

    fn interrupt(&mut self, ctx: &mut SimCtx, j: usize, now: Time) -> Time {
        let SimCtx { jobs, fleet, .. } = ctx;
        coordinator::interrupt(&mut jobs[j], fleet, now)
    }

    fn mark_running(&mut self, ctx: &mut SimCtx, j: usize, now: Time) {
        let SimCtx { jobs, fleet, .. } = ctx;
        coordinator::mark_running(&jobs[j], fleet, now);
    }

    fn arm(&mut self, ctx: &mut SimCtx, j: usize) {
        // Indexed loop: the body needs `ctx` mutably (rng + engine), so we
        // cannot hold an iterator over `ctx.jobs[j].active`.
        let n_active = ctx.jobs[j].active.len();
        for i in 0..n_active {
            let id = ctx.jobs[j].active[i];
            let (dt, kind, gen) = {
                let s = &ctx.fleet[id as usize];
                let (dt, kind) = s.sample_failure(&ctx.p, &mut ctx.rng);
                (dt, kind, s.gen.0)
            };
            ctx.engine.schedule_in(dt, Ev::Fail { server: id, gen, kind });
        }
    }

    fn resolve_gang_fail(
        &mut self,
        _ctx: &mut SimCtx,
        _j: usize,
        _gang_gen: u64,
    ) -> Option<(ServerId, FailureKind)> {
        debug_assert!(false, "per-server model never schedules GangFail");
        None
    }

    fn note_removed(&mut self, _j: usize, _was_bad: bool) {}

    fn note_promoted(&mut self, _j: usize, _is_bad: bool) {}

    fn recount(&mut self, _ctx: &SimCtx, _j: usize) {}

    fn regen_rearm(&mut self, ctx: &mut SimCtx, j: usize) {
        // Newly-bad computing servers get a systematic clock now.
        let now = ctx.engine.now();
        let n_active = ctx.jobs[j].active.len();
        for i in 0..n_active {
            let id = ctx.jobs[j].active[i];
            let (schedule, dt, gen) = {
                let s = &ctx.fleet[id as usize];
                if s.is_bad {
                    let age = s.run_age + (now - s.active_since);
                    let d = ctx
                        .p
                        .failure_dist
                        .with_rate(ctx.p.systematic_failure_rate);
                    (true, d.sample_remaining(&mut ctx.rng, age), s.gen.0)
                } else {
                    (false, 0.0, 0)
                }
            };
            if schedule {
                ctx.engine.schedule_in(
                    dt,
                    Ev::Fail { server: id, gen, kind: FailureKind::Systematic },
                );
            }
        }
    }
}

/// Expected candidate arrivals per thinning window: windows short enough
/// that the envelope stays tight, long enough that refresh markers are a
/// small fraction of traffic.
const WINDOW_CANDIDATES: f64 = 4.0;

/// Aggregate gang clock for non-exponential families via Lewis–Shedler
/// thinning.
///
/// The gang's failure process is the superposition of per-server renewal
/// hazards `H(t) = Σᵢ h_rand(ageᵢ(t)) + [badᵢ]·h_sys(ageᵢ(t))`. Over a
/// lookahead window `[t₀, t₀+w]` we precompute a majorizing constant
/// `Λ = Σᵢ max h` (each term via [`Dist::hazard_max`], exact because every
/// supported hazard is monotone or unimodal), then run ONE Poisson(Λ)
/// candidate clock: at each candidate time `t`, accept with probability
/// `H(t)/Λ` — an accepted candidate is a real failure, and the victim is
/// drawn proportionally to its hazard share. Rejections redraw the next
/// candidate O(1) from the same envelope; a candidate clamped to the
/// window's end is a *refresh marker* that recomputes the envelope.
/// This replaces [`PerServerClocks`]' N timers per burst with one event
/// in flight per gang, at identical statistics (pinned by
/// `tests/thinning.rs`) though not identical draws.
///
/// Requires non-decreasing-at-renewal hazards to stay efficient and
/// finite: the policy registry routes Weibull `shape < 1` (hazard diverges
/// at age 0) to `per_server` instead.
#[derive(Clone, Debug)]
pub struct ThinnedClocks {
    /// Per-job clock generation (bumped on every arm / accepted failure).
    gens: Vec<u64>,
    /// Current envelope rate Λ per job.
    lambda: Vec<f64>,
    /// Absolute end of the current thinning window per job.
    window_end: Vec<Time>,
    /// Random-failure lifetime distribution (from the configured family).
    d_rand: Dist,
    /// Systematic-failure lifetime distribution (bad servers only).
    d_sys: Dist,
    /// Cached hazard-peak ages (golden-section for LogNormal: computed
    /// once here, never in the hot path).
    peak_rand: f64,
    peak_sys: f64,
    /// Per-active-server hazards from the last `total_hazard` call, for
    /// hazard-proportional victim resolution.
    haz_buf: Vec<f64>,
}

impl ThinnedClocks {
    pub fn new(n_jobs: usize, p: &Params) -> Self {
        let d_rand = p.failure_dist.with_rate(p.random_failure_rate);
        let d_sys = p.failure_dist.with_rate(p.systematic_failure_rate);
        let peak_rand = d_rand.hazard_peak();
        let peak_sys = d_sys.hazard_peak();
        ThinnedClocks {
            gens: vec![0; n_jobs],
            lambda: vec![0.0; n_jobs],
            window_end: vec![0.0; n_jobs],
            d_rand,
            d_sys,
            peak_rand,
            peak_sys,
            haz_buf: Vec::new(),
        }
    }

    /// Gang hazard `H(now)` for job `j`, leaving each server's share in
    /// `haz_buf` (indexed like `jobs[j].active`).
    fn total_hazard(&mut self, ctx: &SimCtx, j: usize, now: Time) -> f64 {
        let active = &ctx.jobs[j].active;
        self.haz_buf.clear();
        self.haz_buf.reserve(active.len());
        let mut total = 0.0;
        for &id in active {
            let s = &ctx.fleet[id as usize];
            let age = s.run_age + (now - s.active_since);
            let mut h = self.d_rand.hazard(age);
            if s.is_bad {
                h += self.d_sys.hazard(age);
            }
            self.haz_buf.push(h);
            total += h;
        }
        total
    }

    /// Open a fresh thinning window from `now`: compute the majorizing
    /// envelope Λ over it and schedule the first candidate. Does NOT bump
    /// the generation — callers decide whether in-flight clocks die.
    fn schedule_envelope(&mut self, ctx: &mut SimCtx, j: usize) {
        let now = ctx.engine.now();
        let n_active = ctx.jobs[j].active.len();
        if n_active == 0 {
            return;
        }
        // Window length: aim for WINDOW_CANDIDATES arrivals at the
        // current pace. The exponential-equivalent rate floors the pace so
        // a young increasing-hazard fleet (H(now) ≈ 0) still gets a
        // finite, sensibly-sized window.
        let n_bad = count_bad_active(ctx, j);
        let exp_rate = n_active as f64 * ctx.p.random_failure_rate
            + n_bad as f64 * ctx.p.systematic_failure_rate;
        let pace = self.total_hazard(ctx, j, now).max(exp_rate);
        if pace <= 0.0 {
            return; // failure-free configuration
        }
        let w = WINDOW_CANDIDATES / pace;

        let mut lambda = 0.0;
        for &id in &ctx.jobs[j].active {
            let s = &ctx.fleet[id as usize];
            let age = s.run_age + (now - s.active_since);
            lambda += self.d_rand.hazard_max(age, age + w, self.peak_rand);
            if s.is_bad {
                lambda += self.d_sys.hazard_max(age, age + w, self.peak_sys);
            }
        }
        debug_assert!(
            lambda.is_finite() && lambda > 0.0,
            "degenerate thinning envelope {lambda} (did the registry let a \
             diverging hazard through?)"
        );
        self.lambda[j] = lambda;
        self.window_end[j] = now + w;
        self.schedule_candidate(ctx, j, now);
    }

    /// Draw the next Poisson(Λ) candidate from `from`, clamped to the
    /// window's end (the clamped case is the refresh marker).
    fn schedule_candidate(&mut self, ctx: &mut SimCtx, j: usize, from: Time) {
        let dt = -ctx.rng.next_open_f64().ln() / self.lambda[j];
        let at = (from + dt).min(self.window_end[j]);
        ctx.engine
            .schedule_at(at, Ev::GangFail { job: j as u32, gang_gen: self.gens[j] });
    }
}

impl FailureModel for ThinnedClocks {
    fn name(&self) -> &'static str {
        "thinned"
    }

    fn interrupt(&mut self, ctx: &mut SimCtx, j: usize, now: Time) -> Time {
        // Ages matter here (unlike `gang`): bank every server's burst age
        // so the next envelope conditions on true ages. The aggregate
        // candidate is retired by the next generation bump at arm.
        let SimCtx { jobs, fleet, .. } = ctx;
        coordinator::interrupt(&mut jobs[j], fleet, now)
    }

    fn mark_running(&mut self, ctx: &mut SimCtx, j: usize, now: Time) {
        let SimCtx { jobs, fleet, .. } = ctx;
        coordinator::mark_running(&jobs[j], fleet, now);
    }

    fn arm(&mut self, ctx: &mut SimCtx, j: usize) {
        self.gens[j] += 1; // retire any in-flight candidate
        self.schedule_envelope(ctx, j);
    }

    fn resolve_gang_fail(
        &mut self,
        ctx: &mut SimCtx,
        j: usize,
        gang_gen: u64,
    ) -> Option<(ServerId, FailureKind)> {
        if gang_gen != self.gens[j] {
            return None; // stale clock (lazy cancellation)
        }
        let now = ctx.engine.now();
        if now >= self.window_end[j] {
            // Refresh marker (candidates are clamped to the window end):
            // open the next window under the same generation.
            self.schedule_envelope(ctx, j);
            return None;
        }
        let h = self.total_hazard(ctx, j, now);
        let lambda = self.lambda[j];
        // The envelope majorizes by construction; the 1% slack absorbs the
        // LogNormal deep-tail seam (sim/dist.rs switches to a Mills-ratio
        // asymptotic there, which slightly over-estimates — envelope-safe).
        debug_assert!(
            h <= lambda * 1.01 + 1e-12,
            "hazard {h} escaped its envelope {lambda}"
        );
        if ctx.rng.next_f64() * lambda >= h {
            // Rejected: the next candidate redraws O(1) from the same
            // envelope — no N-server recompute on the rejection path.
            self.schedule_candidate(ctx, j, now);
            return None;
        }
        // Accepted: victim proportional to its hazard share.
        let n_active = ctx.jobs[j].active.len();
        let u = ctx.rng.next_f64() * h;
        let mut k = n_active - 1; // float edges resolve to the last server
        let mut acc = 0.0;
        for (i, &hi) in self.haz_buf.iter().enumerate() {
            acc += hi;
            if u < acc {
                k = i;
                break;
            }
        }
        let victim = ctx.jobs[j].active[k];
        let s = &ctx.fleet[victim as usize];
        let kind = if s.is_bad {
            // Split the server's hazard share between its two processes.
            let age = s.run_age + (now - s.active_since);
            if ctx.rng.next_f64() * self.haz_buf[k] < self.d_rand.hazard(age) {
                FailureKind::Random
            } else {
                FailureKind::Systematic
            }
        } else {
            FailureKind::Random
        };
        self.gens[j] += 1; // retire this clock before the interrupt
        Some((victim, kind))
    }

    // Composition changes only happen between an interrupt and the next
    // arm (which re-envelopes from scratch), so no incremental cache.
    fn note_removed(&mut self, _j: usize, _was_bad: bool) {}

    fn note_promoted(&mut self, _j: usize, _is_bad: bool) {}

    fn recount(&mut self, _ctx: &SimCtx, _j: usize) {}

    fn regen_rearm(&mut self, ctx: &mut SimCtx, j: usize) {
        // Newly-bad servers invalidate the majorization: rebuild the
        // envelope (the gen bump retires the in-flight candidate).
        self.gens[j] += 1;
        self.schedule_envelope(ctx, j);
    }
}

/// Correlated domain outages layered over a base clock model.
///
/// The per-gang machinery (interrupt semantics, aggregate or per-server
/// clocks) delegates verbatim to the wrapped model; on top, every domain
/// of every topology level runs an exponential outage clock. Their
/// superposition is one aggregate clock of rate
/// [`Topology::total_outage_rate`](crate::model::topology::Topology::total_outage_rate)
/// — the same minimum-of-exponentials trick as [`GangExponential`] — and
/// the struck level/domain resolves rate-proportionally at delivery.
/// Domain populations never change, so the clock is always current (no
/// generation guard); non-exponential families can thin against the same
/// aggregate envelope later.
///
/// What an outage *does* to the fleet lives in
/// [`crate::model::lifecycle`]'s domain-outage flow; this model only owns
/// the clocks.
pub struct CorrelatedFailures {
    inner: Box<dyn FailureModel>,
}

impl CorrelatedFailures {
    pub fn new(inner: Box<dyn FailureModel>) -> CorrelatedFailures {
        CorrelatedFailures { inner }
    }

    /// Draw and schedule the next aggregate domain-outage arrival.
    fn schedule_clock(ctx: &mut SimCtx) {
        let Some(t) = &ctx.topo else { return };
        let rate = t.total_outage_rate();
        if rate <= 0.0 {
            return; // outage-free topology: the wrapper is inert
        }
        let dt = -ctx.rng.next_open_f64().ln() / rate;
        ctx.engine.schedule_in(dt, Ev::DomainOutage);
    }
}

impl FailureModel for CorrelatedFailures {
    fn name(&self) -> &'static str {
        "correlated"
    }

    fn interrupt(&mut self, ctx: &mut SimCtx, j: usize, now: Time) -> Time {
        self.inner.interrupt(ctx, j, now)
    }

    fn mark_running(&mut self, ctx: &mut SimCtx, j: usize, now: Time) {
        self.inner.mark_running(ctx, j, now)
    }

    fn arm(&mut self, ctx: &mut SimCtx, j: usize) {
        self.inner.arm(ctx, j)
    }

    fn resolve_gang_fail(
        &mut self,
        ctx: &mut SimCtx,
        j: usize,
        gang_gen: u64,
    ) -> Option<(ServerId, FailureKind)> {
        self.inner.resolve_gang_fail(ctx, j, gang_gen)
    }

    fn note_removed(&mut self, j: usize, was_bad: bool) {
        self.inner.note_removed(j, was_bad)
    }

    fn note_promoted(&mut self, j: usize, is_bad: bool) {
        self.inner.note_promoted(j, is_bad)
    }

    fn recount(&mut self, ctx: &SimCtx, j: usize) {
        self.inner.recount(ctx, j)
    }

    fn regen_rearm(&mut self, ctx: &mut SimCtx, j: usize) {
        self.inner.regen_rearm(ctx, j)
    }

    fn on_sim_start(&mut self, ctx: &mut SimCtx) {
        // Stay a transparent decorator: the inner model initializes
        // first (a no-op and zero draws for today's models).
        self.inner.on_sim_start(ctx);
        Self::schedule_clock(ctx);
    }

    fn resolve_domain_outage(&mut self, ctx: &mut SimCtx) -> Option<(usize, u32)> {
        let (level, domain) = {
            let SimCtx { topo, rng, .. } = ctx;
            let t = topo.as_ref().expect("correlated model requires a topology");
            let total = t.total_outage_rate();
            debug_assert!(total > 0.0, "outage fired with zero rate");
            // Level rate-proportionally (one draw), then the domain
            // uniformly within the level — the superposed processes are
            // homogeneous per level.
            let u = rng.next_f64() * total;
            let mut level = 0usize;
            let mut acc = 0.0;
            for (l, lv) in t.levels().iter().enumerate() {
                let r = lv.n_domains as f64 * lv.outage_rate;
                if r <= 0.0 {
                    continue;
                }
                level = l; // last positive-rate level absorbs float edges
                acc += r;
                if u < acc {
                    break;
                }
            }
            let domain = rng.next_below(t.levels()[level].n_domains as u64) as u32;
            (level, domain)
        };
        Self::schedule_clock(ctx);
        Some((level, domain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Params, TopologyLevelSpec, TopologySpec};
    use crate::model::job::JobPhase;
    use crate::model::server::ServerState;
    use crate::sim::rng::Rng;

    /// Context with job 0 running on the first `job_size` servers.
    fn running_ctx(p: &Params, seed: u64) -> SimCtx {
        let mut ctx = SimCtx::new(p, Rng::new(seed));
        for _ in 0..p.job_size {
            let id = ctx.pools.take_idle(&mut ctx.fleet).unwrap();
            ctx.fleet[id as usize].state = ServerState::JobActive;
            ctx.fleet[id as usize].assigned_job = Some(0);
            ctx.jobs[0].active.push(id);
        }
        ctx.jobs[0].resume(0.0);
        assert_eq!(ctx.jobs[0].phase, JobPhase::Running);
        ctx
    }

    #[test]
    fn gang_schedules_one_event_per_arm() {
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 1);
        let mut fm = GangExponential::new(1);
        fm.recount(&ctx, 0);
        fm.arm(&mut ctx, 0);
        assert_eq!(ctx.engine.pending(), 1, "one aggregate clock");
    }

    #[test]
    fn per_server_schedules_one_event_per_active() {
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 1);
        let mut fm = PerServerClocks;
        fm.arm(&mut ctx, 0);
        assert_eq!(ctx.engine.pending(), p.job_size as usize);
    }

    #[test]
    fn gang_zero_rates_never_fire() {
        let mut p = Params::small_test();
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        let mut ctx = running_ctx(&p, 2);
        let mut fm = GangExponential::new(1);
        fm.recount(&ctx, 0);
        fm.arm(&mut ctx, 0);
        assert_eq!(ctx.engine.pending(), 0);
    }

    #[test]
    fn stale_gang_gen_is_dropped_without_draws() {
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 3);
        let mut fm = GangExponential::new(1);
        fm.recount(&ctx, 0);
        fm.arm(&mut ctx, 0);
        let rng_before = ctx.rng.clone();
        // Generation 0 is stale (arm bumped to 1).
        assert!(fm.resolve_gang_fail(&mut ctx, 0, 0).is_none());
        let mut a = rng_before;
        let mut b = ctx.rng.clone();
        assert_eq!(a.next_u64(), b.next_u64(), "stale resolution must not draw");
    }

    #[test]
    fn current_gang_gen_resolves_a_victim() {
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 4);
        let mut fm = GangExponential::new(1);
        fm.recount(&ctx, 0);
        fm.arm(&mut ctx, 0);
        let (victim, _kind) = fm.resolve_gang_fail(&mut ctx, 0, 1).expect("current gen");
        assert!(ctx.jobs[0].active.contains(&victim));
        // The resolution retired the clock: the same gen is now stale.
        assert!(fm.resolve_gang_fail(&mut ctx, 0, 1).is_none());
    }

    /// Params with a rack/switch topology carrying the given rates.
    fn topo_params(rack_rate: f64, switch_rate: f64) -> Params {
        let mut p = Params::small_test();
        p.topology = Some(TopologySpec {
            levels: vec![
                TopologyLevelSpec { name: "rack".into(), size: 4, outage_rate: rack_rate },
                TopologyLevelSpec {
                    name: "switch".into(),
                    size: 2,
                    outage_rate: switch_rate,
                },
            ],
        });
        p
    }

    #[test]
    fn correlated_arms_one_outage_clock_at_start() {
        let p = topo_params(0.001, 0.0005);
        let mut ctx = SimCtx::new(&p, Rng::new(1));
        let mut fm = CorrelatedFailures::new(Box::new(GangExponential::new(1)));
        fm.on_sim_start(&mut ctx);
        assert_eq!(ctx.engine.pending(), 1, "one aggregate outage clock");
    }

    #[test]
    fn correlated_without_rates_is_inert() {
        let p = topo_params(0.0, 0.0);
        let mut ctx = SimCtx::new(&p, Rng::new(2));
        let rng_before = ctx.rng.clone();
        let mut fm = CorrelatedFailures::new(Box::new(GangExponential::new(1)));
        fm.on_sim_start(&mut ctx);
        assert_eq!(ctx.engine.pending(), 0);
        let mut a = rng_before;
        let mut b = ctx.rng.clone();
        assert_eq!(a.next_u64(), b.next_u64(), "no rates, no draws");
    }

    #[test]
    fn correlated_resolution_picks_a_valid_domain_and_rearms() {
        let p = topo_params(0.001, 0.0005);
        let mut ctx = SimCtx::new(&p, Rng::new(3));
        let mut fm = CorrelatedFailures::new(Box::new(GangExponential::new(1)));
        fm.on_sim_start(&mut ctx);
        for _ in 0..200 {
            let before = ctx.engine.pending();
            let (level, domain) = fm.resolve_domain_outage(&mut ctx).expect("resolves");
            let t = ctx.topo.as_ref().unwrap();
            assert!(level < t.n_levels());
            assert!(domain < t.levels()[level].n_domains);
            assert_eq!(ctx.engine.pending(), before + 1, "clock re-armed");
        }
    }

    #[test]
    fn correlated_level_pick_is_rate_proportional() {
        // rack: 22 domains x 0.003, switch: 11 domains x 0.006 ->
        // P(rack) = 0.5 exactly. 2000 resolutions keep the split tight.
        let p = topo_params(0.003, 0.006);
        let mut ctx = SimCtx::new(&p, Rng::new(4));
        let mut fm = CorrelatedFailures::new(Box::new(GangExponential::new(1)));
        let n = 2000;
        let racks = (0..n)
            .filter(|_| fm.resolve_domain_outage(&mut ctx).unwrap().0 == 0)
            .count();
        let frac = racks as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "rack fraction {frac}");
    }

    #[test]
    fn correlated_delegates_gang_machinery() {
        let p = topo_params(0.001, 0.0);
        let mut ctx = running_ctx(&p, 5);
        let mut fm = CorrelatedFailures::new(Box::new(GangExponential::new(1)));
        fm.recount(&ctx, 0);
        fm.arm(&mut ctx, 0);
        assert_eq!(ctx.engine.pending(), 1, "inner gang clock armed");
        let (victim, _) = fm.resolve_gang_fail(&mut ctx, 0, 1).expect("current gen");
        assert!(ctx.jobs[0].active.contains(&victim));
    }

    #[test]
    fn thinned_schedules_one_event_per_arm() {
        let mut p = Params::small_test();
        p.failure_dist = crate::config::DistKind::Weibull { shape: 1.5 };
        let mut ctx = running_ctx(&p, 1);
        let mut fm = ThinnedClocks::new(1, &p);
        fm.arm(&mut ctx, 0);
        assert_eq!(
            ctx.engine.pending(),
            1,
            "one aggregate candidate clock, vs {} per-server timers",
            p.job_size
        );
    }

    #[test]
    fn thinned_stale_gen_is_dropped_without_draws() {
        let mut p = Params::small_test();
        p.failure_dist = crate::config::DistKind::Weibull { shape: 1.5 };
        let mut ctx = running_ctx(&p, 3);
        let mut fm = ThinnedClocks::new(1, &p);
        fm.arm(&mut ctx, 0);
        let rng_before = ctx.rng.clone();
        // Generation 0 is stale (arm bumped to 1).
        assert!(fm.resolve_gang_fail(&mut ctx, 0, 0).is_none());
        let mut a = rng_before;
        let mut b = ctx.rng.clone();
        assert_eq!(a.next_u64(), b.next_u64(), "stale resolution must not draw");
    }

    #[test]
    fn thinned_exponential_always_accepts_a_victim() {
        // Constant hazard: H == Λ, so the very first candidate resolves.
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 4);
        let mut fm = ThinnedClocks::new(1, &p);
        fm.arm(&mut ctx, 0);
        let (victim, _kind) =
            fm.resolve_gang_fail(&mut ctx, 0, 1).expect("exponential never rejects");
        assert!(ctx.jobs[0].active.contains(&victim));
        // The resolution retired the clock: the same gen is now stale.
        assert!(fm.resolve_gang_fail(&mut ctx, 0, 1).is_none());
    }

    #[test]
    fn thinned_zero_rates_never_fire() {
        let mut p = Params::small_test();
        p.failure_dist = crate::config::DistKind::Weibull { shape: 2.0 };
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        let mut ctx = running_ctx(&p, 2);
        let mut fm = ThinnedClocks::new(1, &p);
        fm.arm(&mut ctx, 0);
        assert_eq!(ctx.engine.pending(), 0);
    }

    #[test]
    fn thinned_refresh_marker_opens_next_window_same_gen() {
        let mut p = Params::small_test();
        p.failure_dist = crate::config::DistKind::Weibull { shape: 3.0 };
        p.random_failure_rate = 1.0; // per-minute: keeps the loop short
        let mut ctx = running_ctx(&p, 6);
        let mut fm = ThinnedClocks::new(1, &p);
        fm.arm(&mut ctx, 0);
        // Young shape-3 fleet: H(0) = 0 while Λ > 0, so candidates at the
        // window end are refresh markers. Drive the engine to the first
        // event; resolving at now == window_end must re-envelope without
        // producing a failure or bumping the generation.
        let (_t, ev) = ctx.engine.pop().expect("candidate scheduled");
        let Ev::GangFail { job, gang_gen } = ev else {
            panic!("unexpected event {ev:?}")
        };
        assert_eq!(job, 0);
        let mut resolved = fm.resolve_gang_fail(&mut ctx, 0, gang_gen);
        // Either an early accept (possible) or a refresh/reject chain that
        // keeps exactly one candidate in flight.
        for _ in 0..512 {
            if resolved.is_some() {
                break;
            }
            assert_eq!(ctx.engine.pending(), 1, "exactly one candidate in flight");
            let (_t, ev) = ctx.engine.pop().unwrap();
            let Ev::GangFail { gang_gen, .. } = ev else { unreachable!() };
            resolved = fm.resolve_gang_fail(&mut ctx, 0, gang_gen);
        }
        let (victim, _) = resolved.expect("shape-3 hazard grows: must fire eventually");
        assert!(ctx.jobs[0].active.contains(&victim));
    }

    #[test]
    fn incremental_bad_count_tracks_recount() {
        let p = Params::small_test();
        let mut ctx = running_ctx(&p, 5);
        let mut fm = GangExponential::new(1);
        fm.recount(&ctx, 0);
        let before = fm.n_bads[0];
        fm.note_promoted(0, true);
        fm.note_removed(0, true);
        assert_eq!(fm.n_bads[0], before);
        fm.recount(&ctx, 0);
        assert_eq!(fm.n_bads[0], count_bad_active(&ctx, 0));
    }
}
