//! The AIReSim model: the paper's five modules (§III-C) decomposed into
//! pluggable policy subsystems over a shared simulation context.
//!
//! | paper module | mechanism | pluggable policy |
//! |---|---|---|
//! | 1. Server       | [`server`] (state machine) | [`failure`] — clock models (`gang`, `per_server`) |
//! | 2. Coordinator  | [`coordinator`] (gang interrupt) | — |
//! | 3. Scheduler    | [`scheduler`] (allotment top-up) | [`selection`] — host choice (`first_fit`, `random`, `locality`) |
//! | 4. Repairs      | [`repair`] (auto→manual, capacity) | [`repair`] — queue discipline (`fifo`, `lifo`, `job_first`, `sla_aged`, `shortest_first`) |
//! | 5. Pool         | [`pool`] (working/spare pools) | — |
//!
//! plus [`checkpoint`] (commit-cost/work-loss/restart policies:
//! `continuous`, `periodic`, `young_daly`, `adaptive`, `tiered`),
//! [`job`] (progress semantics), [`diagnosis`] (inputs
//! 12–13), [`retirement`] (failure-score retirement, §II-B), [`regen`]
//! (bad-server regeneration), [`topology`] (failure-domain hierarchy:
//! feeds the `correlated` failure model and the `anti_affinity`/domain
//! `locality` selection policies), [`workload`] (open-loop arrivals,
//! admission queueing, and NDJSON trace replay), and [`outputs`]
//! (measured outputs, §III-B).
//!
//! The composition layer: [`ctx::SimCtx`] holds the shared state,
//! [`policy::PolicySet`]/[`policy::PolicySpec`] select implementations by
//! name, [`lifecycle`]/[`repair_flow`] sequence the Figure-1 flows, and
//! [`cluster::Simulation`] is the event loop. [`cluster::ReplicationRunner`]
//! reuses one simulation's buffers across batched replications.

pub mod checkpoint;
pub mod cluster;
pub mod coordinator;
pub mod ctx;
pub mod diagnosis;
pub mod events;
pub mod failure;
pub mod job;
pub mod lifecycle;
pub mod outputs;
pub mod policy;
pub mod pool;
pub mod regen;
pub mod repair;
pub mod repair_flow;
pub mod retirement;
pub mod scheduler;
pub mod selection;
pub mod server;
pub mod topology;
pub mod workload;

pub use cluster::{ReplicationRunner, Simulation};
pub use outputs::RunOutputs;
pub use policy::{PolicySet, PolicySpec};
