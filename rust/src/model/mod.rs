//! The AIReSim model: the paper's five modules (§III-C) plus the
//! supporting subsystems they imply.
//!
//! | paper module | here |
//! |---|---|
//! | 1. Server       | [`server`] (state machine, failure clocks) |
//! | 2. Coordinator  | [`coordinator`] (gang interrupt propagation) |
//! | 3. Scheduler    | [`scheduler`] (host selection, warm standbys) |
//! | 4. Repairs      | [`repair`] (auto→manual pipeline, capacity) |
//! | 5. Pool         | [`pool`] (working/spare pools, preemption) |
//!
//! plus [`job`] (progress + checkpoint semantics), [`diagnosis`]
//! (inputs 12–13), [`retirement`] (failure-score retirement, §II-B),
//! [`regen`] (bad-server regeneration, assumption 1 case 2), and
//! [`cluster`] — the [`cluster::Simulation`] event loop that composes all
//! of the above, and [`outputs`] — the measured outputs (§III-B).

pub mod cluster;
pub mod coordinator;
pub mod diagnosis;
pub mod events;
pub mod job;
pub mod outputs;
pub mod pool;
pub mod regen;
pub mod repair;
pub mod retirement;
pub mod scheduler;
pub mod server;

pub use cluster::Simulation;
pub use outputs::RunOutputs;
