//! Checkpoint policies: what committing a checkpoint costs in wall-clock
//! time, what a failure costs in lost work, and what a restore costs in
//! restart latency (§I "restarting … from a previous checkpoint").
//!
//! | name | policy |
//! |---|---|
//! | `continuous` | [`Continuous`] — async checkpointing, no loss, no commit cost (paper default) |
//! | `periodic`   | [`Periodic`] — commit every `checkpoint_interval` minutes of work, each commit stalls the gang `checkpoint_cost` minutes |
//! | `young_daly` | [`SelfTuning::young_daly`] — interval = √(2·C·MTBF_gang) from the configured rates and the live gang composition |
//! | `adaptive`   | [`SelfTuning::adaptive`] — online Young/Daly from a sliding window of observed interrupt inter-arrivals |
//! | `tiered`     | [`Tiered`] — cheap-frequent + expensive-rare commit tiers with distinct restore costs |
//! | `auto`       | `periodic` when `checkpoint_interval > 0`, else `continuous` |
//!
//! ## The commit-cost model
//!
//! A running burst alternates useful work and commit stalls: after every
//! `interval` minutes of work the snapshot is taken **atomically at the
//! work boundary** and the gang then stalls `cost` wall minutes while it
//! is written. A failure during the write window therefore loses nothing
//! past the boundary (the snapshot is already durable), but only the
//! overhead actually elapsed is accounted. Failure clocks keep running
//! through commit stalls — servers can die mid-write.
//!
//! The model adds **zero events**: commit overhead is folded into the
//! `JobComplete` schedule via [`CheckpointPolicy::wall_for_work`] and
//! recovered at burst end via [`CheckpointPolicy::account_burst`]. With
//! `checkpoint_cost = 0` every code path short-circuits to the exact
//! legacy arithmetic, so all outputs stay byte-identical.

use crate::config::Params;
use crate::model::ctx::SimCtx;
use crate::sim::Time;

/// Relative slack for commit-boundary arithmetic: after a loss, `done` is
/// restored to a committed multiple only up to FP rounding, so boundary
/// comparisons treat values within one part in 10⁹ as exact. Without it a
/// failure landing on a commit boundary can floor one interval low and
/// re-lose already-committed work.
const BOUNDARY_EPS: f64 = 1e-9;

/// What one running burst produced, in useful-work terms.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BurstAccount {
    /// Useful work completed during the burst (wall minus commit stalls).
    pub work: Time,
    /// Checkpoints committed during the burst (all tiers).
    pub commits: u64,
    /// Wall-clock spent writing checkpoints (partial for a write cut
    /// short by the interrupt — only elapsed stall time is charged).
    pub overhead: Time,
}

impl BurstAccount {
    /// The cost-free account: every wall minute was useful work.
    fn passthrough(wall: Time) -> BurstAccount {
        BurstAccount { work: wall, commits: 0, overhead: 0.0 }
    }
}

/// Checkpoint semantics: commit overhead, lost work on interrupt, and
/// restore latency. Methods take the job index so stateful policies
/// (Young/Daly intervals, tier bookkeeping) can track per-job state.
pub trait CheckpointPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Useful work lost when a failure interrupts job `job` after `done`
    /// minutes of committed-plus-uncommitted work. Called once per
    /// interrupt, *after* [`CheckpointPolicy::account_burst`].
    fn work_lost(&mut self, job: usize, done: Time) -> Time;

    /// Checkpoint-restore latency charged for job `job`'s next recovery.
    fn restart_cost(&self, job: usize) -> Time;

    /// Wall-clock needed to complete `work` useful minutes starting from
    /// `done0` minutes already done (commit stalls included). Schedules
    /// `JobComplete`; a commit coinciding with completion is skipped.
    fn wall_for_work(&self, _job: usize, _done0: Time, work: Time) -> Time {
        work
    }

    /// End-of-burst accounting: invert `wall` elapsed minutes of a burst
    /// that started at `done0` into useful work, commits, and overhead.
    /// `interrupted` distinguishes a failure (a commit starting at the
    /// exact interrupt instant counts — the snapshot is atomic) from
    /// completion (a commit coinciding with the finish is skipped).
    fn account_burst(
        &mut self,
        _job: usize,
        _done0: Time,
        wall: Time,
        _interrupted: bool,
    ) -> BurstAccount {
        BurstAccount::passthrough(wall)
    }

    /// Job `job` (re-)entered Running: self-optimizing policies recompute
    /// their interval here against the live gang composition. Must not
    /// draw from the RNG. The interval then holds for the whole burst
    /// (the pending `JobComplete` was scheduled against it).
    fn on_start_running(&mut self, _ctx: &SimCtx, _job: usize) {}
}

// ------------------------------------------------------------------ //
// The single-tier commit schedule (shared by periodic / young_daly /
// adaptive)
// ------------------------------------------------------------------ //

/// One tier's commit schedule within a burst that starts at a committed
/// checkpoint: `interval` minutes of work, then a `cost`-minute write
/// stall, repeating. Closed-form in both directions.
#[derive(Clone, Copy, Debug)]
struct CommitClock {
    interval: Time,
    cost: Time,
}

impl CommitClock {
    /// Commits strictly inside `work` useful minutes (one per full
    /// interval; none at the completion point itself).
    fn commits_within(&self, work: Time) -> u64 {
        if self.interval <= 0.0 || !self.interval.is_finite() || work <= 0.0 {
            return 0;
        }
        let n = (work / self.interval - BOUNDARY_EPS).ceil() - 1.0;
        if n > 0.0 {
            n as u64
        } else {
            0
        }
    }

    fn wall_for_work(&self, work: Time) -> Time {
        if self.cost <= 0.0 {
            return work; // exact passthrough: cost 0 stays byte-identical
        }
        work + self.commits_within(work) as f64 * self.cost
    }

    fn account(&self, wall: Time, interrupted: bool) -> BurstAccount {
        if self.interval <= 0.0 || !self.interval.is_finite() || wall <= 0.0 {
            return BurstAccount::passthrough(wall);
        }
        if self.cost <= 0.0 {
            // Free commits: progress equals wall time; only the commit
            // count is tracked (boundary-inclusive on interrupts — the
            // snapshot at the boundary is atomic — exclusive at
            // completion).
            let commits = if interrupted {
                (wall / self.interval + BOUNDARY_EPS).floor() as u64
            } else {
                self.commits_within(wall)
            };
            return BurstAccount { work: wall, commits, overhead: 0.0 };
        }
        // Commit k (k >= 1) starts at wall offset k·interval + (k-1)·cost
        // and is durable the instant it starts; its write window ends at
        // k·(interval + cost).
        let period = self.interval + self.cost;
        let ratio = (wall + self.cost) / period;
        let raw = if interrupted {
            (ratio + BOUNDARY_EPS).floor()
        } else {
            (ratio - BOUNDARY_EPS).ceil() - 1.0
        };
        let n = raw.max(0.0);
        let commits = n as u64;
        let end = n * period;
        if wall >= end {
            BurstAccount {
                work: n * self.interval + (wall - end),
                commits,
                overhead: n * self.cost,
            }
        } else {
            // Interrupted inside commit n's write window: the boundary is
            // committed; charge only the stall time actually elapsed.
            BurstAccount {
                work: n * self.interval,
                commits,
                overhead: (n * self.cost - (end - wall)).max(0.0),
            }
        }
    }
}

/// The effective per-commit stall for `p`'s gang: the flat
/// `checkpoint_cost` plus the bandwidth-bound per-server term
/// `checkpoint_cost_per_server × job_size` (a gang-wide barrier write
/// scales with the gang's aggregate state). Both knobs default to 0, so
/// the effective cost is 0 — and every commit path short-circuits —
/// unless one is configured. Used by `periodic`/`auto`/`young_daly`/
/// `adaptive`; `tiered` keeps its explicit per-tier costs.
pub(crate) fn effective_commit_cost(p: &Params) -> Time {
    p.checkpoint_cost + p.checkpoint_cost_per_server * p.job_size as f64
}

/// Young's optimal interval √(2·C·MTBF) for commit cost `C` and gang
/// failure rate `rate` (1/min). A rate of 0 yields an infinite interval:
/// no failures, no commits needed.
fn young_daly_interval(cost: Time, rate: f64) -> Time {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    (2.0 * cost / rate).sqrt()
}

/// The configured-rate gang interrupt estimate used before any
/// composition or observation is available: `job_size` random clocks
/// plus the expected bad fraction's systematic clocks, plus — when a
/// topology carries outage rates — the domain-outage exposure of a
/// packed gang (one outage clock per distinct domain it would span).
/// Without the outage term, a cluster whose only interrupt source is
/// correlated outages would derive an infinite interval and never
/// commit.
fn configured_gang_rate(p: &Params) -> f64 {
    let mut rate = p.job_size as f64
        * (p.random_failure_rate + p.systematic_fraction * p.systematic_failure_rate);
    if let Some(topo) = &p.topology {
        let total = p.total_servers() as u64;
        let mut stride: u64 = 1;
        for level in &topo.levels {
            stride = stride.saturating_mul(level.size.max(1) as u64);
            if level.outage_rate <= 0.0 {
                continue;
            }
            let n_domains = total.div_ceil(stride).max(1);
            let spans = (p.job_size as u64).div_ceil(stride).max(1).min(n_domains);
            rate += spans as f64 * level.outage_rate;
        }
    }
    rate
}

/// The live gang interrupt rate of job `job`: the same composition
/// arithmetic as the `gang` failure model (one random clock per active
/// server, one extra systematic clock per bad active) plus, when a
/// topology carries outage rates, one outage clock per distinct domain
/// the gang actually touches — so an anti-affinity placement that spans
/// more domains checkpoints more often, exactly matching its exposure.
fn live_gang_rate(ctx: &SimCtx, job: usize) -> f64 {
    let active = &ctx.jobs[job].active;
    let n_bad = active.iter().filter(|&&id| ctx.fleet[id as usize].is_bad).count();
    let mut rate = active.len() as f64 * ctx.p.random_failure_rate
        + n_bad as f64 * ctx.p.systematic_failure_rate;
    if let Some(t) = &ctx.topo {
        let mut domains: Vec<u32> = Vec::new();
        for (l, lv) in t.levels().iter().enumerate() {
            if lv.outage_rate <= 0.0 {
                continue;
            }
            domains.clear();
            domains.extend(active.iter().map(|&id| t.domain_of(l, id)));
            domains.sort_unstable();
            domains.dedup();
            rate += domains.len() as f64 * lv.outage_rate;
        }
    }
    rate
}

// ------------------------------------------------------------------ //
// Continuous
// ------------------------------------------------------------------ //

/// The paper's continuous asynchronous checkpointing: all committed work
/// survives a failure; only the constant restore latency is paid and
/// commits cost nothing.
#[derive(Clone, Copy, Debug)]
pub struct Continuous {
    pub recovery_time: Time,
}

impl CheckpointPolicy for Continuous {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn work_lost(&mut self, _job: usize, _done: Time) -> Time {
        0.0
    }

    fn restart_cost(&self, _job: usize) -> Time {
        self.recovery_time
    }
}

// ------------------------------------------------------------------ //
// Periodic
// ------------------------------------------------------------------ //

/// Checkpoints are committed every `interval` minutes of useful work, at
/// `cost` wall minutes per commit; progress past the last committed
/// checkpoint is lost on failure. `interval <= 0` degenerates to
/// [`Continuous`] (only reachable via `auto`; naming `periodic`
/// explicitly with a zero interval is a build error).
#[derive(Clone, Copy, Debug)]
pub struct Periodic {
    pub interval: Time,
    pub cost: Time,
    pub recovery_time: Time,
}

impl Periodic {
    fn clock(&self) -> CommitClock {
        CommitClock { interval: self.interval, cost: self.cost }
    }
}

impl CheckpointPolicy for Periodic {
    fn name(&self) -> &'static str {
        "periodic"
    }

    fn work_lost(&mut self, _job: usize, done: Time) -> Time {
        if self.interval <= 0.0 {
            return 0.0;
        }
        // Epsilon-tolerant floor: `done` sits on a committed multiple
        // only up to FP error after a restore; without the slack the
        // next failure can floor one interval low and re-lose committed
        // work.
        let committed = (done / self.interval + BOUNDARY_EPS).floor() * self.interval;
        (done - committed).max(0.0)
    }

    fn restart_cost(&self, _job: usize) -> Time {
        self.recovery_time
    }

    fn wall_for_work(&self, _job: usize, _done0: Time, work: Time) -> Time {
        self.clock().wall_for_work(work)
    }

    fn account_burst(
        &mut self,
        _job: usize,
        _done0: Time,
        wall: Time,
        interrupted: bool,
    ) -> BurstAccount {
        // Bursts always start at a committed checkpoint (losses restore
        // to one), so the schedule relative to the burst start is the
        // absolute multiple schedule `work_lost` floors against.
        self.clock().account(wall, interrupted)
    }
}

// ------------------------------------------------------------------ //
// Young/Daly (one struct, pluggable MTBF source)
// ------------------------------------------------------------------ //

/// Sliding window of observed interrupt inter-arrivals per job.
const ADAPTIVE_WINDOW: usize = 16;

/// Where a [`SelfTuning`] policy gets its gang MTBF estimate from. The
/// interval/`last_committed` machinery is identical for both policies —
/// only this estimate differs — so they share one struct.
#[derive(Clone, Debug)]
pub enum MtbfSource {
    /// `young_daly`: the configured failure/outage rates applied to the
    /// live gang composition at every burst start.
    ConfiguredRate,
    /// `adaptive`: a per-job sliding window of observed interrupt
    /// inter-arrivals (running-burst lengths that ended in an interrupt),
    /// falling back to the configured-rate estimate until the first
    /// interrupt is observed.
    SlidingWindow {
        /// Configured-rate MTBF estimate (the cold-start fallback).
        fallback_mtbf: Time,
        /// Observed burst lengths per job, newest last.
        window: Vec<Vec<Time>>,
    },
}

/// Self-optimizing Young/Daly interval: √(2·C·MTBF_gang), recomputed
/// every time the job (re-)enters Running from whatever the policy's
/// [`MtbfSource`] currently estimates — the configured rates over the
/// live gang composition (`young_daly`), or a sliding window of observed
/// interrupts (`adaptive`). Commits move with the interval, so the last
/// committed point is tracked per job instead of floored from a fixed
/// grid.
#[derive(Clone, Debug)]
pub struct SelfTuning {
    cost: Time,
    recovery_time: Time,
    source: MtbfSource,
    /// Current interval per job (configured-rate estimate until the
    /// first burst).
    interval: Vec<Time>,
    /// Absolute work point of the newest committed checkpoint per job.
    last_committed: Vec<Time>,
}

impl SelfTuning {
    fn new(n_jobs: usize, p: &Params, source: MtbfSource) -> SelfTuning {
        let cost = effective_commit_cost(p);
        let initial = young_daly_interval(cost, configured_gang_rate(p));
        SelfTuning {
            cost,
            recovery_time: p.recovery_time,
            source,
            interval: vec![initial; n_jobs],
            last_committed: vec![0.0; n_jobs],
        }
    }

    /// The `young_daly` policy: configured-rate MTBF source.
    pub fn young_daly(n_jobs: usize, p: &Params) -> SelfTuning {
        SelfTuning::new(n_jobs, p, MtbfSource::ConfiguredRate)
    }

    /// The `adaptive` policy: sliding-window MTBF source.
    pub fn adaptive(n_jobs: usize, p: &Params) -> SelfTuning {
        let rate = configured_gang_rate(p);
        let fallback_mtbf = if rate > 0.0 { 1.0 / rate } else { f64::INFINITY };
        SelfTuning::new(
            n_jobs,
            p,
            MtbfSource::SlidingWindow {
                fallback_mtbf,
                window: vec![Vec::new(); n_jobs],
            },
        )
    }

    /// The interval currently in force for `job` (test hook).
    pub fn interval(&self, job: usize) -> Time {
        self.interval[job]
    }

    fn clock(&self, job: usize) -> CommitClock {
        CommitClock { interval: self.interval[job], cost: self.cost }
    }

    /// The gang interrupt rate (1/min) the next interval derives from.
    fn rate(&self, ctx: &SimCtx, job: usize) -> f64 {
        match &self.source {
            MtbfSource::ConfiguredRate => live_gang_rate(ctx, job),
            MtbfSource::SlidingWindow { fallback_mtbf, window } => {
                let w = &window[job];
                let mtbf = if w.is_empty() {
                    *fallback_mtbf
                } else {
                    w.iter().sum::<Time>() / w.len() as f64
                };
                // One formula, one site: the observed MTBF feeds the same
                // Young/Daly helper the configured-rate source uses.
                if mtbf.is_finite() {
                    1.0 / mtbf
                } else {
                    0.0
                }
            }
        }
    }
}

impl CheckpointPolicy for SelfTuning {
    fn name(&self) -> &'static str {
        match self.source {
            MtbfSource::ConfiguredRate => "young_daly",
            MtbfSource::SlidingWindow { .. } => "adaptive",
        }
    }

    fn work_lost(&mut self, job: usize, done: Time) -> Time {
        (done - self.last_committed[job]).max(0.0)
    }

    fn restart_cost(&self, _job: usize) -> Time {
        self.recovery_time
    }

    fn wall_for_work(&self, job: usize, _done0: Time, work: Time) -> Time {
        self.clock(job).wall_for_work(work)
    }

    fn account_burst(
        &mut self,
        job: usize,
        done0: Time,
        wall: Time,
        interrupted: bool,
    ) -> BurstAccount {
        let acct = self.clock(job).account(wall, interrupted);
        if acct.commits > 0 {
            // Milestones are relative to the burst start (itself the last
            // committed point), so intervals can change between bursts
            // without stranding the committed grid.
            self.last_committed[job] = done0 + acct.commits as f64 * self.interval[job];
        }
        if interrupted {
            if let MtbfSource::SlidingWindow { window, .. } = &mut self.source {
                let w = &mut window[job];
                if w.len() == ADAPTIVE_WINDOW {
                    w.remove(0);
                }
                w.push(wall);
            }
        }
        acct
    }

    fn on_start_running(&mut self, ctx: &SimCtx, job: usize) {
        self.interval[job] = young_daly_interval(self.cost, self.rate(ctx, job));
    }
}

// ------------------------------------------------------------------ //
// Tiered
// ------------------------------------------------------------------ //

/// Two commit tiers on fixed absolute grids: a cheap-frequent tier
/// (`checkpoint_interval` / `checkpoint_cost`, restored at
/// `recovery_time`) and an expensive-rare tier
/// (`checkpoint_tier2_interval` / `checkpoint_tier2_cost`, restored at
/// `checkpoint_tier2_restore`). A failure restores from the nearest
/// committed tier — ties (coincident grid points write both tiers) go to
/// the cheap tier. The write stalls add: a coincident boundary pays both
/// costs.
#[derive(Clone, Debug)]
pub struct Tiered {
    cheap_interval: Time,
    cheap_cost: Time,
    cheap_restore: Time,
    tier2_interval: Time,
    tier2_cost: Time,
    tier2_restore: Time,
    /// Absolute work point of the newest commit per tier, per job.
    last_cheap: Vec<Time>,
    last_tier2: Vec<Time>,
    /// Whether job `job`'s next restore comes from the expensive tier
    /// (set by [`Tiered::work_lost`], read by restart_cost).
    restore_tier2: Vec<bool>,
}

impl Tiered {
    pub fn new(n_jobs: usize, p: &Params) -> Tiered {
        let tier2_restore = if p.checkpoint_tier2_restore > 0.0 {
            p.checkpoint_tier2_restore
        } else {
            p.recovery_time
        };
        Tiered {
            cheap_interval: p.checkpoint_interval,
            cheap_cost: p.checkpoint_cost,
            cheap_restore: p.recovery_time,
            tier2_interval: p.checkpoint_tier2_interval,
            tier2_cost: p.checkpoint_tier2_cost,
            tier2_restore,
            last_cheap: vec![0.0; n_jobs],
            last_tier2: vec![0.0; n_jobs],
            restore_tier2: vec![false; n_jobs],
        }
    }

    /// The next commit milestone strictly after absolute work point
    /// `after`: (work point, write cost, cheap committed, tier2
    /// committed). Coincident grid points merge into one milestone that
    /// writes both tiers.
    fn next_milestone(&self, after: Time) -> (Time, Time, bool, bool) {
        let next_of = |interval: Time| -> Time {
            ((after / interval + BOUNDARY_EPS).floor() + 1.0) * interval
        };
        let w1 = next_of(self.cheap_interval);
        let w2 = next_of(self.tier2_interval);
        if (w1 - w2).abs() <= BOUNDARY_EPS * w2.abs().max(1.0) {
            (w2, self.cheap_cost + self.tier2_cost, true, true)
        } else if w1 < w2 {
            (w1, self.cheap_cost, true, false)
        } else {
            (w2, self.tier2_cost, false, true)
        }
    }
}

impl CheckpointPolicy for Tiered {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn work_lost(&mut self, job: usize, done: Time) -> Time {
        let (cheap, tier2) = (self.last_cheap[job], self.last_tier2[job]);
        // Nearest committed tier; on a tie both tiers hold the point and
        // the cheap (fast) restore wins.
        self.restore_tier2[job] = tier2 > cheap;
        (done - cheap.max(tier2)).max(0.0)
    }

    fn restart_cost(&self, job: usize) -> Time {
        if self.restore_tier2[job] {
            self.tier2_restore
        } else {
            self.cheap_restore
        }
    }

    fn wall_for_work(&self, _job: usize, done0: Time, work: Time) -> Time {
        let target = done0 + work;
        let slack = BOUNDARY_EPS * target.abs().max(1.0);
        let mut pos = done0;
        let mut cost = 0.0;
        loop {
            let (w, c, _, _) = self.next_milestone(pos);
            if w >= target - slack {
                // Completion-coincident commits are skipped.
                return work + cost;
            }
            cost += c;
            pos = w;
        }
    }

    fn account_burst(
        &mut self,
        job: usize,
        done0: Time,
        wall: Time,
        interrupted: bool,
    ) -> BurstAccount {
        if wall <= 0.0 {
            return BurstAccount::passthrough(wall);
        }
        let slack = BOUNDARY_EPS * wall.abs().max(1.0);
        let mut pos = done0; // absolute work reached
        let mut acc_cost = 0.0; // wall spent in completed write windows
        let mut out = BurstAccount::default();
        loop {
            let (w, c, cheap, tier2) = self.next_milestone(pos);
            let start = (w - done0) + acc_cost; // wall offset of this write
            let reached =
                if interrupted { start <= wall + slack } else { start < wall - slack };
            if !reached {
                out.work = (wall - acc_cost).max(0.0);
                out.overhead = acc_cost;
                return out;
            }
            // Committed (snapshots are atomic at the boundary).
            if cheap {
                out.commits += 1;
                self.last_cheap[job] = w;
            }
            if tier2 {
                out.commits += 1;
                self.last_tier2[job] = w;
            }
            if wall < start + c {
                // Interrupted inside this write window.
                out.work = w - done0;
                out.overhead = acc_cost + (wall - start).max(0.0);
                return out;
            }
            acc_cost += c;
            pos = w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_loses_nothing() {
        let mut c = Continuous { recovery_time: 20.0 };
        assert_eq!(c.work_lost(0, 123.4), 0.0);
        assert_eq!(c.restart_cost(0), 20.0);
        assert_eq!(c.wall_for_work(0, 0.0, 500.0), 500.0);
        assert_eq!(c.account_burst(0, 0.0, 77.0, true), BurstAccount::passthrough(77.0));
    }

    #[test]
    fn periodic_loses_past_last_commit() {
        let mut p = Periodic { interval: 30.0, cost: 0.0, recovery_time: 20.0 };
        assert!((p.work_lost(0, 100.0) - 10.0).abs() < 1e-9);
        assert!(p.work_lost(0, 90.0).abs() < 1e-9, "exact boundary loses nothing");
        assert!((p.work_lost(0, 29.9) - 29.9).abs() < 1e-9);
    }

    #[test]
    fn periodic_zero_interval_degenerates_to_continuous() {
        let mut p = Periodic { interval: 0.0, cost: 0.0, recovery_time: 20.0 };
        assert_eq!(p.work_lost(0, 500.0), 0.0);
    }

    /// Satellite bugfix: `done` restored to a committed multiple only up
    /// to FP error must not floor one interval low on the next failure.
    #[test]
    fn work_lost_floor_is_fp_tolerant() {
        // 0.7 + 0.1 = 0.7999999999999999 < 0.8: the naive floor loses the
        // whole interval again.
        let mut p = Periodic { interval: 0.8, cost: 0.0, recovery_time: 20.0 };
        let done = 0.7 + 0.1;
        assert!(done < 0.8, "test premise: FP lands below the boundary");
        assert!(p.work_lost(0, done).abs() < 1e-9, "re-lost committed work");
    }

    /// Repeated failures landing exactly on commit boundaries: committed
    /// work must never be lost twice, regardless of FP drift in `done`.
    #[test]
    fn repeated_boundary_failures_never_relose_work() {
        let interval = 0.1;
        let mut p = Periodic { interval, cost: 0.0, recovery_time: 20.0 };
        let mut done = 0.0f64;
        for k in 1..=100 {
            done += interval; // burst ends exactly at the k-th boundary
            let lost = p.work_lost(0, done);
            assert!(lost.abs() < 1e-9, "step {k}: re-lost {lost} of committed work");
            done -= lost;
        }
        assert!((done - 10.0).abs() < 1e-6, "all 100 intervals committed: {done}");
    }

    #[test]
    fn commit_clock_dilates_and_inverts() {
        let c = CommitClock { interval: 100.0, cost: 10.0 };
        // 250 work = 2 commits inside (at 100 and 200; none at 250).
        assert_eq!(c.wall_for_work(250.0), 270.0);
        // Exact-multiple completion skips the final commit.
        assert_eq!(c.wall_for_work(300.0), 320.0);
        // Inversion at completion reproduces the work.
        let a = c.account(270.0, false);
        assert!((a.work - 250.0).abs() < 1e-9);
        assert_eq!(a.commits, 2);
        assert!((a.overhead - 20.0).abs() < 1e-9);
        let a = c.account(320.0, false);
        assert!((a.work - 300.0).abs() < 1e-9);
        assert_eq!(a.commits, 2, "completion-coincident commit skipped");
    }

    #[test]
    fn commit_clock_interrupt_during_write_is_committed() {
        let c = CommitClock { interval: 100.0, cost: 10.0 };
        // Interrupt at wall 105: commit 1 started at 100, write half done.
        let a = c.account(105.0, true);
        assert_eq!(a.commits, 1, "snapshot is atomic at the boundary");
        assert!((a.work - 100.0).abs() < 1e-9);
        assert!((a.overhead - 5.0).abs() < 1e-9, "only elapsed stall counts");
        // Interrupt exactly at the write start: committed, zero overhead.
        let a = c.account(100.0, true);
        assert_eq!(a.commits, 1);
        assert!((a.work - 100.0).abs() < 1e-9);
        assert!(a.overhead.abs() < 1e-9);
        // Interrupt mid-work after a full write window.
        let a = c.account(160.0, true);
        assert_eq!(a.commits, 1);
        assert!((a.work - 150.0).abs() < 1e-9);
        assert!((a.overhead - 10.0).abs() < 1e-9);
    }

    #[test]
    fn commit_clock_cost_zero_is_exact_passthrough() {
        let c = CommitClock { interval: 37.0, cost: 0.0 };
        for wall in [0.0, 1.5, 36.999999, 37.0, 1234.567] {
            assert_eq!(c.wall_for_work(wall), wall, "bit-identical wall");
            let a = c.account(wall, true);
            assert_eq!(a.work, wall, "bit-identical work");
            assert_eq!(a.overhead, 0.0);
        }
        assert_eq!(c.account(74.0, true).commits, 2);
        assert_eq!(c.account(74.0, false).commits, 1, "completion skips the boundary");
    }

    #[test]
    fn effective_cost_scales_with_gang_size() {
        let mut p = Params::small_test();
        p.checkpoint_cost = 2.0;
        p.checkpoint_cost_per_server = 0.5;
        assert_eq!(effective_commit_cost(&p), 2.0 + 0.5 * p.job_size as f64);
        // Either knob alone supplies a positive effective cost.
        p.checkpoint_cost = 0.0;
        assert_eq!(effective_commit_cost(&p), 0.5 * p.job_size as f64);
        // Both at their defaults: 0 — the byte-identity short-circuit.
        p.checkpoint_cost_per_server = 0.0;
        assert_eq!(effective_commit_cost(&p), 0.0);
        // The per-server term feeds the self-tuning interval: a bigger
        // effective cost widens √(2·C·MTBF) exactly as a flat cost would.
        p.checkpoint_cost_per_server = 1.0;
        let scaled = SelfTuning::young_daly(1, &p);
        p.checkpoint_cost_per_server = 0.0;
        p.checkpoint_cost = p.job_size as f64;
        let flat = SelfTuning::young_daly(1, &p);
        assert_eq!(scaled.interval[0], flat.interval[0]);
        assert_eq!(scaled.cost, flat.cost);
    }

    #[test]
    fn young_daly_formula() {
        // MTBF 500 min, cost 10 min -> sqrt(2*10*500) = 100.
        assert!((young_daly_interval(10.0, 1.0 / 500.0) - 100.0).abs() < 1e-9);
        assert_eq!(young_daly_interval(10.0, 0.0), f64::INFINITY);
    }

    #[test]
    fn young_daly_tracks_commits_across_interval_changes() {
        let mut p = Params::small_test();
        p.checkpoint_cost = 10.0;
        let mut yd = SelfTuning::young_daly(1, &p);
        assert_eq!(yd.name(), "young_daly");
        yd.interval[0] = 100.0;
        // Burst from 0: wall 270 = 250 work, commits at 100 and 200.
        let a = yd.account_burst(0, 0.0, 270.0, true);
        assert_eq!(a.commits, 2);
        assert!((yd.last_committed[0] - 200.0).abs() < 1e-9);
        assert!((yd.work_lost(0, 250.0) - 50.0).abs() < 1e-9);
        // Interval changes; the committed point stays where it was.
        yd.interval[0] = 80.0;
        assert!((yd.work_lost(0, 250.0) - 50.0).abs() < 1e-9);
        // Next burst from 200 commits relative to 200: 200 + 80 = 280.
        let a = yd.account_burst(0, 200.0, 95.0, true);
        assert_eq!(a.commits, 1);
        assert!((yd.last_committed[0] - 280.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_interval_follows_observed_interarrivals() {
        let mut p = Params::small_test();
        p.checkpoint_cost = 10.0;
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        let mut a = SelfTuning::adaptive(1, &p);
        assert_eq!(a.name(), "adaptive");
        // The observed MTBF behind the interval the source would derive.
        let observed_mtbf = |a: &SelfTuning| -> Time {
            let MtbfSource::SlidingWindow { fallback_mtbf, window } = &a.source else {
                panic!("adaptive uses the sliding-window source")
            };
            let w = &window[0];
            if w.is_empty() {
                *fallback_mtbf
            } else {
                w.iter().sum::<Time>() / w.len() as f64
            }
        };
        assert_eq!(observed_mtbf(&a), f64::INFINITY, "no rates, no observations");
        // Observe interrupts every ~200 minutes of running.
        for _ in 0..8 {
            a.account_burst(0, 0.0, 200.0, true);
        }
        assert!((observed_mtbf(&a) - 200.0).abs() < 1e-9);
        let ctx_free = crate::model::ctx::SimCtx::new(&p, crate::sim::rng::Rng::new(1));
        a.on_start_running(&ctx_free, 0);
        assert!((a.interval(0) - (2.0f64 * 10.0 * 200.0).sqrt()).abs() < 1e-9);
        // The window slides: old samples age out.
        for _ in 0..ADAPTIVE_WINDOW {
            a.account_burst(0, 0.0, 50.0, true);
        }
        assert!((observed_mtbf(&a) - 50.0).abs() < 1e-9);
        // Completions are not interrupts and must not enter the window.
        a.account_burst(0, 0.0, 9999.0, false);
        assert!((observed_mtbf(&a) - 50.0).abs() < 1e-9);
        // The configured-rate twin never grows a window: interrupts leave
        // its source untouched (the fold must not cross-contaminate).
        let mut yd = SelfTuning::young_daly(1, &p);
        yd.account_burst(0, 0.0, 200.0, true);
        assert!(matches!(yd.source, MtbfSource::ConfiguredRate));
    }

    #[test]
    fn gang_rate_counts_domain_outage_exposure() {
        // A cluster whose ONLY interrupt source is correlated outages
        // must still yield a finite Young/Daly interval.
        let mut p = Params::small_test(); // 72 + 16 servers
        p.checkpoint_cost = 10.0;
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        p.systematic_fraction = 0.0;
        p.topology = Some(crate::config::TopologySpec {
            levels: vec![crate::config::TopologyLevelSpec {
                name: "rack".into(),
                size: 8,
                outage_rate: 0.001,
            }],
        });
        // Packed estimate: a 64-gang spans 8 of the 11 rack domains.
        let rate = configured_gang_rate(&p);
        assert!((rate - 8.0 * 0.001).abs() < 1e-12, "{rate}");
        assert!(SelfTuning::young_daly(1, &p).interval(0).is_finite());

        // Live rate counts the domains the gang actually touches.
        let mut ctx = crate::model::ctx::SimCtx::new(&p, crate::sim::rng::Rng::new(1));
        ctx.jobs[0].active = (0..16).collect(); // racks 0 and 1
        let live = live_gang_rate(&ctx, 0);
        assert!((live - 2.0 * 0.001).abs() < 1e-12, "{live}");

        // Without a topology the rates stay the plain gang arithmetic.
        p.topology = None;
        assert_eq!(configured_gang_rate(&p), 0.0);
    }

    fn tiered_params() -> Params {
        let mut p = Params::small_test();
        p.checkpoint_interval = 100.0;
        p.checkpoint_cost = 5.0;
        p.checkpoint_tier2_interval = 300.0;
        p.checkpoint_tier2_cost = 20.0;
        p.checkpoint_tier2_restore = 60.0;
        p.recovery_time = 20.0;
        p
    }

    #[test]
    fn tiered_merges_coincident_boundaries_and_restores_nearest() {
        let mut t = Tiered::new(1, &tiered_params());
        // Work 0..250: cheap commits at 100 and 200 (5 each).
        assert!((t.wall_for_work(0, 0.0, 250.0) - 260.0).abs() < 1e-9);
        // Work 0..350: cheap at 100, 200 + coincident at 300 (5+20).
        assert!((t.wall_for_work(0, 0.0, 350.0) - 385.0).abs() < 1e-9);
        let a = t.account_burst(0, 0.0, 385.0, true);
        assert_eq!(a.commits, 4, "3 cheap + 1 tier2 (300 writes both)");
        assert!((a.work - 350.0).abs() < 1e-9);
        assert!((a.overhead - 35.0).abs() < 1e-9);
        assert!((t.last_cheap[0] - 300.0).abs() < 1e-9);
        assert!((t.last_tier2[0] - 300.0).abs() < 1e-9);
        // Failure at 350: nearest committed tier is the coincident 300 —
        // tie goes to the cheap (fast) restore.
        assert!((t.work_lost(0, 350.0) - 50.0).abs() < 1e-9);
        assert_eq!(t.restart_cost(0), 20.0);
    }

    #[test]
    fn tiered_distinct_restore_costs() {
        let mut t = Tiered::new(1, &tiered_params());
        // A long burst: cheap commits at 100..800, tier2 at 300 and 600
        // (wall 880 = 800 work + 80 of commit stalls, ending exactly as
        // the 800-commit's write finishes).
        let a = t.account_burst(0, 0.0, 880.0, true);
        assert!((a.work - 800.0).abs() < 1e-9);
        assert!((t.last_cheap[0] - 800.0).abs() < 1e-9);
        assert!((t.last_tier2[0] - 600.0).abs() < 1e-9);
        // Nearest committed tier is the cheap 800: fast restore.
        let lost = t.work_lost(0, 800.0);
        assert!(lost.abs() < 1e-9);
        assert_eq!(t.restart_cost(0), 20.0, "cheap tier restores at recovery_time");
        // Force the tier2-nearest case directly.
        t.last_cheap[0] = 200.0;
        t.last_tier2[0] = 300.0;
        let lost = t.work_lost(0, 420.0);
        assert!((lost - 120.0).abs() < 1e-9, "restore to 300, the nearest tier");
        assert_eq!(t.restart_cost(0), 60.0, "tier2 restores at its own cost");
    }

    #[test]
    fn tiered_account_interrupt_inside_write_window() {
        let mut t = Tiered::new(1, &tiered_params());
        // Burst from 0; commit 1 (cheap) starts at wall 100; interrupt at
        // wall 102 — inside the 5-minute write.
        let a = t.account_burst(0, 0.0, 102.0, true);
        assert_eq!(a.commits, 1);
        assert!((a.work - 100.0).abs() < 1e-9);
        assert!((a.overhead - 2.0).abs() < 1e-9);
        assert!((t.last_cheap[0] - 100.0).abs() < 1e-9);
        assert_eq!(t.last_tier2[0], 0.0);
    }

    #[test]
    fn tiered_bursts_resume_from_tier2_grid_points() {
        let mut t = Tiered::new(1, &tiered_params());
        // done0 = 300 (a tier2 point, also cheap-coincident): next cheap
        // milestone is 400, not 300 again.
        let (w, c, cheap, tier2) = t.next_milestone(300.0);
        assert!((w - 400.0).abs() < 1e-9);
        assert!(cheap && !tier2);
        assert!((c - 5.0).abs() < 1e-9);
        // And accounting a burst from 300 commits at 400 first.
        let a = t.account_burst(0, 300.0, 120.0, true);
        assert_eq!(a.commits, 1);
        assert!((t.last_cheap[0] - 400.0).abs() < 1e-9);
    }
}
