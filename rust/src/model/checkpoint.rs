//! Checkpoint policies: what a failure costs in lost work and restart
//! latency (§I "restarting … from a previous checkpoint").
//!
//! | name | policy |
//! |---|---|
//! | `continuous` | [`Continuous`] — async checkpointing, no work lost (paper default) |
//! | `periodic`   | [`Periodic`] — commit every `checkpoint_interval` minutes of work |
//! | `auto`       | `periodic` when `checkpoint_interval > 0`, else `continuous` |

use crate::sim::Time;

/// Checkpoint semantics: lost work on interrupt + restore latency.
pub trait CheckpointPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Useful work lost when a failure interrupts a job that has
    /// completed `done` minutes of work since start.
    fn work_lost(&self, done: Time) -> Time;

    /// Checkpoint-restore latency charged per recovery.
    fn restart_cost(&self) -> Time;
}

/// The paper's continuous asynchronous checkpointing: all committed work
/// survives a failure; only the constant restore latency is paid.
#[derive(Clone, Copy, Debug)]
pub struct Continuous {
    pub recovery_time: Time,
}

impl CheckpointPolicy for Continuous {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn work_lost(&self, _done: Time) -> Time {
        0.0
    }

    fn restart_cost(&self) -> Time {
        self.recovery_time
    }
}

/// Checkpoints are committed every `interval` minutes of useful work;
/// progress past the last committed checkpoint is lost on failure.
/// `interval <= 0` degenerates to [`Continuous`].
#[derive(Clone, Copy, Debug)]
pub struct Periodic {
    pub interval: Time,
    pub recovery_time: Time,
}

impl CheckpointPolicy for Periodic {
    fn name(&self) -> &'static str {
        "periodic"
    }

    fn work_lost(&self, done: Time) -> Time {
        if self.interval <= 0.0 {
            return 0.0;
        }
        let committed = (done / self.interval).floor() * self.interval;
        done - committed
    }

    fn restart_cost(&self) -> Time {
        self.recovery_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_loses_nothing() {
        let c = Continuous { recovery_time: 20.0 };
        assert_eq!(c.work_lost(123.4), 0.0);
        assert_eq!(c.restart_cost(), 20.0);
    }

    #[test]
    fn periodic_loses_past_last_commit() {
        let p = Periodic { interval: 30.0, recovery_time: 20.0 };
        assert!((p.work_lost(100.0) - 10.0).abs() < 1e-9);
        assert!(p.work_lost(90.0).abs() < 1e-9, "exact boundary loses nothing");
        assert!((p.work_lost(29.9) - 29.9).abs() < 1e-9);
    }

    #[test]
    fn periodic_zero_interval_degenerates_to_continuous() {
        let p = Periodic { interval: 0.0, recovery_time: 20.0 };
        assert_eq!(p.work_lost(500.0), 0.0);
    }
}
