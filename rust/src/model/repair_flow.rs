//! The repair pipeline flow (module 4): retirement scoring, shop
//! admission, stage completion, and reintegration.
//!
//! Like [`crate::model::lifecycle`], this is dispatch glue: capacity and
//! silent-failure mechanics live in [`crate::model::repair`], the queue
//! discipline behind the pluggable `RepairPolicy` trait object.

use crate::model::ctx::SimCtx;
use crate::model::events::{Ev, RepairStage, ServerId};
use crate::model::job::JobPhase;
use crate::model::lifecycle;
use crate::model::policy::PolicySet;
use crate::model::repair::{self, Admission, AutoResult};
use crate::model::retirement;
use crate::model::server::ServerState;
use crate::sim::Time;
use crate::trace::TraceKind;

/// Retirement policy (§II-B): score the blamed server's failure and
/// either retire it permanently or send it to the repair pipeline.
pub(crate) fn retire_or_repair(
    ctx: &mut SimCtx,
    pol: &mut PolicySet,
    server: ServerId,
    now: Time,
) {
    let retire =
        retirement::record_and_decide(&ctx.p, &mut ctx.fleet[server as usize], now);
    if retire {
        let sv = &mut ctx.fleet[server as usize];
        sv.state = ServerState::Retired;
        sv.assigned_job = None;
        ctx.out.retirements += 1;
        ctx.tr(TraceKind::Retired { server });
    } else {
        start_repair(ctx, pol, server);
    }
}

/// Every failure goes to automated testing first (assumption 3).
pub(crate) fn start_repair(ctx: &mut SimCtx, pol: &mut PolicySet, server: ServerId) {
    enter_stage(ctx, pol, server, RepairStage::Automated);
}

/// Admission into a repair stage (possibly queueing on capacity).
fn enter_stage(ctx: &mut SimCtx, pol: &mut PolicySet, server: ServerId, stage: RepairStage) {
    // The queue index keys on the server's assigned job (stable while it
    // sits in the shop) so `job_first` picks without scanning; the
    // enqueue time feeds the `sla_aged` age check.
    let job = ctx.fleet[server as usize].assigned_job;
    let now = ctx.now();
    match ctx.shop.admit(&ctx.p, stage, server, job, now) {
        Admission::Start => start_stage(ctx, pol, server, stage),
        Admission::Queued => {
            // `shortest_first` ranks queued servers by how long their
            // repair will take: draw the stage duration now and stash it;
            // `start_stage` consumes the stash instead of drawing fresh.
            // Other disciplines never pre-draw, so their RNG order is
            // untouched.
            if pol.repair.name() == "shortest_first" {
                let d = repair::duration(&ctx.p, stage, &mut ctx.rng);
                ctx.fleet[server as usize].predrawn_repair = Some(d);
            }
            ctx.fleet[server as usize].state = ServerState::RepairQueued;
            ctx.tr(TraceKind::RepairQueued {
                server,
                manual: stage == RepairStage::Manual,
            });
        }
    }
}

fn start_stage(ctx: &mut SimCtx, _pol: &mut PolicySet, server: ServerId, stage: RepairStage) {
    ctx.fleet[server as usize].state = match stage {
        RepairStage::Automated => ServerState::AutoRepair,
        RepairStage::Manual => ServerState::ManualRepair,
    };
    // A pre-drawn duration (stashed at queue entry under `shortest_first`)
    // is the *same* sample the stage would draw here — consuming it keeps
    // the duration distribution exact.
    let predrawn = ctx.fleet[server as usize].predrawn_repair.take();
    let d = predrawn.unwrap_or_else(|| repair::duration(&ctx.p, stage, &mut ctx.rng));
    ctx.tr(TraceKind::RepairStart { server, manual: stage == RepairStage::Manual });
    ctx.engine.schedule_in(d, Ev::RepairDone { server, stage });
}

pub(crate) fn on_repair_done(
    ctx: &mut SimCtx,
    pol: &mut PolicySet,
    server: ServerId,
    stage: RepairStage,
) {
    // Free the shop slot; the repair policy picks who starts next.
    let now = ctx.now();
    let next = ctx.shop.complete(
        &ctx.p,
        stage,
        pol.repair.as_ref(),
        &ctx.fleet,
        &ctx.jobs,
        now,
    );
    if let Some(next) = next {
        start_stage(ctx, pol, next, stage);
    }

    match stage {
        RepairStage::Automated => match repair::auto_outcome(&ctx.p, &mut ctx.rng) {
            AutoResult::Escalate => {
                enter_stage(ctx, pol, server, RepairStage::Manual);
            }
            AutoResult::Resolved { fixed } => {
                reintegrate(ctx, pol, server, false, fixed);
            }
        },
        RepairStage::Manual => {
            let fixed = repair::manual_fixed(&ctx.p, &mut ctx.rng);
            reintegrate(ctx, pol, server, true, fixed);
        }
    }
}

/// Return a repaired server to service (assumption 5: a successful repair
/// turns a bad server good; a silent failure leaves it bad).
fn reintegrate(ctx: &mut SimCtx, pol: &mut PolicySet, server: ServerId, manual: bool, fixed: bool) {
    {
        let s = &mut ctx.fleet[server as usize];
        if fixed && s.is_bad {
            s.is_bad = false;
        }
        s.renew();
    }
    ctx.tr(TraceKind::RepairDone { server, manual, fixed });

    let jobs = &ctx.jobs;
    let assigned = ctx.fleet[server as usize]
        .assigned_job
        .map(|j| j as usize)
        .filter(|&j| jobs[j].wants_more(&ctx.p));
    match assigned {
        Some(j) => {
            // §II-B: returns to *its* job without host selection.
            ctx.fleet[server as usize].state = ServerState::JobStandby;
            ctx.jobs[j].standbys.push(server);
            if ctx.jobs[j].phase == JobPhase::Stalled {
                lifecycle::attempt_start(ctx, pol, j);
            }
        }
        None => {
            ctx.fleet[server as usize].assigned_job = None;
            ctx.pools.route_freed(&mut ctx.fleet, server);
            lifecycle::retry_stalled(ctx, pol);
        }
    }
}
