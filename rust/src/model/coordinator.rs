//! Paper module 2 — **Coordinator**: gang-failure propagation.
//!
//! "When a server fails, the coordinator is notified. In turn, it informs
//! the other servers in the group of the failure, and asks them to stop
//! executing the job (and initiate a fast recovery)." (§III-C)
//!
//! Concretely: pause the job (committing checkpointed progress), stop every
//! active server's failure clock (generation bump — in-flight `Fail`
//! events become stale), and accumulate each server's running age so
//! non-exponential clocks resume age-conditionally.

use crate::model::job::{Job, JobPhase};
use crate::model::server::{Server, ServerState};
use crate::sim::Time;

/// Interrupt the running gang at `now`. Returns the length of the running
/// burst that just ended (for the "average run duration" output).
pub fn interrupt(job: &mut Job, fleet: &mut [Server], now: Time) -> Time {
    debug_assert_eq!(job.phase, JobPhase::Running);
    let burst = job.pause(now);
    for &id in &job.active {
        let s = &mut fleet[id as usize];
        debug_assert_eq!(s.state, ServerState::JobActive);
        // Invalidate this server's in-flight failure event(s)...
        s.gen.bump();
        // ...and bank its running age for age-conditional resampling.
        s.run_age += now - s.active_since;
    }
    burst
}

/// Arm failure clocks: mark every active server computing from `now`.
/// The cluster event loop samples and schedules the actual `Fail` events
/// (it owns the RNG and the engine); this records the bookkeeping side.
pub fn mark_running(job: &Job, fleet: &mut [Server], now: Time) {
    for &id in &job.active {
        let s = &mut fleet[id as usize];
        debug_assert_eq!(s.state, ServerState::JobActive);
        s.active_since = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::Home;

    fn gang(n: u32) -> (Job, Vec<Server>) {
        let mut job = Job::new(10_000.0);
        let mut fleet: Vec<Server> =
            (0..n).map(|i| Server::new(i, false, Home::Working)).collect();
        for s in fleet.iter_mut() {
            s.state = ServerState::JobActive;
            job.active.push(s.id);
        }
        (job, fleet)
    }

    #[test]
    fn interrupt_pauses_and_bumps_generations() {
        let (mut job, mut fleet) = gang(8);
        job.resume(100.0);
        mark_running(&job, &mut fleet, 100.0);
        let gens_before: Vec<u64> = fleet.iter().map(|s| s.gen.0).collect();

        let burst = interrupt(&mut job, &mut fleet, 160.0);
        assert_eq!(burst, 60.0);
        assert_eq!(job.remaining, 10_000.0 - 60.0);
        for (s, g0) in fleet.iter().zip(gens_before) {
            assert_eq!(s.gen.0, g0 + 1, "server {} gen not bumped", s.id);
            assert_eq!(s.run_age, 60.0);
        }
    }

    #[test]
    fn ages_accumulate_across_bursts() {
        let (mut job, mut fleet) = gang(4);
        job.resume(0.0);
        mark_running(&job, &mut fleet, 0.0);
        interrupt(&mut job, &mut fleet, 50.0);

        job.resume(70.0);
        mark_running(&job, &mut fleet, 70.0);
        interrupt(&mut job, &mut fleet, 100.0);

        for s in &fleet {
            assert_eq!(s.run_age, 50.0 + 30.0);
        }
        assert_eq!(job.remaining, 10_000.0 - 80.0);
    }
}
