//! The cluster topology: a hierarchy of failure domains over the fleet.
//!
//! Server ids are assigned domain-contiguously at fleet construction (the
//! convention the old id-proximity `locality` policy already leaned on),
//! so a domain is a contiguous id range and membership is pure
//! arithmetic: server `s` belongs to domain `s / stride` of a level,
//! where `stride` is the cumulative product of the level sizes below it.
//! A fleet whose size does not divide a stride gets a trailing *partial*
//! domain — smaller blast radius, same failure behavior.
//!
//! Built once per run from the declarative
//! [`TopologySpec`](crate::config::TopologySpec) (`topology:` config
//! block) and exposed through [`crate::model::ctx::SimCtx::topo`]; the
//! consumers are the `anti_affinity`/`locality` selection policies
//! ([`crate::model::selection`]), the `correlated` failure model
//! ([`crate::model::failure::CorrelatedFailures`]), and the domain-outage
//! flow ([`crate::model::lifecycle`]).

use crate::config::TopologySpec;
use crate::model::events::ServerId;
use std::ops::Range;

/// One concrete failure-domain level.
#[derive(Clone, Debug, PartialEq)]
pub struct TopoLevel {
    /// Level name (labels trace events and reports).
    pub name: String,
    /// Servers per domain at this level (cumulative product of the spec's
    /// per-level sizes; the trailing domain may hold fewer).
    pub stride: u32,
    /// Number of domains covering the fleet (includes a trailing partial
    /// domain when the fleet size does not divide the stride).
    pub n_domains: u32,
    /// Outage rate of one domain at this level, 1/min.
    pub outage_rate: f64,
}

/// The fleet's failure-domain hierarchy, innermost level first.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    levels: Vec<TopoLevel>,
    total_servers: u32,
}

impl Topology {
    /// Materialize a spec for a concrete fleet size.
    pub fn build(spec: &TopologySpec, total_servers: u32) -> Topology {
        let mut levels = Vec::with_capacity(spec.levels.len());
        let mut stride = 1u32;
        for l in &spec.levels {
            stride = stride.saturating_mul(l.size.max(1));
            levels.push(TopoLevel {
                name: l.name.clone(),
                stride,
                n_domains: total_servers.div_ceil(stride).max(1),
                outage_rate: l.outage_rate,
            });
        }
        Topology { levels, total_servers }
    }

    pub fn levels(&self) -> &[TopoLevel] {
        &self.levels
    }

    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn total_servers(&self) -> u32 {
        self.total_servers
    }

    /// Which domain of `level` holds `server`.
    #[inline]
    pub fn domain_of(&self, level: usize, server: ServerId) -> u32 {
        server / self.levels[level].stride
    }

    /// The id range of one domain (the trailing domain is clipped to the
    /// fleet).
    pub fn servers_of(&self, level: usize, domain: u32) -> Range<ServerId> {
        let stride = self.levels[level].stride;
        let start = domain * stride;
        start..(start.saturating_add(stride)).min(self.total_servers)
    }

    /// Topological distance between two servers: the index of the first
    /// (innermost) level whose domains contain both, or `n_levels()` when
    /// no level does. 0 = same rack; lower = closer.
    #[inline]
    pub fn distance(&self, a: ServerId, b: ServerId) -> usize {
        for (l, level) in self.levels.iter().enumerate() {
            if a / level.stride == b / level.stride {
                return l;
            }
        }
        self.levels.len()
    }

    /// Aggregate outage rate over every domain of every level (the rate
    /// of the superposed domain-outage process, 1/min).
    pub fn total_outage_rate(&self) -> f64 {
        self.levels
            .iter()
            .map(|l| l.n_domains as f64 * l.outage_rate)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyLevelSpec;

    fn spec(levels: &[(&str, u32, f64)]) -> TopologySpec {
        TopologySpec {
            levels: levels
                .iter()
                .map(|&(name, size, outage_rate)| TopologyLevelSpec {
                    name: name.into(),
                    size,
                    outage_rate,
                })
                .collect(),
        }
    }

    #[test]
    fn strides_multiply_up_the_hierarchy() {
        let t = Topology::build(&spec(&[("rack", 8, 0.0), ("switch", 4, 0.0)]), 64);
        assert_eq!(t.levels()[0].stride, 8);
        assert_eq!(t.levels()[1].stride, 32);
        assert_eq!(t.levels()[0].n_domains, 8);
        assert_eq!(t.levels()[1].n_domains, 2);
        assert_eq!(t.domain_of(0, 7), 0);
        assert_eq!(t.domain_of(0, 8), 1);
        assert_eq!(t.domain_of(1, 31), 0);
        assert_eq!(t.domain_of(1, 32), 1);
    }

    #[test]
    fn non_dividing_fleet_gets_trailing_partial_domain() {
        let t = Topology::build(&spec(&[("rack", 4, 0.0)]), 10);
        assert_eq!(t.levels()[0].n_domains, 3);
        assert_eq!(t.servers_of(0, 0), 0..4);
        assert_eq!(t.servers_of(0, 2), 8..10, "partial trailing domain");
        assert_eq!(t.domain_of(0, 9), 2);
    }

    #[test]
    fn distance_ascends_levels() {
        let t = Topology::build(&spec(&[("rack", 4, 0.0), ("switch", 2, 0.0)]), 32);
        assert_eq!(t.distance(0, 3), 0, "same rack");
        assert_eq!(t.distance(0, 4), 1, "same switch, different rack");
        assert_eq!(t.distance(0, 8), 2, "different switch");
        assert_eq!(t.distance(5, 5), 0);
    }

    #[test]
    fn total_outage_rate_sums_domains() {
        let t = Topology::build(&spec(&[("rack", 4, 0.5), ("switch", 2, 0.25)]), 32);
        // 8 racks * 0.5 + 4 switches * 0.25 = 5.0
        assert!((t.total_outage_rate() - 5.0).abs() < 1e-12);
        let quiet = Topology::build(&spec(&[("rack", 4, 0.0)]), 32);
        assert_eq!(quiet.total_outage_rate(), 0.0);
    }
}
