//! The AI training job: gang semantics, checkpointed progress, phases.
//!
//! The paper's §II-A model: the job needs `job_size` servers computing in
//! task-synchronous parallelism; any active server's failure kills the
//! whole iteration; asynchronous checkpoints mean work completed *before*
//! the failure is preserved and only the recovery latency is paid.

use crate::config::Params;
use crate::model::events::ServerId;
use crate::sim::event::Generation;
use crate::sim::Time;

/// Job lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// All `job_size` active servers computing.
    Running,
    /// Host selection in progress (standbys were exhausted).
    Selecting,
    /// Checkpoint restore in progress (after swap-in or selection).
    Recovering,
    /// Not enough servers to reach `job_size`; waiting for arrivals.
    Stalled,
    /// Finished.
    Done,
}

/// One AI training job. The paper's assumption 6 runs a single job;
/// `Params::num_jobs` lifts it (the extension the paper names), with all
/// jobs contending for the same pools and repair shop.
#[derive(Clone, Debug)]
pub struct Job {
    /// Index into the simulation's job table.
    pub id: u32,
    pub phase: JobPhase,
    /// Work remaining, in minutes of failure-free execution.
    pub remaining: Time,
    /// When the current running burst started (valid in Running).
    pub run_start: Time,
    /// Servers actively computing.
    pub active: Vec<ServerId>,
    /// Warm standbys: allotted, powered, not computing.
    pub standbys: Vec<ServerId>,
    /// Generation guarding JobComplete / RecoveryDone / SelectionDone.
    pub gen: Generation,
    /// When the job entered Stalled (to account stall time).
    pub stalled_since: Time,
    /// When the current checkpoint-restore recovery will finish (valid in
    /// Recovering): a recovery cut short refunds `recovery_end - now` of
    /// the cost charged up front, so `recovery_total` accrues only
    /// recovery time actually spent.
    pub recovery_end: Time,
    /// When a correlated domain outage last stopped this job, if it has
    /// not resumed running since (attributes downtime to domain events).
    pub domain_down_since: Option<Time>,

    // ---- per-job shape (workload job-mix classes) ----
    /// Gang size for this job; 0 = "use `Params::job_size`" (the legacy
    /// homogeneous path and directly-constructed test jobs).
    pub size: u32,
    /// Warm-standby target for this job (only meaningful when `size > 0`;
    /// the homogeneous path reads `Params::warm_standbys`).
    pub standbys_target: u32,
    /// Failure-free length of this job in minutes (every constructor sets
    /// it; workload classes override the `Params::job_len` default).
    pub len: Time,

    // ---- open-loop arrival bookkeeping (workload subsystem) ----
    /// Has the job arrived? Legacy jobs are constructed arrived; workload
    /// jobs flip this in the `JobArrival` handler. An unarrived job takes
    /// no servers and blocks no repair routing.
    pub arrived: bool,
    /// When the job arrived (admission-wait accounting).
    pub arrived_at: Time,
    /// Has the job been admitted (first successful allocation)? Guards
    /// the one-shot admission metrics; legacy jobs are born admitted.
    pub admitted: bool,
}

impl Job {
    pub fn new(job_len: Time) -> Self {
        Self::with_id(0, job_len)
    }

    pub fn with_id(id: u32, job_len: Time) -> Self {
        Job {
            id,
            phase: JobPhase::Stalled, // until first host selection completes
            remaining: job_len,
            run_start: 0.0,
            active: Vec::new(),
            standbys: Vec::new(),
            gen: Generation::default(),
            stalled_since: 0.0,
            recovery_end: 0.0,
            domain_down_since: None,
            size: 0,
            standbys_target: 0,
            len: job_len,
            arrived: true,
            arrived_at: 0.0,
            admitted: true,
        }
    }

    /// Re-initialize in place for a new run, keeping the server-list
    /// allocations (the batched replication runner resets jobs this way).
    pub fn reset(&mut self, id: u32, job_len: Time) {
        self.id = id;
        self.phase = JobPhase::Stalled;
        self.remaining = job_len;
        self.run_start = 0.0;
        self.active.clear();
        self.standbys.clear();
        self.gen = Generation::default();
        self.stalled_since = 0.0;
        self.recovery_end = 0.0;
        self.domain_down_since = None;
        self.size = 0;
        self.standbys_target = 0;
        self.len = job_len;
        self.arrived = true;
        self.arrived_at = 0.0;
        self.admitted = true;
    }

    /// This job's `(gang size, warm-standby target)`: its own class shape
    /// when one was assigned (`size > 0`), else the homogeneous Table-I
    /// values — identical arithmetic, so the legacy path is bit-for-bit
    /// unchanged.
    #[inline]
    pub fn shape(&self, p: &Params) -> (u32, u32) {
        if self.size > 0 {
            (self.size, self.standbys_target)
        } else {
            (p.job_size, p.warm_standbys)
        }
    }

    /// Total servers currently allotted to the job.
    pub fn allotted(&self) -> usize {
        self.active.len() + self.standbys.len()
    }

    /// Is the job live and under its full allotment (`size +
    /// standbys_target`, per-job)? The single source of truth for "this
    /// job would take another server": repair reintegration,
    /// preemption-arrival routing, and the `job_first` repair priority
    /// all key on it. A job that has not arrived yet takes nothing.
    pub fn wants_more(&self, p: &Params) -> bool {
        let (size, standbys) = self.shape(p);
        self.arrived
            && self.phase != JobPhase::Done
            && self.allotted() < (size + standbys) as usize
    }

    /// Commit the progress of a running burst that ends now.
    /// Returns the burst duration.
    pub fn pause(&mut self, now: Time) -> Time {
        debug_assert_eq!(self.phase, JobPhase::Running);
        let ran = now - self.run_start;
        debug_assert!(ran >= -1e-9, "negative burst {ran}");
        self.remaining = (self.remaining - ran).max(0.0);
        ran.max(0.0)
    }

    /// Enter the running phase at `now`; caller schedules JobComplete.
    pub fn resume(&mut self, now: Time) {
        self.phase = JobPhase::Running;
        self.run_start = now;
    }

    /// Remove a server from the job's bookkeeping (wherever it sits).
    /// Returns true if it was part of the job.
    pub fn remove(&mut self, id: ServerId) -> bool {
        if let Some(i) = self.active.iter().position(|&s| s == id) {
            self.active.swap_remove(i);
            return true;
        }
        if let Some(i) = self.standbys.iter().position(|&s| s == id) {
            self.standbys.swap_remove(i);
            return true;
        }
        false
    }

    /// Promote one standby to active; returns it.
    pub fn promote_standby(&mut self) -> Option<ServerId> {
        let s = self.standbys.pop()?;
        self.active.push(s);
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pause_commits_progress() {
        let mut j = Job::new(1000.0);
        j.resume(10.0);
        let ran = j.pause(110.0);
        assert_eq!(ran, 100.0);
        assert_eq!(j.remaining, 900.0);
    }

    #[test]
    fn pause_clamps_at_zero() {
        let mut j = Job::new(50.0);
        j.resume(0.0);
        j.pause(80.0);
        assert_eq!(j.remaining, 0.0);
    }

    #[test]
    fn reset_reuses_allocations() {
        let mut j = Job::with_id(3, 500.0);
        j.active = vec![1, 2, 3];
        j.standbys = vec![4];
        j.resume(10.0);
        j.pause(60.0);
        j.gen.bump();
        j.recovery_end = 99.0;
        j.size = 16;
        j.arrived = false;
        j.admitted = false;
        j.arrived_at = 40.0;
        j.reset(0, 1000.0);
        assert_eq!(j.id, 0);
        assert_eq!(j.recovery_end, 0.0);
        assert_eq!(j.phase, JobPhase::Stalled);
        assert_eq!(j.remaining, 1000.0);
        assert!(j.active.is_empty() && j.standbys.is_empty());
        assert_eq!(j.gen.0, 0);
        assert_eq!((j.size, j.len), (0, 1000.0));
        assert!(j.arrived && j.admitted);
        assert_eq!(j.arrived_at, 0.0);
    }

    #[test]
    fn shape_falls_back_to_params() {
        let p = Params::small_test();
        let mut j = Job::new(100.0);
        assert_eq!(j.shape(&p), (p.job_size, p.warm_standbys));
        j.size = 8;
        j.standbys_target = 0;
        assert_eq!(j.shape(&p), (8, 0), "per-job shape wins, even 0 standbys");
    }

    #[test]
    fn unarrived_job_wants_nothing() {
        let p = Params::small_test();
        let mut j = Job::new(100.0);
        assert!(j.wants_more(&p), "legacy jobs are born arrived");
        j.arrived = false;
        assert!(!j.wants_more(&p));
        j.arrived = true;
        j.phase = JobPhase::Done;
        assert!(!j.wants_more(&p));
    }

    #[test]
    fn remove_from_active_and_standby() {
        let mut j = Job::new(10.0);
        j.active = vec![1, 2, 3];
        j.standbys = vec![4, 5];
        assert!(j.remove(2));
        assert!(j.remove(5));
        assert!(!j.remove(99));
        assert_eq!(j.active.len(), 2);
        assert_eq!(j.standbys.len(), 1);
        assert_eq!(j.allotted(), 3);
    }

    #[test]
    fn promote_standby_moves_server() {
        let mut j = Job::new(10.0);
        j.standbys = vec![7];
        let s = j.promote_standby().unwrap();
        assert_eq!(s, 7);
        assert_eq!(j.active, vec![7]);
        assert!(j.standbys.is_empty());
        assert!(j.promote_standby().is_none());
    }
}
