//! Bad-server regeneration (assumption 1, case 2): "bad servers are
//! regenerated periodically (e.g., end of life aging or new hardware
//! models being integrated into the cluster)".
//!
//! Every `bad_regen_interval` minutes, each currently-good, non-retired
//! server independently turns bad with probability `bad_regen_fraction`
//! — a fresh cohort of latent systematic defects entering the fleet.

use crate::config::Params;
use crate::model::server::{Server, ServerState};
use crate::sim::rng::Rng;

/// Apply one regeneration tick. Returns how many servers turned bad.
pub fn regenerate(p: &Params, fleet: &mut [Server], rng: &mut Rng) -> usize {
    let mut converted = 0;
    for s in fleet.iter_mut() {
        if !s.is_bad
            && s.state != ServerState::Retired
            && rng.bernoulli(p.bad_regen_fraction)
        {
            s.is_bad = true;
            converted += 1;
        }
    }
    converted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::build_fleet;

    #[test]
    fn zero_fraction_converts_nobody() {
        let mut p = Params::small_test();
        p.bad_regen_fraction = 0.0;
        let mut rng = Rng::new(1);
        let mut fleet = build_fleet(&p, &mut rng);
        assert_eq!(regenerate(&p, &mut fleet, &mut rng), 0);
    }

    #[test]
    fn conversion_rate_close_to_fraction() {
        let mut p = Params::small_test();
        p.systematic_fraction = 0.0; // start all-good
        p.bad_regen_fraction = 0.1;
        let mut rng = Rng::new(2);
        let mut total_good = 0usize;
        let mut total_converted = 0usize;
        for seed in 0..200 {
            let mut fleet = build_fleet(&p, &mut Rng::new(seed));
            total_good += fleet.len();
            total_converted += regenerate(&p, &mut fleet, &mut rng);
        }
        let rate = total_converted as f64 / total_good as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn already_bad_and_retired_untouched() {
        let mut p = Params::small_test();
        p.systematic_fraction = 1.0; // everyone bad
        p.bad_regen_fraction = 1.0;
        let mut rng = Rng::new(3);
        let mut fleet = build_fleet(&p, &mut rng);
        fleet[0].is_bad = false;
        fleet[0].state = ServerState::Retired;
        assert_eq!(regenerate(&p, &mut fleet, &mut rng), 0);
        assert!(!fleet[0].is_bad, "retired server must not be converted");
    }
}
