//! The job lifecycle flow of Figure 1: failure handling, allocation,
//! recovery, running, completion, and preemption arrivals.
//!
//! Every function here is dispatch glue over the shared [`SimCtx`] and
//! the pluggable [`PolicySet`]: the *decisions* (which server to take,
//! what a failure costs, when clocks fire) are delegated to the policy
//! traits; this module sequences them.

use crate::model::ctx::SimCtx;
use crate::model::diagnosis::{self, Diagnosis};
use crate::model::events::{Ev, FailureKind, ServerId};
use crate::model::job::JobPhase;
use crate::model::policy::PolicySet;
use crate::model::regen;
use crate::model::repair_flow;
use crate::model::scheduler;
use crate::model::server::ServerState;
use crate::sim::Time;
use crate::trace::inject::Injection;
use crate::trace::TraceKind;

pub(crate) fn on_fail(
    ctx: &mut SimCtx,
    pol: &mut PolicySet,
    server: ServerId,
    gen: u64,
    kind: FailureKind,
) {
    let s = &ctx.fleet[server as usize];
    // Lazy cancellation: stale clock, or server no longer computing.
    if s.gen.0 != gen || s.state != ServerState::JobActive {
        return;
    }
    let Some(j) = s.assigned_job.map(|j| j as usize) else {
        return;
    };
    if ctx.jobs[j].phase != JobPhase::Running {
        return;
    }
    handle_failure(ctx, pol, j, server, kind);
}

pub(crate) fn on_gang_fail(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize, gang_gen: u64) {
    if ctx.jobs[j].phase != JobPhase::Running {
        return;
    }
    if let Some((victim, kind)) = pol.failure.resolve_gang_fail(ctx, j, gang_gen) {
        handle_failure(ctx, pol, j, victim, kind);
    }
}

/// A scripted failure fires: resolve the victim now; drop cleanly if the
/// target job does not exist or is not running (the injection missed its
/// window).
pub(crate) fn on_inject(ctx: &mut SimCtx, pol: &mut PolicySet, inj: Injection) {
    // Server-targeted form (`workload: replay:` re-injecting recorded
    // failures): fail that server wherever it computes; dropped cleanly
    // if it is not computing at `at`.
    if let Some(server) = inj.server {
        if server as usize >= ctx.fleet.len() {
            return;
        }
        let s = &ctx.fleet[server as usize];
        if s.state != ServerState::JobActive {
            return;
        }
        let Some(j) = s.assigned_job.map(|j| j as usize) else {
            return;
        };
        if ctx.jobs[j].phase != JobPhase::Running {
            return;
        }
        handle_failure(ctx, pol, j, server, inj.kind);
        return;
    }
    let j = inj.job as usize;
    if j >= ctx.jobs.len() {
        return;
    }
    if ctx.jobs[j].phase != JobPhase::Running || ctx.jobs[j].active.is_empty() {
        return;
    }
    let victim = ctx.jobs[j].active[inj.victim_index % ctx.jobs[j].active.len()];
    handle_failure(ctx, pol, j, victim, inj.kind);
}

/// Common failure path (stochastic clock or injection) for job `j`.
pub(crate) fn handle_failure(
    ctx: &mut SimCtx,
    pol: &mut PolicySet,
    j: usize,
    server: ServerId,
    kind: FailureKind,
) {
    let now = ctx.now();

    // Count the failure.
    ctx.out.failures_total += 1;
    match kind {
        FailureKind::Random => ctx.out.failures_random += 1,
        FailureKind::Systematic => ctx.out.failures_systematic += 1,
    }
    ctx.tr(TraceKind::Failure { server, systematic: kind == FailureKind::Systematic });

    // Module 2 (coordinator): stop the gang, commit progress. The failure
    // model owns the per-server vs aggregate clock split.
    let r0 = ctx.jobs[j].remaining; // work remaining at burst start
    let burst = pol.failure.interrupt(ctx, j, now);
    ctx.burst_sum += burst;
    ctx.burst_count += 1;
    account_interrupted_burst(ctx, pol, j, r0, burst);
    ctx.jobs[j].gen.bump(); // invalidate JobComplete / stale phase events

    // Diagnosis (inputs 12–13) — allocation-free over the active list
    // (which still contains the failed server at this point).
    let diag =
        diagnosis::diagnose_in_gang(&ctx.p, server, &ctx.jobs[j].active, &mut ctx.rng);

    let to_repair: Option<ServerId> = match diag {
        Diagnosis::Undiagnosed => {
            ctx.out.undiagnosed += 1;
            None
        }
        Diagnosis::Correct(id) => Some(id),
        Diagnosis::Wrong { blamed, .. } => {
            ctx.out.wrong_diagnoses += 1;
            Some(blamed)
        }
    };

    match to_repair {
        None => {
            // Restart in place after recovery: nobody leaves the gang.
            begin_recovery(ctx, pol, j);
        }
        Some(blamed) => {
            // The blamed server leaves the job.
            let was_bad = ctx.fleet[blamed as usize].is_bad;
            pol.failure.note_removed(j, was_bad);
            let removed = ctx.jobs[j].remove(blamed);
            debug_assert!(removed, "blamed server {blamed} not in job {j}");

            repair_flow::retire_or_repair(ctx, pol, blamed, now);

            // Replacement: warm standby if available, else selection.
            if let Some(promoted) = ctx.jobs[j].promote_standby() {
                let is_bad = ctx.fleet[promoted as usize].is_bad;
                pol.failure.note_promoted(j, is_bad);
                ctx.fleet[promoted as usize].state = ServerState::JobActive;
                ctx.out.standby_swaps += 1;
                ctx.tr(TraceKind::StandbySwap { failed: blamed, replacement: promoted });
                begin_recovery(ctx, pol, j);
            } else {
                ctx.out.host_selections += 1;
                attempt_start(ctx, pol, j);
            }
        }
    }
}

/// End-of-burst accounting at an interrupt: convert the wall-clock burst
/// into useful work (commit stalls are wall time, not progress), then
/// lose work past the last committed checkpoint. `r0` is the job's
/// `remaining` as it stood when the burst started (the failure model's
/// `interrupt` subtracts wall time and clamps, which loses information
/// once commits stretch the burst past `remaining`).
fn account_interrupted_burst(
    ctx: &mut SimCtx,
    pol: &mut PolicySet,
    j: usize,
    r0: Time,
    burst: Time,
) {
    let done0 = ctx.jobs[j].len - r0;
    let acct = pol.checkpoint.account_burst(j, done0, burst, true);
    ctx.out.checkpoints_committed += acct.commits;
    ctx.out.checkpoint_overhead += acct.overhead;
    // Same expression `pause` used, in useful-work terms — bit-identical
    // when the policy has no commit cost (acct.work == burst exactly).
    ctx.jobs[j].remaining = (r0 - acct.work).max(0.0);
    let done = ctx.jobs[j].len - ctx.jobs[j].remaining;
    let lost = pol.checkpoint.work_lost(j, done);
    ctx.jobs[j].remaining += lost;
    ctx.out.work_lost += lost;
}

/// Enter checkpoint-restore recovery (cost set by the checkpoint policy).
pub(crate) fn begin_recovery(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize) {
    ctx.jobs[j].phase = JobPhase::Recovering;
    let cost = pol.checkpoint.restart_cost(j);
    ctx.tr(TraceKind::RecoveryStart { cost });
    ctx.out.recovery_total += cost;
    ctx.jobs[j].recovery_end = ctx.now() + cost;
    let gen = ctx.jobs[j].gen.0;
    ctx.engine.schedule_in(cost, Ev::RecoveryDone { job: j as u32, gen });
}

/// A recovery in progress is being cut short (e.g. a domain outage broke
/// the gang mid-restore): refund the unelapsed remainder that
/// [`begin_recovery`] charged up front, so `recovery_total` accrues only
/// recovery time actually spent. The retry charges its own full cost —
/// without the refund an interrupted recovery double-charges time the
/// job never spent recovering.
pub(crate) fn interrupt_recovery(ctx: &mut SimCtx, j: usize) {
    debug_assert_eq!(ctx.jobs[j].phase, JobPhase::Recovering);
    let remainder = (ctx.jobs[j].recovery_end - ctx.now()).max(0.0);
    ctx.out.recovery_total -= remainder;
}

/// (Re-)allocation: Figure 1's host-selection / stall decision.
pub(crate) fn attempt_start(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize) {
    let was_stalled = ctx.jobs[j].phase == JobPhase::Stalled;
    let alloc = scheduler::allocate(
        &ctx.p,
        pol.selection.as_mut(),
        &mut ctx.jobs[j],
        &mut ctx.pools,
        &mut ctx.fleet,
        ctx.topo.as_ref(),
        &mut ctx.rng,
    );
    for &id in &alloc.preempted {
        ctx.tr(TraceKind::Preempted { server: id });
        ctx.engine.schedule_in(ctx.p.waiting_time, Ev::PreemptArrive { server: id });
    }
    if alloc.can_start {
        // One-shot admission: the first successful allocation after an
        // open-loop arrival leaves the admission queue (legacy jobs are
        // born admitted, so this path stays dormant without `workload:`).
        if !ctx.jobs[j].admitted {
            ctx.jobs[j].admitted = true;
            let wait = ctx.now() - ctx.jobs[j].arrived_at;
            ctx.out.jobs_admitted += 1;
            ctx.out.queue_wait_total += wait;
            ctx.wait_p50.insert(wait);
            ctx.wait_p99.insert(wait);
            ctx.queued_now -= 1;
            ctx.tr(TraceKind::JobAdmitted { job: j as u32, waited: wait });
        }
        if was_stalled {
            let waited = ctx.now() - ctx.jobs[j].stalled_since;
            ctx.out.stall_time += waited;
            ctx.tr(TraceKind::Unstalled { waited });
        }
        ctx.jobs[j].phase = JobPhase::Selecting;
        let allotted = ctx.jobs[j].allotted();
        ctx.tr(TraceKind::HostSelection { allotted });
        let gen = ctx.jobs[j].gen.0;
        ctx.engine
            .schedule_in(ctx.p.host_selection_time, Ev::SelectionDone { job: j as u32, gen });
    } else {
        if !was_stalled {
            ctx.jobs[j].stalled_since = ctx.now();
        }
        ctx.jobs[j].phase = JobPhase::Stalled;
        let allotted = ctx.jobs[j].allotted();
        ctx.tr(TraceKind::Stalled { allotted });
    }
}

/// Give every stalled job another allocation attempt (a server just
/// became available somewhere). Jobs that have not arrived yet sit in
/// the initial `Stalled` phase but are not in the system.
pub(crate) fn retry_stalled(ctx: &mut SimCtx, pol: &mut PolicySet) {
    for j in 0..ctx.jobs.len() {
        if ctx.jobs[j].phase == JobPhase::Stalled && ctx.jobs[j].arrived {
            attempt_start(ctx, pol, j);
        }
    }
}

/// An open-loop arrival fires ([`crate::model::workload`]): the job
/// enters the system, joins the admission queue, and immediately tries
/// to allocate.
pub(crate) fn on_job_arrival(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize) {
    debug_assert!(!ctx.jobs[j].arrived, "job {j} arrived twice");
    let now = ctx.now();
    ctx.jobs[j].arrived = true;
    ctx.jobs[j].arrived_at = now;
    // Stall accounting starts at arrival, not t=0.
    ctx.jobs[j].stalled_since = now;
    let (size, standbys) = ctx.jobs[j].shape(&ctx.p);
    let len = ctx.jobs[j].len;
    ctx.tr(TraceKind::JobArrival { job: j as u32, size, len, standbys });
    ctx.out.jobs_arrived += 1;
    ctx.queued_now += 1;
    ctx.out.queue_depth_max = ctx.out.queue_depth_max.max(ctx.queued_now);
    attempt_start(ctx, pol, j);
}

pub(crate) fn on_selection_done(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize, gen: u64) {
    if ctx.jobs[j].gen.0 != gen || ctx.jobs[j].phase != JobPhase::Selecting {
        return;
    }
    let ok = scheduler::activate(&ctx.p, &mut ctx.jobs[j], &mut ctx.fleet);
    debug_assert!(ok, "selection completed without enough servers");
    pol.failure.recount(ctx, j);
    if ctx.jobs[j].remaining < ctx.jobs[j].len {
        // There is a checkpoint to restore.
        begin_recovery(ctx, pol, j);
    } else {
        start_running(ctx, pol, j);
    }
}

pub(crate) fn on_recovery_done(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize, gen: u64) {
    if ctx.jobs[j].gen.0 != gen || ctx.jobs[j].phase != JobPhase::Recovering {
        return;
    }
    ctx.tr(TraceKind::RecoveryDone);
    // Standbys may have arrived while recovering; top the gang up.
    let before = ctx.jobs[j].active.len();
    let ok = scheduler::activate(&ctx.p, &mut ctx.jobs[j], &mut ctx.fleet);
    debug_assert!(ok, "recovery completed without enough servers");
    if ctx.jobs[j].active.len() != before {
        pol.failure.recount(ctx, j); // rare: arrivals promoted mid-recovery
    }
    start_running(ctx, pol, j);
}

/// Arm the gang and let job `j` run.
pub(crate) fn start_running(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize) {
    let now = ctx.now();
    debug_assert!(ctx.jobs[j].active.len() >= ctx.jobs[j].shape(&ctx.p).0 as usize);
    // Close out downtime attributed to a correlated domain outage.
    if let Some(t) = ctx.jobs[j].domain_down_since.take() {
        ctx.out.domain_downtime += now - t;
    }
    ctx.jobs[j].resume(now);
    pol.failure.mark_running(ctx, j, now);
    if ctx.jobs[j].remaining >= ctx.jobs[j].len {
        ctx.tr(TraceKind::JobStarted);
    }
    // Self-optimizing checkpoint policies re-derive their interval from
    // the gang composition now armed (no RNG draws); the interval holds
    // for the whole burst.
    pol.checkpoint.on_start_running(ctx, j);
    // Completion clock first (FIFO tie-break: completion wins a tie
    // against a failure at the exact same instant). Commit stalls
    // stretch the wall clock past the useful work remaining.
    let gen = ctx.jobs[j].gen.0;
    let remaining = ctx.jobs[j].remaining;
    let wall = pol.checkpoint.wall_for_work(j, ctx.jobs[j].len - remaining, remaining);
    ctx.engine.schedule_in(wall, Ev::JobComplete { job: j as u32, gen });
    // Failure clocks (module 1), per the failure model.
    pol.failure.arm(ctx, j);
}

pub(crate) fn on_job_complete(ctx: &mut SimCtx, pol: &mut PolicySet, j: usize, gen: u64) {
    if ctx.jobs[j].gen.0 != gen || ctx.jobs[j].phase != JobPhase::Running {
        return;
    }
    let now = ctx.now();
    let r0 = ctx.jobs[j].remaining;
    let burst = ctx.jobs[j].pause(now);
    ctx.burst_sum += burst;
    ctx.burst_count += 1;
    // The final burst's commit stalls were wall time, not work: account
    // them and restate `remaining` in useful-work terms (bit-identical
    // to `pause`'s arithmetic when commits are free).
    let acct = pol.checkpoint.account_burst(j, ctx.jobs[j].len - r0, burst, false);
    ctx.out.checkpoints_committed += acct.commits;
    ctx.out.checkpoint_overhead += acct.overhead;
    ctx.jobs[j].remaining = (r0 - acct.work).max(0.0);
    debug_assert!(ctx.jobs[j].remaining <= 1e-6);
    ctx.jobs[j].phase = JobPhase::Done;
    ctx.out.per_job_makespans[j] = now;
    ctx.tr(TraceKind::JobCompleted { makespan: now });

    // Release the job's servers back to the pools (other jobs may be
    // waiting on them).
    let mut released: Vec<ServerId> = ctx.jobs[j].active.drain(..).collect();
    released.extend(ctx.jobs[j].standbys.drain(..));
    for id in released {
        let s = &mut ctx.fleet[id as usize];
        s.gen.bump(); // retire any in-flight per-server clocks
        s.assigned_job = None;
        ctx.pools.route_freed(&mut ctx.fleet, id);
    }
    pol.failure.recount(ctx, j); // active drained: zero
    retry_stalled(ctx, pol);
}

pub(crate) fn on_preempt_arrive(ctx: &mut SimCtx, pol: &mut PolicySet, server: ServerId) {
    ctx.pools.arrive(&mut ctx.fleet, server);
    ctx.tr(TraceKind::PreemptArrived { server });
    // Offer the arrival to the neediest job (stalled first, then any
    // under-allotted one), in id order.
    let jobs = &ctx.jobs;
    let pick = (0..jobs.len())
        .filter(|&j| jobs[j].wants_more(&ctx.p))
        .min_by_key(|&j| (jobs[j].phase != JobPhase::Stalled, j));
    match pick {
        Some(j) => {
            let s = &mut ctx.fleet[server as usize];
            s.state = ServerState::JobStandby;
            s.assigned_job = Some(j as u32);
            ctx.jobs[j].standbys.push(server);
            if ctx.jobs[j].phase == JobPhase::Stalled {
                attempt_start(ctx, pol, j);
            }
        }
        None => {
            // No longer needed: drain back.
            ctx.pools.route_freed(&mut ctx.fleet, server);
            retry_stalled(ctx, pol);
        }
    }
}

/// A correlated domain outage: the failure model resolves *which* domain
/// was struck (and re-arms its clock); this flow takes every up-server in
/// that domain down as one event.
///
/// Scope of the blast: servers currently computing (`JobActive`), warm
/// standbys, and idle working-pool servers — everything on the struck
/// fabric. Servers already in the repair pipeline, in spare-pool transit,
/// or retired are unaffected; the spare pool itself runs off-fabric
/// (other workloads, other network), so `SparePool` servers are exempt.
/// Victims go through the normal repair pipeline but do *not* accrue
/// retirement/failure-history score — the outage is exogenous to the
/// server (a switch died, not the host).
pub(crate) fn on_domain_outage(ctx: &mut SimCtx, pol: &mut PolicySet) {
    let Some((level, domain)) = pol.failure.resolve_domain_outage(ctx) else {
        return;
    };
    let now = ctx.now();
    let range = ctx
        .topo
        .as_ref()
        .expect("domain outage without a topology")
        .servers_of(level, domain);
    // Collect the blast in id order (deterministic processing order).
    let mut hit: Vec<ServerId> = Vec::new();
    for id in range {
        if matches!(
            ctx.fleet[id as usize].state,
            ServerState::JobActive | ServerState::JobStandby | ServerState::WorkingIdle
        ) {
            hit.push(id);
        }
    }
    ctx.out.domain_failures += 1;
    ctx.out.domain_servers_lost += hit.len() as u64;
    ctx.out.domain_max_blast = ctx.out.domain_max_blast.max(hit.len() as u64);
    ctx.tr(TraceKind::DomainFailure {
        level: level as u32,
        domain_id: domain,
        servers_hit: hit.len(),
    });
    if hit.is_empty() {
        return;
    }

    // Pause every running job that lost an active server, *before*
    // detaching anyone: progress and per-server ages must be committed
    // against the pre-blast gang. `hit_actives` remembers each job's
    // fallen active servers in id order, to pair standby swaps with
    // their victims in the trace.
    let mut interrupted: Vec<usize> = Vec::new(); // ascending job ids
    let mut hit_actives: Vec<(usize, ServerId)> = Vec::new();
    for &id in &hit {
        if ctx.fleet[id as usize].state == ServerState::JobActive {
            let j = ctx.fleet[id as usize].assigned_job.expect("active implies assigned")
                as usize;
            hit_actives.push((j, id));
            if ctx.jobs[j].phase == JobPhase::Running && !interrupted.contains(&j) {
                interrupted.push(j);
            }
        }
    }
    for &j in &interrupted {
        let r0 = ctx.jobs[j].remaining;
        let burst = pol.failure.interrupt(ctx, j, now);
        ctx.burst_sum += burst;
        ctx.burst_count += 1;
        account_interrupted_burst(ctx, pol, j, r0, burst);
        ctx.jobs[j].gen.bump(); // invalidate JobComplete
        ctx.jobs[j].domain_down_since = Some(now);
    }

    // Detach the victims and send them through the repair pipeline. No
    // diagnosis draw (the struck domain is self-evident) and no
    // retirement score; `assigned_job` stays set so job servers return
    // to their job after repair, exactly like a blamed failure (§II-B).
    let mut touched: Vec<usize> = Vec::new(); // jobs that lost any server
    for &id in &hit {
        let state = ctx.fleet[id as usize].state;
        ctx.fleet[id as usize].gen.bump(); // retire in-flight per-server clocks
        match state {
            ServerState::WorkingIdle => {
                let removed = ctx.pools.remove_idle(id);
                debug_assert!(removed, "idle server {id} missing from the free-list");
            }
            ServerState::JobActive | ServerState::JobStandby => {
                let j = ctx.fleet[id as usize]
                    .assigned_job
                    .expect("allotted implies assigned") as usize;
                if state == ServerState::JobActive {
                    pol.failure.note_removed(j, ctx.fleet[id as usize].is_bad);
                }
                let removed = ctx.jobs[j].remove(id);
                debug_assert!(removed, "server {id} not in job {j}");
                if !touched.contains(&j) {
                    touched.push(j);
                }
            }
            _ => unreachable!("only up states are collected"),
        }
        repair_flow::start_repair(ctx, pol, id);
    }

    // Let every interrupted job continue: refill from warm standbys when
    // the blast fits, else the whole-job interruption `anti_affinity`
    // placement exists to avoid — a full host selection.
    for &j in &interrupted {
        // Pair each promotion with one of this job's fallen actives (id
        // order), so the trace's swap events name their victims exactly
        // as the single-failure path does.
        let mut victims = hit_actives.iter().filter(|&&(job, _)| job == j);
        let size = ctx.jobs[j].shape(&ctx.p).0 as usize;
        while ctx.jobs[j].active.len() < size {
            match ctx.jobs[j].promote_standby() {
                Some(s) => {
                    let is_bad = ctx.fleet[s as usize].is_bad;
                    pol.failure.note_promoted(j, is_bad);
                    ctx.fleet[s as usize].state = ServerState::JobActive;
                    ctx.out.standby_swaps += 1;
                    let &(_, failed) =
                        victims.next().expect("one fallen active per promotion");
                    ctx.tr(TraceKind::StandbySwap { failed, replacement: s });
                }
                None => break,
            }
        }
        if ctx.jobs[j].active.len() >= size {
            begin_recovery(ctx, pol, j);
        } else {
            ctx.out.domain_job_interruptions += 1;
            ctx.out.host_selections += 1;
            attempt_start(ctx, pol, j);
        }
    }

    // Jobs disrupted outside Running (standby theft, or servers stolen
    // mid-recovery/selection): when the surviving allotment can no longer
    // cover `job_size`, invalidate the pending phase event and re-select
    // — a RecoveryDone/SelectionDone must never find the gang short.
    for j in touched {
        if interrupted.contains(&j) {
            continue;
        }
        match ctx.jobs[j].phase {
            JobPhase::Recovering | JobPhase::Selecting
                if ctx.jobs[j].allotted() < ctx.jobs[j].shape(&ctx.p).0 as usize =>
            {
                if ctx.jobs[j].phase == JobPhase::Recovering {
                    // The restore is cut short: only the elapsed recovery
                    // time stays charged (the retry pays its own cost).
                    interrupt_recovery(ctx, j);
                }
                ctx.jobs[j].gen.bump();
                ctx.jobs[j].domain_down_since.get_or_insert(now);
                ctx.out.domain_job_interruptions += 1;
                ctx.out.host_selections += 1;
                attempt_start(ctx, pol, j);
            }
            // Running (lost standbys only), Stalled (no pending event,
            // repairs will re-trigger it), or still-covered phases: the
            // normal flow absorbs the loss.
            _ => {}
        }
    }
}

pub(crate) fn on_bad_regen(ctx: &mut SimCtx, pol: &mut PolicySet) {
    let converted = regen::regenerate(&ctx.p, &mut ctx.fleet, &mut ctx.rng);
    ctx.out.regenerated_bad += converted as u64;
    ctx.tr(TraceKind::Regenerated { converted });
    if converted > 0 {
        for j in 0..ctx.jobs.len() {
            // Conversions may touch active servers regardless of phase.
            pol.failure.recount(ctx, j);
            // Running gangs get their clocks re-armed against the new
            // composition.
            if ctx.jobs[j].phase != JobPhase::Running {
                continue;
            }
            pol.failure.regen_rearm(ctx, j);
        }
    }
    ctx.engine.schedule_in(ctx.p.bad_regen_interval, Ev::BadRegen);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Params;
    use crate::sim::rng::Rng;

    /// Satellite bugfix regression: `begin_recovery` charges the full
    /// restart cost up front; a recovery cut short mid-flight must keep
    /// only the elapsed time charged, and the retry charges its own full
    /// cost — the pre-fix code kept both full costs, over-counting
    /// recovery time the job never spent.
    #[test]
    fn interrupted_recovery_accrues_only_elapsed_time() {
        let p = Params::small_test(); // recovery_time = 20
        let mut ctx = SimCtx::new(&p, Rng::new(1));
        let mut pol = PolicySet::defaults(&p);

        // A 20-minute recovery starts at t = 0.
        begin_recovery(&mut ctx, &mut pol, 0);
        assert_eq!(ctx.jobs[0].phase, JobPhase::Recovering);
        assert_eq!(ctx.out.recovery_total, 20.0, "charged up front");
        assert_eq!(ctx.jobs[0].recovery_end, 20.0);

        // The clock advances to t = 5 (mid-recovery)...
        ctx.engine.schedule_at(5.0, Ev::BadRegen);
        let _ = ctx.engine.pop();
        assert_eq!(ctx.now(), 5.0);

        // ...and a domain outage cuts the recovery short: only the 5
        // elapsed minutes stay charged.
        interrupt_recovery(&mut ctx, 0);
        assert_eq!(
            ctx.out.recovery_total, 5.0,
            "an interrupted recovery accrues only elapsed time (pre-fix: 20)"
        );

        // The retry charges its own full cost; the total is 5 + 20, not
        // the pre-fix 20 + 20.
        ctx.jobs[0].gen.bump();
        begin_recovery(&mut ctx, &mut pol, 0);
        assert_eq!(ctx.out.recovery_total, 25.0);
    }

    /// A recovery that runs to completion stays charged exactly once —
    /// the refund path must not touch the normal flow.
    #[test]
    fn completed_recovery_accounting_is_unchanged() {
        let p = Params::small_test();
        let mut ctx = SimCtx::new(&p, Rng::new(2));
        let mut pol = PolicySet::defaults(&p);
        begin_recovery(&mut ctx, &mut pol, 0);
        // Pop the RecoveryDone event: the full cost elapsed.
        let (at, _) = ctx.engine.pop().expect("RecoveryDone scheduled");
        assert_eq!(at, 20.0);
        assert_eq!(ctx.out.recovery_total, 20.0);
    }
}
