//! Policy plumbing: the [`PolicySet`] of trait objects a simulation runs
//! with, and the name-based [`PolicySpec`] that YAML scenarios, sweeps,
//! and the CLI use to select implementations.
//!
//! ```yaml
//! policies:
//!   selection: locality      # first_fit | random | locality | anti_affinity | power_of_two_choices | history_scored
//!   repair: job_first        # fifo | lifo | job_first | sla_aged | shortest_first | pool_aware
//!   checkpoint: periodic     # auto | continuous | periodic | young_daly | adaptive | tiered
//!   failure: auto            # auto | gang | per_server | thinned | correlated
//! ```
//!
//! `anti_affinity` and `correlated` require a configured `topology:`
//! block (rejected at build time otherwise); `auto` failure clocks wrap
//! themselves in [`CorrelatedFailures`] whenever the topology carries
//! outage rates, so topology configs get domain outages without naming a
//! model.

use crate::config::{DistKind, Params};
use crate::model::checkpoint::{
    effective_commit_cost, CheckpointPolicy, Continuous, Periodic, SelfTuning, Tiered,
};
use crate::model::failure::{
    CorrelatedFailures, FailureModel, GangExponential, PerServerClocks, ThinnedClocks,
};
use crate::model::repair::{
    Fifo, JobFirst, Lifo, PoolAware, RepairPolicy, ShortestFirst, SlaAged,
};
use crate::model::selection::{
    AntiAffinity, FirstFit, HistoryScored, Locality, PowerOfTwoChoices, Random,
    SelectionPolicy,
};

/// The four policy subsystems of one simulation run.
pub struct PolicySet {
    pub selection: Box<dyn SelectionPolicy>,
    pub repair: Box<dyn RepairPolicy>,
    pub checkpoint: Box<dyn CheckpointPolicy>,
    pub failure: Box<dyn FailureModel>,
}

impl PolicySet {
    /// The paper's default policies for `p` (first-fit selection, FIFO
    /// repair, interval-driven checkpointing, auto failure clocks).
    pub fn defaults(p: &Params) -> PolicySet {
        PolicySpec::default().build(p).expect("default spec always builds")
    }
}

/// Name-based policy selection — `Clone + Sync`, cheap to ship across
/// sweep threads and to parse from YAML/CLI.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicySpec {
    pub selection: String,
    pub repair: String,
    pub checkpoint: String,
    pub failure: String,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec {
            selection: "first_fit".into(),
            repair: "fifo".into(),
            checkpoint: "auto".into(),
            failure: "auto".into(),
        }
    }
}

/// Valid selection-policy names.
pub const SELECTION_NAMES: &[&str] = &[
    "first_fit",
    "random",
    "locality",
    "anti_affinity",
    "power_of_two_choices",
    "history_scored",
];
/// Valid repair-policy names.
pub const REPAIR_NAMES: &[&str] =
    &["fifo", "lifo", "job_first", "sla_aged", "shortest_first", "pool_aware"];
/// Valid checkpoint-policy names.
pub const CHECKPOINT_NAMES: &[&str] =
    &["auto", "continuous", "periodic", "young_daly", "adaptive", "tiered"];
/// Valid failure-model names.
pub const FAILURE_NAMES: &[&str] =
    &["auto", "gang", "per_server", "thinned", "correlated"];

impl PolicySpec {
    /// Set one axis by name (`selection`, `repair`, `checkpoint`,
    /// `failure`), validating the value against the registry.
    pub fn set(&mut self, axis: &str, value: &str) -> Result<(), String> {
        let (names, slot): (&[&str], &mut String) = match axis {
            "selection" => (SELECTION_NAMES, &mut self.selection),
            "repair" => (REPAIR_NAMES, &mut self.repair),
            "checkpoint" => (CHECKPOINT_NAMES, &mut self.checkpoint),
            "failure" => (FAILURE_NAMES, &mut self.failure),
            other => {
                return Err(format!(
                    "unknown policy axis `{other}` (expected selection, repair, \
                     checkpoint, or failure)"
                ))
            }
        };
        if !names.contains(&value) {
            return Err(format!(
                "unknown {axis} policy `{value}` (expected one of {})",
                names.join(", ")
            ));
        }
        *slot = value.to_string();
        Ok(())
    }

    /// Instantiate the policy set for a concrete parameter set (the
    /// `auto` names resolve against `p`).
    pub fn build(&self, p: &Params) -> Result<PolicySet, String> {
        let n_jobs = p.num_jobs.max(1) as usize;
        let selection: Box<dyn SelectionPolicy> = match self.selection.as_str() {
            "first_fit" => Box::new(FirstFit),
            "random" => Box::new(Random),
            "locality" => Box::new(Locality),
            "anti_affinity" => {
                if p.topology.is_none() {
                    return Err(
                        "selection policy `anti_affinity` requires a `topology:` block \
                         (it spreads gangs across failure domains)"
                            .into(),
                    );
                }
                Box::new(AntiAffinity)
            }
            "power_of_two_choices" => Box::new(PowerOfTwoChoices),
            "history_scored" => {
                if p.selection_history_window <= 0.0 {
                    return Err(
                        "selection policy `history_scored` requires \
                         `selection_history_window` > 0 (the sliding window its \
                         failure scores count within)"
                            .into(),
                    );
                }
                Box::new(HistoryScored)
            }
            other => return Err(format!("unknown selection policy `{other}`")),
        };
        let repair: Box<dyn RepairPolicy> = match self.repair.as_str() {
            "fifo" => Box::new(Fifo),
            "lifo" => Box::new(Lifo),
            "job_first" => Box::new(JobFirst),
            "sla_aged" => Box::new(SlaAged),
            "shortest_first" => Box::new(ShortestFirst),
            "pool_aware" => {
                // At the 0 default the mark is "always flush": every
                // drain-back repair would be deferred forever. Name the
                // knob instead of running a silently starved shop.
                if p.repair_pool_high_water <= 0.0 {
                    return Err(
                        "repair policy `pool_aware` requires `repair_pool_high_water` \
                         > 0 (the spare-pool fraction above which drain-back repairs \
                         are deferred; at 0 every repair would defer forever)"
                            .into(),
                    );
                }
                Box::new(PoolAware)
            }
            other => return Err(format!("unknown repair policy `{other}`")),
        };
        // The self-optimizing interval √(2·C·MTBF) is degenerate at C = 0
        // (a zero commit cost makes an infinitesimal interval optimal —
        // the exact degeneracy the cost knob exists to remove).
        let needs_cost = |name: &str| -> Result<(), String> {
            if effective_commit_cost(p) <= 0.0 {
                return Err(format!(
                    "checkpoint policy `{name}` requires `checkpoint_cost` (or \
                     `checkpoint_cost_per_server`) > 0 \
                     (its interval √(2·C·MTBF) is degenerate at C = 0; with free \
                     commits use `continuous` or `periodic`)"
                ));
            }
            Ok(())
        };
        let checkpoint: Box<dyn CheckpointPolicy> = match self.checkpoint.as_str() {
            "continuous" => Box::new(Continuous { recovery_time: p.recovery_time }),
            "periodic" => {
                // An explicit `periodic` with a zero interval used to
                // silently degenerate to `continuous`; name the knob
                // instead (the quiet fallback stays available as `auto`).
                if p.checkpoint_interval <= 0.0 {
                    return Err(
                        "checkpoint policy `periodic` requires `checkpoint_interval` > 0 \
                         (interval 0 is continuous checkpointing; say `continuous`, or \
                         `auto` to pick by interval)"
                            .into(),
                    );
                }
                Box::new(Periodic {
                    interval: p.checkpoint_interval,
                    cost: effective_commit_cost(p),
                    recovery_time: p.recovery_time,
                })
            }
            "young_daly" => {
                needs_cost("young_daly")?;
                Box::new(SelfTuning::young_daly(n_jobs, p))
            }
            "adaptive" => {
                needs_cost("adaptive")?;
                Box::new(SelfTuning::adaptive(n_jobs, p))
            }
            "tiered" => {
                if p.checkpoint_interval <= 0.0 || p.checkpoint_tier2_interval <= 0.0 {
                    return Err(
                        "checkpoint policy `tiered` requires `checkpoint_interval` > 0 \
                         (cheap tier) and `checkpoint_tier2_interval` > 0 (expensive \
                         tier)"
                            .into(),
                    );
                }
                if p.checkpoint_tier2_interval < p.checkpoint_interval {
                    return Err(format!(
                        "checkpoint policy `tiered`: `checkpoint_tier2_interval` \
                         ({}) must be >= `checkpoint_interval` ({}) — the expensive \
                         tier is the rare one",
                        p.checkpoint_tier2_interval, p.checkpoint_interval
                    ));
                }
                // Tiered accounting walks one step per commit milestone;
                // an interval microscopically small relative to the job
                // would turn every burst into a near-endless walk (the
                // single-tier policies are closed-form and unaffected).
                if p.job_len / p.checkpoint_interval > 1e6 {
                    return Err(format!(
                        "checkpoint policy `tiered`: `checkpoint_interval` ({}) is \
                         pathologically small for `job_len` ({}) — over 1e6 commit \
                         milestones per job",
                        p.checkpoint_interval, p.job_len
                    ));
                }
                Box::new(Tiered::new(n_jobs, p))
            }
            // The pre-refactor behavior: periodic loss when an interval is
            // configured, lossless continuous checkpointing otherwise.
            "auto" => {
                if p.checkpoint_interval > 0.0 {
                    Box::new(Periodic {
                        interval: p.checkpoint_interval,
                        cost: effective_commit_cost(p),
                        recovery_time: p.recovery_time,
                    })
                } else {
                    Box::new(Continuous { recovery_time: p.recovery_time })
                }
            }
            other => return Err(format!("unknown checkpoint policy `{other}`")),
        };
        let exponential = matches!(p.failure_dist, DistKind::Exponential);
        let outage_rates = p.topology.as_ref().is_some_and(|t| t.has_outages());
        // A plain clock model named against a topology that carries
        // outage rates would silently drop those rates — domain metrics
        // all zero, no signal. Refuse; set the rates to 0 to compare
        // without correlated outages.
        let plain_vs_rates = |name: &str| -> Result<(), String> {
            if outage_rates {
                return Err(format!(
                    "failure model `{name}` would ignore the topology's outage \
                     rates; use `correlated` (or `auto`), or set the rates to 0"
                ));
            }
            Ok(())
        };
        // Thinning needs a finite majorizing envelope: a Weibull with
        // shape < 1 has a hazard diverging at renewal age 0, so no
        // constant can bound it over a window starting there.
        let thinnable = match p.failure_dist {
            DistKind::Weibull { shape } => shape >= 1.0,
            _ => true,
        };
        // The family-appropriate per-gang clock model (`auto` resolution):
        // exponential keeps the exact legacy gang fast path (byte-identical
        // streams), other thinnable families get the aggregate thinned
        // clock, and diverging hazards fall back to per-server timers.
        let auto_inner = |n_jobs: usize| -> Box<dyn FailureModel> {
            if exponential {
                Box::new(GangExponential::new(n_jobs))
            } else if thinnable {
                Box::new(ThinnedClocks::new(n_jobs, p))
            } else {
                Box::new(PerServerClocks)
            }
        };
        let failure: Box<dyn FailureModel> = match self.failure.as_str() {
            "gang" => {
                if !exponential {
                    return Err(format!(
                        "failure model `gang` requires exponential clocks, got {}",
                        p.failure_dist.name()
                    ));
                }
                plain_vs_rates("gang")?;
                Box::new(GangExponential::new(n_jobs))
            }
            "per_server" => {
                plain_vs_rates("per_server")?;
                Box::new(PerServerClocks)
            }
            "thinned" => {
                if !thinnable {
                    return Err(format!(
                        "failure model `thinned` cannot majorize a {} hazard \
                         (it diverges at renewal age 0); use `per_server`, or \
                         `auto` to route by family",
                        p.failure_dist.name()
                    ));
                }
                plain_vs_rates("thinned")?;
                Box::new(ThinnedClocks::new(n_jobs, p))
            }
            "correlated" => {
                if p.topology.is_none() {
                    return Err(
                        "failure model `correlated` requires a `topology:` block \
                         (its outage clocks are per failure domain)"
                            .into(),
                    );
                }
                Box::new(CorrelatedFailures::new(auto_inner(n_jobs)))
            }
            // `auto` resolves by clock family — and wraps correlated
            // domain-outage clocks on top whenever the topology carries
            // outage rates (a topology config gets them without naming a
            // model; no topology keeps the legacy models untouched).
            "auto" => {
                if outage_rates {
                    Box::new(CorrelatedFailures::new(auto_inner(n_jobs)))
                } else {
                    auto_inner(n_jobs)
                }
            }
            other => return Err(format!("unknown failure model `{other}`")),
        };
        Ok(PolicySet { selection, repair, checkpoint, failure })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_builds_paper_policies() {
        let p = Params::small_test(); // exponential, no checkpoint interval
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.selection.name(), "first_fit");
        assert_eq!(set.repair.name(), "fifo");
        assert_eq!(set.checkpoint.name(), "continuous");
        assert_eq!(set.failure.name(), "gang");
    }

    #[test]
    fn auto_resolves_against_params() {
        let mut p = Params::small_test();
        p.checkpoint_interval = 60.0;
        p.failure_dist = DistKind::Weibull { shape: 1.5 };
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.checkpoint.name(), "periodic");
        assert_eq!(set.failure.name(), "thinned");
    }

    #[test]
    fn auto_failure_routes_by_hazard_family() {
        let case = |dist: DistKind| {
            let mut p = Params::small_test();
            p.failure_dist = dist;
            PolicySpec::default().build(&p).unwrap().failure.name()
        };
        // Exponential keeps the exact legacy fast path.
        assert_eq!(case(DistKind::Exponential), "gang");
        // Non-decreasing / unimodal hazards thin.
        assert_eq!(case(DistKind::Weibull { shape: 1.0 }), "thinned");
        assert_eq!(case(DistKind::Weibull { shape: 2.5 }), "thinned");
        assert_eq!(case(DistKind::LogNormal { sigma: 0.8 }), "thinned");
        // A diverging hazard (Weibull shape < 1) cannot be majorized.
        assert_eq!(case(DistKind::Weibull { shape: 0.8 }), "per_server");
    }

    #[test]
    fn explicit_thinned_rejects_diverging_hazard() {
        let mut p = Params::small_test();
        p.failure_dist = DistKind::Weibull { shape: 0.7 };
        let mut spec = PolicySpec::default();
        spec.set("failure", "thinned").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("per_server"), "{err}");
        // The same family with shape >= 1 builds.
        p.failure_dist = DistKind::Weibull { shape: 1.5 };
        assert_eq!(spec.build(&p).unwrap().failure.name(), "thinned");
    }

    #[test]
    fn set_validates_names() {
        let mut spec = PolicySpec::default();
        spec.set("selection", "locality").unwrap();
        spec.set("repair", "job_first").unwrap();
        assert_eq!(spec.selection, "locality");
        assert!(spec.set("selection", "bogus").is_err());
        assert!(spec.set("bogus_axis", "fifo").is_err());
    }

    #[test]
    fn gang_rejects_non_exponential() {
        let mut p = Params::small_test();
        p.failure_dist = DistKind::LogNormal { sigma: 0.5 };
        let mut spec = PolicySpec::default();
        spec.set("failure", "gang").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("exponential"), "{err}");
    }

    /// Params with a minimal one-level topology at the given per-domain
    /// outage rate, plus checkpoint knobs every checkpoint policy can
    /// build against (interval + cost for `periodic`/`young_daly`/
    /// `adaptive`, a second tier for `tiered`).
    fn topo_params(outage_rate: f64) -> Params {
        let mut p = Params::small_test();
        p.checkpoint_interval = 60.0;
        p.checkpoint_cost = 5.0;
        p.checkpoint_tier2_interval = 240.0;
        p.checkpoint_tier2_cost = 20.0;
        p.checkpoint_tier2_restore = 60.0;
        p.selection_history_window = 1440.0;
        p.repair_pool_high_water = 0.25;
        p.topology = Some(crate::config::TopologySpec {
            levels: vec![crate::config::TopologyLevelSpec {
                name: "rack".into(),
                size: 8,
                outage_rate,
            }],
        });
        p
    }

    #[test]
    fn every_registered_name_builds() {
        // Rate 0: plain models are legal alongside the topology (with
        // rates they refuse — see plain_models_refuse_configured_rates).
        let p = topo_params(0.0);
        for &s in SELECTION_NAMES {
            for &r in REPAIR_NAMES {
                for &c in CHECKPOINT_NAMES {
                    for &f in FAILURE_NAMES {
                        let spec = PolicySpec {
                            selection: s.into(),
                            repair: r.into(),
                            checkpoint: c.into(),
                            failure: f.into(),
                        };
                        spec.build(&p).unwrap_or_else(|e| panic!("{s}/{r}/{c}/{f}: {e}"));
                    }
                }
            }
        }
    }

    /// Satellite bugfix: an explicit `checkpoint: periodic` with a zero
    /// interval used to silently degenerate to `continuous`; it is now a
    /// build error naming the knob. `auto` keeps the quiet legacy
    /// resolution.
    #[test]
    fn explicit_periodic_with_zero_interval_is_rejected() {
        let p = Params::small_test(); // checkpoint_interval = 0
        let mut spec = PolicySpec::default();
        spec.set("checkpoint", "periodic").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("checkpoint_interval"), "{err}");
        assert!(err.contains("periodic"), "{err}");
        // `auto` still degrades quietly (the documented legacy behavior).
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.checkpoint.name(), "continuous");
    }

    #[test]
    fn self_optimizing_policies_require_a_commit_cost() {
        // young_daly / adaptive are degenerate with free commits.
        let mut p = Params::small_test();
        p.checkpoint_interval = 60.0; // cost stays 0
        for name in ["young_daly", "adaptive"] {
            let mut spec = PolicySpec::default();
            spec.set("checkpoint", name).unwrap();
            let err = spec.build(&p).unwrap_err();
            assert!(err.contains("checkpoint_cost"), "{name}: {err}");
        }
        p.checkpoint_cost = 10.0;
        for name in ["young_daly", "adaptive"] {
            let mut spec = PolicySpec::default();
            spec.set("checkpoint", name).unwrap();
            assert_eq!(spec.build(&p).unwrap().checkpoint.name(), name);
        }
    }

    #[test]
    fn per_server_cost_satisfies_the_commit_cost_requirement() {
        // √(2·C·MTBF) is non-degenerate as soon as the *effective* cost
        // is positive, whichever knob supplies it.
        let mut p = Params::small_test();
        p.checkpoint_cost = 0.0;
        p.checkpoint_cost_per_server = 0.5;
        let mut spec = PolicySpec::default();
        spec.set("checkpoint", "young_daly").unwrap();
        assert_eq!(spec.build(&p).unwrap().checkpoint.name(), "young_daly");
    }

    #[test]
    fn pool_aware_requires_a_high_water_mark() {
        // At the 0 default the mark is "always flush" and every
        // drain-back repair would defer forever: a build error naming
        // the knob instead.
        let p = Params::small_test();
        let mut spec = PolicySpec::default();
        spec.set("repair", "pool_aware").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("repair_pool_high_water"), "{err}");

        let mut p = Params::small_test();
        p.repair_pool_high_water = 0.5;
        assert_eq!(spec.build(&p).unwrap().repair.name(), "pool_aware");
    }

    #[test]
    fn tiered_requires_ordered_intervals() {
        let mut p = Params::small_test();
        let mut spec = PolicySpec::default();
        spec.set("checkpoint", "tiered").unwrap();
        // No intervals at all.
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("checkpoint_tier2_interval"), "{err}");
        // Expensive tier more frequent than the cheap one.
        p.checkpoint_interval = 120.0;
        p.checkpoint_tier2_interval = 60.0;
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains(">="), "{err}");
        // Properly ordered tiers build.
        p.checkpoint_tier2_interval = 480.0;
        assert_eq!(spec.build(&p).unwrap().checkpoint.name(), "tiered");
        // A cheap interval microscopically small for the job is rejected
        // (its milestone walk would effectively hang every burst).
        p.checkpoint_interval = p.job_len / 2e6;
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("pathologically small"), "{err}");
    }

    #[test]
    fn history_scored_requires_a_window() {
        // With `selection_history_window` at its 0 default no failure
        // history is ever retained, so the scan would silently be LIFO:
        // a build error naming the knob instead.
        let p = Params::small_test();
        let mut spec = PolicySpec::default();
        spec.set("selection", "history_scored").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("selection_history_window"), "{err}");

        let mut p = Params::small_test();
        p.selection_history_window = 1440.0;
        assert_eq!(spec.build(&p).unwrap().selection.name(), "history_scored");
    }

    #[test]
    fn topology_policies_require_a_topology() {
        let p = Params::small_test(); // no topology
        let mut spec = PolicySpec::default();
        spec.set("selection", "anti_affinity").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("topology"), "{err}");

        let mut spec = PolicySpec::default();
        spec.set("failure", "correlated").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("topology"), "{err}");

        // With a topology both build.
        let p = topo_params(0.001);
        let mut spec = PolicySpec::default();
        spec.set("selection", "anti_affinity").unwrap();
        spec.set("failure", "correlated").unwrap();
        let set = spec.build(&p).unwrap();
        assert_eq!(set.selection.name(), "anti_affinity");
        assert_eq!(set.failure.name(), "correlated");
    }

    #[test]
    fn auto_failure_wraps_correlated_only_with_outage_rates() {
        // Outage rates configured: auto = correlated over the family model.
        let p = topo_params(0.001);
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.failure.name(), "correlated");

        // Topology without rates: auto stays the plain family model.
        let set = PolicySpec::default().build(&topo_params(0.0)).unwrap();
        assert_eq!(set.failure.name(), "gang");

        // No topology at all: unchanged legacy resolution.
        let set = PolicySpec::default().build(&Params::small_test()).unwrap();
        assert_eq!(set.failure.name(), "gang");
    }

    #[test]
    fn plain_models_refuse_configured_outage_rates() {
        // Naming `gang`/`per_server` against a rated topology would
        // silently drop the configured outages — hard error instead.
        let p = topo_params(0.001);
        for name in ["gang", "per_server"] {
            let mut spec = PolicySpec::default();
            spec.set("failure", name).unwrap();
            let err = spec.build(&p).unwrap_err();
            assert!(err.contains("outage"), "{name}: {err}");
        }
        // With the rates at 0 both are fine again.
        let quiet = topo_params(0.0);
        for name in ["gang", "per_server"] {
            let mut spec = PolicySpec::default();
            spec.set("failure", name).unwrap();
            spec.build(&quiet).unwrap();
        }
    }
}
