//! Policy plumbing: the [`PolicySet`] of trait objects a simulation runs
//! with, and the name-based [`PolicySpec`] that YAML scenarios, sweeps,
//! and the CLI use to select implementations.
//!
//! ```yaml
//! policies:
//!   selection: locality      # first_fit | random | locality
//!   repair: job_first        # fifo | lifo | job_first
//!   checkpoint: periodic     # auto | continuous | periodic
//!   failure: auto            # auto | gang | per_server
//! ```

use crate::config::{DistKind, Params};
use crate::model::checkpoint::{CheckpointPolicy, Continuous, Periodic};
use crate::model::failure::{FailureModel, GangExponential, PerServerClocks};
use crate::model::repair::{Fifo, JobFirst, Lifo, RepairPolicy};
use crate::model::selection::{FirstFit, Locality, Random, SelectionPolicy};

/// The four policy subsystems of one simulation run.
pub struct PolicySet {
    pub selection: Box<dyn SelectionPolicy>,
    pub repair: Box<dyn RepairPolicy>,
    pub checkpoint: Box<dyn CheckpointPolicy>,
    pub failure: Box<dyn FailureModel>,
}

impl PolicySet {
    /// The paper's default policies for `p` (first-fit selection, FIFO
    /// repair, interval-driven checkpointing, auto failure clocks).
    pub fn defaults(p: &Params) -> PolicySet {
        PolicySpec::default().build(p).expect("default spec always builds")
    }
}

/// Name-based policy selection — `Clone + Sync`, cheap to ship across
/// sweep threads and to parse from YAML/CLI.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicySpec {
    pub selection: String,
    pub repair: String,
    pub checkpoint: String,
    pub failure: String,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec {
            selection: "first_fit".into(),
            repair: "fifo".into(),
            checkpoint: "auto".into(),
            failure: "auto".into(),
        }
    }
}

/// Valid selection-policy names.
pub const SELECTION_NAMES: &[&str] = &["first_fit", "random", "locality"];
/// Valid repair-policy names.
pub const REPAIR_NAMES: &[&str] = &["fifo", "lifo", "job_first"];
/// Valid checkpoint-policy names.
pub const CHECKPOINT_NAMES: &[&str] = &["auto", "continuous", "periodic"];
/// Valid failure-model names.
pub const FAILURE_NAMES: &[&str] = &["auto", "gang", "per_server"];

impl PolicySpec {
    /// Set one axis by name (`selection`, `repair`, `checkpoint`,
    /// `failure`), validating the value against the registry.
    pub fn set(&mut self, axis: &str, value: &str) -> Result<(), String> {
        let (names, slot): (&[&str], &mut String) = match axis {
            "selection" => (SELECTION_NAMES, &mut self.selection),
            "repair" => (REPAIR_NAMES, &mut self.repair),
            "checkpoint" => (CHECKPOINT_NAMES, &mut self.checkpoint),
            "failure" => (FAILURE_NAMES, &mut self.failure),
            other => {
                return Err(format!(
                    "unknown policy axis `{other}` (expected selection, repair, \
                     checkpoint, or failure)"
                ))
            }
        };
        if !names.contains(&value) {
            return Err(format!(
                "unknown {axis} policy `{value}` (expected one of {})",
                names.join(", ")
            ));
        }
        *slot = value.to_string();
        Ok(())
    }

    /// Instantiate the policy set for a concrete parameter set (the
    /// `auto` names resolve against `p`).
    pub fn build(&self, p: &Params) -> Result<PolicySet, String> {
        let n_jobs = p.num_jobs.max(1) as usize;
        let selection: Box<dyn SelectionPolicy> = match self.selection.as_str() {
            "first_fit" => Box::new(FirstFit),
            "random" => Box::new(Random),
            "locality" => Box::new(Locality),
            other => return Err(format!("unknown selection policy `{other}`")),
        };
        let repair: Box<dyn RepairPolicy> = match self.repair.as_str() {
            "fifo" => Box::new(Fifo),
            "lifo" => Box::new(Lifo),
            "job_first" => Box::new(JobFirst),
            other => return Err(format!("unknown repair policy `{other}`")),
        };
        let checkpoint: Box<dyn CheckpointPolicy> = match self.checkpoint.as_str() {
            "continuous" => Box::new(Continuous { recovery_time: p.recovery_time }),
            "periodic" => Box::new(Periodic {
                interval: p.checkpoint_interval,
                recovery_time: p.recovery_time,
            }),
            // The pre-refactor behavior: periodic loss when an interval is
            // configured, lossless continuous checkpointing otherwise.
            "auto" => {
                if p.checkpoint_interval > 0.0 {
                    Box::new(Periodic {
                        interval: p.checkpoint_interval,
                        recovery_time: p.recovery_time,
                    })
                } else {
                    Box::new(Continuous { recovery_time: p.recovery_time })
                }
            }
            other => return Err(format!("unknown checkpoint policy `{other}`")),
        };
        let exponential = matches!(p.failure_dist, DistKind::Exponential);
        let failure: Box<dyn FailureModel> = match self.failure.as_str() {
            "gang" => {
                if !exponential {
                    return Err(format!(
                        "failure model `gang` requires exponential clocks, got {}",
                        p.failure_dist.name()
                    ));
                }
                Box::new(GangExponential::new(n_jobs))
            }
            "per_server" => Box::new(PerServerClocks),
            "auto" => {
                if exponential {
                    Box::new(GangExponential::new(n_jobs))
                } else {
                    Box::new(PerServerClocks)
                }
            }
            other => return Err(format!("unknown failure model `{other}`")),
        };
        Ok(PolicySet { selection, repair, checkpoint, failure })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_builds_paper_policies() {
        let p = Params::small_test(); // exponential, no checkpoint interval
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.selection.name(), "first_fit");
        assert_eq!(set.repair.name(), "fifo");
        assert_eq!(set.checkpoint.name(), "continuous");
        assert_eq!(set.failure.name(), "gang");
    }

    #[test]
    fn auto_resolves_against_params() {
        let mut p = Params::small_test();
        p.checkpoint_interval = 60.0;
        p.failure_dist = DistKind::Weibull { shape: 1.5 };
        let set = PolicySpec::default().build(&p).unwrap();
        assert_eq!(set.checkpoint.name(), "periodic");
        assert_eq!(set.failure.name(), "per_server");
    }

    #[test]
    fn set_validates_names() {
        let mut spec = PolicySpec::default();
        spec.set("selection", "locality").unwrap();
        spec.set("repair", "job_first").unwrap();
        assert_eq!(spec.selection, "locality");
        assert!(spec.set("selection", "bogus").is_err());
        assert!(spec.set("bogus_axis", "fifo").is_err());
    }

    #[test]
    fn gang_rejects_non_exponential() {
        let mut p = Params::small_test();
        p.failure_dist = DistKind::LogNormal { sigma: 0.5 };
        let mut spec = PolicySpec::default();
        spec.set("failure", "gang").unwrap();
        let err = spec.build(&p).unwrap_err();
        assert!(err.contains("exponential"), "{err}");
    }

    #[test]
    fn every_registered_name_builds() {
        let p = Params::small_test();
        for &s in SELECTION_NAMES {
            for &r in REPAIR_NAMES {
                for &c in CHECKPOINT_NAMES {
                    for &f in FAILURE_NAMES {
                        let spec = PolicySpec {
                            selection: s.into(),
                            repair: r.into(),
                            checkpoint: c.into(),
                            failure: f.into(),
                        };
                        spec.build(&p).unwrap_or_else(|e| panic!("{s}/{r}/{c}/{f}: {e}"));
                    }
                }
            }
        }
    }
}
