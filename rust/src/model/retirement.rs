//! Server retirement (§II-B): "maintain a score for each server that keeps
//! track of how often it has failed in a given time period, and remove
//! servers that exhibit a number of failures exceeding a certain threshold
//! (within that time period)".
//!
//! Disabled at Table I defaults (`retirement_threshold == 0`); the
//! ablation bench sweeps it.

use crate::config::Params;
use crate::model::server::Server;
use crate::sim::Time;

/// Record a failure at `now` against `server`'s sliding-window score and
/// decide whether the policy retires it.
pub fn record_and_decide(p: &Params, server: &mut Server, now: Time) -> bool {
    server.total_failures += 1;
    if p.retirement_threshold == 0 {
        return false;
    }
    // Maintain the sliding window.
    let cutoff = now - p.retirement_window;
    server.failure_times.retain(|&t| t > cutoff);
    server.failure_times.push(now);
    server.failure_times.len() >= p.retirement_threshold as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::Home;

    fn server() -> Server {
        Server::new(0, true, Home::Working)
    }

    #[test]
    fn disabled_when_threshold_zero() {
        let p = Params::small_test(); // threshold 0
        let mut s = server();
        for i in 0..100 {
            assert!(!record_and_decide(&p, &mut s, i as f64));
        }
        assert_eq!(s.total_failures, 100);
        // No window bookkeeping when disabled.
        assert!(s.failure_times.is_empty());
    }

    #[test]
    fn retires_at_threshold_within_window() {
        let mut p = Params::small_test();
        p.retirement_threshold = 3;
        p.retirement_window = 100.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 10.0));
        assert!(!record_and_decide(&p, &mut s, 20.0));
        assert!(record_and_decide(&p, &mut s, 30.0));
    }

    #[test]
    fn old_failures_age_out() {
        let mut p = Params::small_test();
        p.retirement_threshold = 3;
        p.retirement_window = 100.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 0.0));
        assert!(!record_and_decide(&p, &mut s, 50.0));
        // t=0 falls out of the (t-100, t] window by t=150.
        assert!(!record_and_decide(&p, &mut s, 150.0));
        // Window now holds {50?, 150}: 50 is out too at 151+100... check:
        // at t=150 window is (50,150] -> {150, 50 excluded}. One more
        // failure soon after should still not trip (2 < 3)...
        assert!(!record_and_decide(&p, &mut s, 160.0));
        // ...but a third inside the window does.
        assert!(record_and_decide(&p, &mut s, 170.0));
    }

    #[test]
    fn threshold_one_retires_immediately() {
        let mut p = Params::small_test();
        p.retirement_threshold = 1;
        let mut s = server();
        assert!(record_and_decide(&p, &mut s, 5.0));
    }
}
