//! Server retirement (§II-B): "maintain a score for each server that keeps
//! track of how often it has failed in a given time period, and remove
//! servers that exhibit a number of failures exceeding a certain threshold
//! (within that time period)".
//!
//! Disabled at Table I defaults (`retirement_threshold == 0`); the
//! ablation bench sweeps it.
//!
//! The per-server `failure_times` log this module maintains is shared
//! with failure-history-aware selection
//! ([`crate::model::selection::HistoryScored`]): when
//! `selection_history_window` is set the log is kept even with
//! retirement disabled, pruned to the larger of the two windows.

use crate::config::Params;
use crate::model::server::Server;
use crate::sim::Time;

/// Record a failure at `now` against `server`'s sliding-window score and
/// decide whether the policy retires it.
pub fn record_and_decide(p: &Params, server: &mut Server, now: Time) -> bool {
    server.total_failures += 1;
    if p.retirement_threshold == 0 && p.selection_history_window <= 0.0 {
        return false;
    }
    // Maintain the sliding window: entries are kept as long as *either*
    // consumer (retirement scoring, history-scored selection) still
    // counts them; the retirement decision below re-filters to its own
    // window, so a longer selection window never changes retirements.
    let retire_w = if p.retirement_threshold > 0 { p.retirement_window } else { 0.0 };
    let keep = retire_w.max(p.selection_history_window);
    server.failure_times.retain(|&t| t > now - keep);
    server.failure_times.push(now);
    if p.retirement_threshold == 0 {
        return false;
    }
    server.failure_times.iter().filter(|&&t| t > now - p.retirement_window).count()
        >= p.retirement_threshold as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::server::Home;

    fn server() -> Server {
        Server::new(0, true, Home::Working)
    }

    #[test]
    fn disabled_when_threshold_zero() {
        let p = Params::small_test(); // threshold 0
        let mut s = server();
        for i in 0..100 {
            assert!(!record_and_decide(&p, &mut s, i as f64));
        }
        assert_eq!(s.total_failures, 100);
        // No window bookkeeping when disabled.
        assert!(s.failure_times.is_empty());
    }

    #[test]
    fn retires_at_threshold_within_window() {
        let mut p = Params::small_test();
        p.retirement_threshold = 3;
        p.retirement_window = 100.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 10.0));
        assert!(!record_and_decide(&p, &mut s, 20.0));
        assert!(record_and_decide(&p, &mut s, 30.0));
    }

    #[test]
    fn old_failures_age_out() {
        let mut p = Params::small_test();
        p.retirement_threshold = 3;
        p.retirement_window = 100.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 0.0));
        assert!(!record_and_decide(&p, &mut s, 50.0));
        // t=0 falls out of the (t-100, t] window by t=150.
        assert!(!record_and_decide(&p, &mut s, 150.0));
        // Window now holds {50?, 150}: 50 is out too at 151+100... check:
        // at t=150 window is (50,150] -> {150, 50 excluded}. One more
        // failure soon after should still not trip (2 < 3)...
        assert!(!record_and_decide(&p, &mut s, 160.0));
        // ...but a third inside the window does.
        assert!(record_and_decide(&p, &mut s, 170.0));
    }

    #[test]
    fn selection_window_keeps_history_without_retiring() {
        // Retirement disabled, but a selection window set: the log is
        // maintained (HistoryScored's score source), old entries age
        // out, and nothing ever retires.
        let mut p = Params::small_test(); // threshold 0
        p.selection_history_window = 100.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 10.0));
        assert!(!record_and_decide(&p, &mut s, 20.0));
        assert_eq!(s.failure_times, vec![10.0, 20.0]);
        // t=10 falls out of the (t-100, t] window by t=130.
        assert!(!record_and_decide(&p, &mut s, 130.0));
        assert_eq!(s.failure_times, vec![20.0, 130.0]);
        assert_eq!(s.total_failures, 3);
    }

    #[test]
    fn longer_selection_window_never_changes_retirements() {
        // Retirement counts only its own window even when the selection
        // window retains older entries in the shared log.
        let mut p = Params::small_test();
        p.retirement_threshold = 3;
        p.retirement_window = 100.0;
        p.selection_history_window = 10_000.0;
        let mut s = server();
        assert!(!record_and_decide(&p, &mut s, 0.0));
        assert!(!record_and_decide(&p, &mut s, 50.0));
        // The t=0 entry is still in the log (selection window) but out
        // of the retirement window at t=150: only {50, 150} count.
        assert!(!record_and_decide(&p, &mut s, 150.0));
        assert_eq!(s.failure_times, vec![0.0, 50.0, 150.0]);
        // Two in-window failures (150, 160) still sit below threshold 3
        // even though the log holds four entries; the third trips it.
        assert!(!record_and_decide(&p, &mut s, 160.0));
        assert!(record_and_decide(&p, &mut s, 170.0));
    }

    #[test]
    fn threshold_one_retires_immediately() {
        let mut p = Params::small_test();
        p.retirement_threshold = 1;
        let mut s = server();
        assert!(record_and_decide(&p, &mut s, 5.0));
    }
}
