//! Paper module 5 — **Pool**: working-pool and spare-pool bookkeeping.
//!
//! The working pool holds powered, job-ready servers (idle ones are
//! immediately allocatable). The spare pool runs other workloads; pulling
//! a server from it requires preempting that work (`waiting_time`) and is
//! counted as a preemption with an optional per-server cost (assumption 7).
//! When pressure subsides, borrowed servers flow back to the spare pool.

use crate::model::events::ServerId;
use crate::model::server::{Home, Server, ServerState};

/// Index structures over the fleet; the authoritative state lives in each
/// [`Server`] and the pool keeps the free-lists consistent with it.
#[derive(Clone, Debug, Default)]
pub struct Pools {
    /// Idle servers in the working pool (allocatable now).
    idle: Vec<ServerId>,
    /// Servers in the spare pool (preemptable).
    spares: Vec<ServerId>,
    /// Servers in flight from spare to working pool.
    pub in_transit: u32,
    /// Net count of servers borrowed from the spare pool.
    pub borrowed: u32,
    /// Stats: total preemptions performed.
    pub preemptions: u64,
    /// Stats: accumulated preemption cost (minutes of other-job work).
    pub preemption_cost_total: f64,
}

impl Pools {
    /// Build from the initial fleet (everyone idle in their home pool).
    pub fn from_fleet(fleet: &[Server]) -> Pools {
        let mut p = Pools::default();
        p.rebuild(fleet);
        p
    }

    /// Re-index an initial fleet in place, reusing the free-list
    /// allocations (the batched replication runner resets pools this way).
    pub fn rebuild(&mut self, fleet: &[Server]) {
        self.idle.clear();
        self.spares.clear();
        for s in fleet {
            match s.state {
                ServerState::WorkingIdle => self.idle.push(s.id),
                ServerState::SparePool => self.spares.push(s.id),
                _ => {}
            }
        }
        self.in_transit = 0;
        self.borrowed = 0;
        self.preemptions = 0;
        self.preemption_cost_total = 0.0;
    }

    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    /// The idle free-list (selection policies scan it; order is LIFO).
    pub fn idle_ids(&self) -> &[ServerId] {
        &self.idle
    }

    pub fn spare_count(&self) -> usize {
        self.spares.len()
    }

    /// Move the idle entry at position `k` to the back of the free-list
    /// (supports the Random selection policy: swap-then-pop is uniform).
    pub fn swap_idle_to_back(&mut self, k: usize) {
        let last = self.idle.len() - 1;
        self.idle.swap(k, last);
    }

    /// Remove a *specific* server from the idle free-list (a domain
    /// outage takes idle servers down in place). Returns false if the
    /// server was not idle. O(n) scan, O(1) removal — outage events are
    /// rare next to allocations.
    pub fn remove_idle(&mut self, id: ServerId) -> bool {
        match self.idle.iter().position(|&x| x == id) {
            Some(i) => {
                self.idle.swap_remove(i);
                true
            }
            None => false,
        }
    }

    /// Take one idle working-pool server (LIFO: cache-warm first).
    pub fn take_idle(&mut self, fleet: &mut [Server]) -> Option<ServerId> {
        let id = self.idle.pop()?;
        debug_assert_eq!(fleet[id as usize].state, ServerState::WorkingIdle);
        Some(id)
    }

    /// Return a server to the working pool's idle list.
    pub fn add_idle(&mut self, fleet: &mut [Server], id: ServerId) {
        fleet[id as usize].state = ServerState::WorkingIdle;
        self.idle.push(id);
    }

    /// Begin preempting one spare-pool server (caller schedules its
    /// `PreemptArrive` after `waiting_time`). Returns None if the spare
    /// pool is exhausted.
    pub fn start_preempt(
        &mut self,
        fleet: &mut [Server],
        cost_per_server: f64,
    ) -> Option<ServerId> {
        let id = self.spares.pop()?;
        let s = &mut fleet[id as usize];
        debug_assert_eq!(s.state, ServerState::SparePool);
        s.state = ServerState::SpareTransit;
        self.in_transit += 1;
        self.borrowed += 1;
        self.preemptions += 1;
        self.preemption_cost_total += cost_per_server;
        Some(id)
    }

    /// A preempted server arrived in the working pool (caller routes it).
    pub fn arrive(&mut self, fleet: &mut [Server], id: ServerId) {
        debug_assert_eq!(fleet[id as usize].state, ServerState::SpareTransit);
        debug_assert!(self.in_transit > 0);
        self.in_transit -= 1;
    }

    /// Send a server (back) to the spare pool.
    pub fn add_spare(&mut self, fleet: &mut [Server], id: ServerId) {
        fleet[id as usize].state = ServerState::SparePool;
        self.spares.push(id);
        self.borrowed = self.borrowed.saturating_sub(1);
    }

    /// Route a server that just became free: borrowed spare-home servers
    /// drain back to the spare pool once the working pool is whole again;
    /// everyone else idles in the working pool.
    pub fn route_freed(&mut self, fleet: &mut [Server], id: ServerId) {
        if fleet[id as usize].home == Home::Spare && self.borrowed > 0 {
            self.add_spare(fleet, id);
        } else {
            self.add_idle(fleet, id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Params;
    use crate::model::server::build_fleet;
    use crate::sim::rng::Rng;

    fn setup() -> (Vec<Server>, Pools) {
        let p = Params::small_test(); // 72 working, 16 spare
        let mut rng = Rng::new(1);
        let fleet = build_fleet(&p, &mut rng);
        let pools = Pools::from_fleet(&fleet);
        (fleet, pools)
    }

    #[test]
    fn initial_counts() {
        let (_, pools) = setup();
        assert_eq!(pools.idle_count(), 72);
        assert_eq!(pools.spare_count(), 16);
        assert_eq!(pools.in_transit, 0);
        assert_eq!(pools.borrowed, 0);
    }

    #[test]
    fn take_and_return_idle() {
        let (mut fleet, mut pools) = setup();
        let id = pools.take_idle(&mut fleet).unwrap();
        assert_eq!(pools.idle_count(), 71);
        pools.add_idle(&mut fleet, id);
        assert_eq!(pools.idle_count(), 72);
        assert_eq!(fleet[id as usize].state, ServerState::WorkingIdle);
    }

    #[test]
    fn preemption_lifecycle() {
        let (mut fleet, mut pools) = setup();
        let id = pools.start_preempt(&mut fleet, 5.0).unwrap();
        assert_eq!(fleet[id as usize].state, ServerState::SpareTransit);
        assert_eq!(pools.in_transit, 1);
        assert_eq!(pools.borrowed, 1);
        assert_eq!(pools.preemptions, 1);
        assert_eq!(pools.preemption_cost_total, 5.0);

        pools.arrive(&mut fleet, id);
        assert_eq!(pools.in_transit, 0);

        // Borrowed spare-home server drains back to the spare pool.
        pools.route_freed(&mut fleet, id);
        assert_eq!(pools.spare_count(), 16);
        assert_eq!(pools.borrowed, 0);
    }

    #[test]
    fn exhausted_spare_pool_returns_none() {
        let (mut fleet, mut pools) = setup();
        for _ in 0..16 {
            assert!(pools.start_preempt(&mut fleet, 0.0).is_some());
        }
        assert!(pools.start_preempt(&mut fleet, 0.0).is_none());
    }

    #[test]
    fn remove_idle_takes_a_specific_server() {
        let (_, mut pools) = setup();
        assert!(pools.remove_idle(30));
        assert_eq!(pools.idle_count(), 71);
        assert!(!pools.idle_ids().contains(&30));
        assert!(!pools.remove_idle(30), "already removed");
        assert!(!pools.remove_idle(999), "never existed");
    }

    #[test]
    fn working_home_server_routes_to_idle() {
        let (mut fleet, mut pools) = setup();
        let id = pools.take_idle(&mut fleet).unwrap();
        fleet[id as usize].state = ServerState::JobActive; // pretend it ran
        pools.route_freed(&mut fleet, id);
        assert_eq!(fleet[id as usize].state, ServerState::WorkingIdle);
        assert_eq!(pools.idle_count(), 72);
    }
}
