//! Paper module 4 — **Repairs**: the serial automated→manual pipeline.
//!
//! Every failed (diagnosed) server first undergoes automated test & repair;
//! with probability `1 - auto_repair_prob` the problem is beyond the
//! automated scope and escalates to manual repair (§II-B). Either stage may
//! *silently* fail on a bad server (`*_repair_fail_prob`): the status says
//! repaired but the systematic defect persists, and the server is
//! reintegrated anyway [Lin et al., DSN-W'18].
//!
//! Repair durations are exponentially distributed with the configured
//! means (assumption 4); repairs are stateless (assumption 5).
//!
//! The `RepairShop` additionally models *finite repair capacity* (an
//! extension knob, 0 = unlimited): at most `auto_repair_capacity`
//! concurrent automated fixtures and `manual_repair_capacity` technicians,
//! with FIFO queues in front of each stage.

use crate::config::Params;
use crate::model::events::{RepairStage, ServerId};
use crate::sim::dist::Dist;
use crate::sim::rng::Rng;
use crate::sim::Time;
use std::collections::VecDeque;

/// What happens when an automated repair completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoResult {
    /// Resolved at the automated stage; if the server was bad,
    /// `fixed` says whether the defect was actually cured.
    Resolved { fixed: bool },
    /// Beyond automated scope: escalate to manual repair.
    Escalate,
}

/// Sample the outcome of a completed automated repair.
pub fn auto_outcome(p: &Params, rng: &mut Rng) -> AutoResult {
    if rng.bernoulli(p.auto_repair_prob) {
        AutoResult::Resolved { fixed: !rng.bernoulli(p.auto_repair_fail_prob) }
    } else {
        AutoResult::Escalate
    }
}

/// Sample whether a completed manual repair actually fixed a bad server.
pub fn manual_fixed(p: &Params, rng: &mut Rng) -> bool {
    !rng.bernoulli(p.manual_repair_fail_prob)
}

/// Sample a repair duration for the given stage (assumption 4).
pub fn duration(p: &Params, stage: RepairStage, rng: &mut Rng) -> Time {
    let mean = match stage {
        RepairStage::Automated => p.auto_repair_time,
        RepairStage::Manual => p.manual_repair_time,
    };
    Dist::exp_mean(mean).sample(rng)
}

/// Admission decision from the shop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Start immediately; caller schedules RepairDone after the duration.
    Start,
    /// Capacity exhausted; the server waits in the stage's FIFO queue.
    Queued,
}

/// Finite-capacity repair shop (capacity 0 = unlimited).
#[derive(Clone, Debug, Default)]
pub struct RepairShop {
    in_auto: u32,
    in_manual: u32,
    queue_auto: VecDeque<ServerId>,
    queue_manual: VecDeque<ServerId>,
    /// Stats: completed repairs per stage.
    pub completed_auto: u64,
    pub completed_manual: u64,
    /// Stats: total queueing delay experienced (minutes · servers).
    pub max_queue_auto: usize,
    pub max_queue_manual: usize,
}

impl RepairShop {
    pub fn new() -> Self {
        Self::default()
    }

    fn cap(p: &Params, stage: RepairStage) -> u32 {
        match stage {
            RepairStage::Automated => p.auto_repair_capacity,
            RepairStage::Manual => p.manual_repair_capacity,
        }
    }

    /// Try to admit `server` into `stage`.
    pub fn admit(&mut self, p: &Params, stage: RepairStage, server: ServerId) -> Admission {
        let cap = Self::cap(p, stage);
        let (busy, queue) = match stage {
            RepairStage::Automated => (&mut self.in_auto, &mut self.queue_auto),
            RepairStage::Manual => (&mut self.in_manual, &mut self.queue_manual),
        };
        if cap == 0 || *busy < cap {
            *busy += 1;
            Admission::Start
        } else {
            queue.push_back(server);
            match stage {
                RepairStage::Automated => {
                    self.max_queue_auto = self.max_queue_auto.max(queue.len())
                }
                RepairStage::Manual => {
                    self.max_queue_manual = self.max_queue_manual.max(queue.len())
                }
            }
            Admission::Queued
        }
    }

    /// A repair of `stage` completed: free the slot and return the next
    /// queued server (if any), which the caller must now start.
    pub fn complete(&mut self, stage: RepairStage) -> Option<ServerId> {
        match stage {
            RepairStage::Automated => {
                debug_assert!(self.in_auto > 0);
                self.in_auto -= 1;
                self.completed_auto += 1;
                let next = self.queue_auto.pop_front();
                if next.is_some() {
                    self.in_auto += 1;
                }
                next
            }
            RepairStage::Manual => {
                debug_assert!(self.in_manual > 0);
                self.in_manual -= 1;
                self.completed_manual += 1;
                let next = self.queue_manual.pop_front();
                if next.is_some() {
                    self.in_manual += 1;
                }
                next
            }
        }
    }

    /// Servers currently inside the shop (busy + queued) — used by the
    /// conservation property tests.
    pub fn population(&self) -> usize {
        (self.in_auto + self.in_manual) as usize
            + self.queue_auto.len()
            + self.queue_manual.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_capacity_always_starts() {
        let p = Params::small_test(); // capacities 0
        let mut shop = RepairShop::new();
        for id in 0..1000 {
            assert_eq!(shop.admit(&p, RepairStage::Automated, id), Admission::Start);
        }
        assert_eq!(shop.population(), 1000);
    }

    #[test]
    fn finite_capacity_queues() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 2;
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 1), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 3), Admission::Queued);
        // Completion hands the slot to the FIFO head.
        assert_eq!(shop.complete(RepairStage::Automated), Some(2));
        assert_eq!(shop.complete(RepairStage::Automated), Some(3));
        assert_eq!(shop.complete(RepairStage::Automated), None);
        assert_eq!(shop.complete(RepairStage::Automated), None);
        assert_eq!(shop.population(), 0);
        assert_eq!(shop.completed_auto, 4);
    }

    #[test]
    fn stages_have_independent_capacity() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 1;
        p.manual_repair_capacity = 1;
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 1), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 3), Admission::Queued);
    }

    #[test]
    fn outcome_rates_match_probabilities() {
        let mut p = Params::small_test();
        p.auto_repair_prob = 0.8;
        p.auto_repair_fail_prob = 0.4;
        p.manual_repair_fail_prob = 0.2;
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mut escalated = 0;
        let mut fixed = 0;
        let mut resolved = 0;
        for _ in 0..n {
            match auto_outcome(&p, &mut rng) {
                AutoResult::Escalate => escalated += 1,
                AutoResult::Resolved { fixed: f } => {
                    resolved += 1;
                    if f {
                        fixed += 1;
                    }
                }
            }
        }
        assert!((escalated as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((fixed as f64 / resolved as f64 - 0.6).abs() < 0.01);
        let man_fixed = (0..n).filter(|_| manual_fixed(&p, &mut rng)).count();
        assert!((man_fixed as f64 / n as f64 - 0.8).abs() < 0.01);
    }

    #[test]
    fn durations_have_configured_means() {
        let p = Params::small_test();
        let mut rng = Rng::new(2);
        let n = 100_000;
        let auto: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Automated, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((auto - p.auto_repair_time).abs() / p.auto_repair_time < 0.02);
        let man: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Manual, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((man - p.manual_repair_time).abs() / p.manual_repair_time < 0.02);
    }
}
