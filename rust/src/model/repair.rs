//! Paper module 4 — **Repairs**: the serial automated→manual pipeline.
//!
//! Every failed (diagnosed) server first undergoes automated test & repair;
//! with probability `1 - auto_repair_prob` the problem is beyond the
//! automated scope and escalates to manual repair (§II-B). Either stage may
//! *silently* fail on a bad server (`*_repair_fail_prob`): the status says
//! repaired but the systematic defect persists, and the server is
//! reintegrated anyway [Lin et al., DSN-W'18].
//!
//! Repair durations are exponentially distributed with the configured
//! means (assumption 4); repairs are stateless (assumption 5).
//!
//! The `RepairShop` additionally models *finite repair capacity* (an
//! extension knob, 0 = unlimited): at most `auto_repair_capacity`
//! concurrent automated fixtures and `manual_repair_capacity` technicians,
//! with FIFO queues in front of each stage.

use crate::config::Params;
use crate::model::events::{RepairStage, ServerId};
use crate::model::job::Job;
use crate::model::server::Server;
use crate::sim::dist::Dist;
use crate::sim::rng::Rng;
use crate::sim::Time;
use std::collections::VecDeque;

/// Queue discipline for a repair stage: which queued server starts when a
/// slot frees up. Selected by name (see [`crate::model::policy`]):
///
/// | name | policy |
/// |---|---|
/// | `fifo`      | [`Fifo`] — arrival order (default) |
/// | `lifo`      | [`Lifo`] — most recent arrival first |
/// | `job_first` | [`JobFirst`] — servers a live job is waiting on jump the queue |
pub trait RepairPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Remove and return the next server to repair from `queue`.
    fn pick_next(
        &self,
        queue: &mut VecDeque<ServerId>,
        fleet: &[Server],
        jobs: &[Job],
        p: &Params,
    ) -> Option<ServerId>;
}

/// First-in-first-out (the paper's implicit discipline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl RepairPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick_next(
        &self,
        queue: &mut VecDeque<ServerId>,
        _fleet: &[Server],
        _jobs: &[Job],
        _p: &Params,
    ) -> Option<ServerId> {
        queue.pop_front()
    }
}

/// Last-in-first-out: freshest failure first (stack discipline — useful
/// as a worst-case fairness baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Lifo;

impl RepairPolicy for Lifo {
    fn name(&self) -> &'static str {
        "lifo"
    }

    fn pick_next(
        &self,
        queue: &mut VecDeque<ServerId>,
        _fleet: &[Server],
        _jobs: &[Job],
        _p: &Params,
    ) -> Option<ServerId> {
        queue.pop_back()
    }
}

/// Would a repaired `server` return directly to a job right now (§II-B
/// reintegration: its assigned job is live and under-allotted)? This is
/// the discriminator [`JobFirst`] prioritizes on — note that *every*
/// server entering the shop still carries `assigned_job`, so the job's
/// phase/allotment ([`Job::wants_more`]) is what distinguishes urgent
/// repairs from ones that would just drain back to the pools.
fn job_is_waiting(server: ServerId, fleet: &[Server], jobs: &[Job], p: &Params) -> bool {
    fleet[server as usize]
        .assigned_job
        .is_some_and(|j| jobs[j as usize].wants_more(p))
}

/// Priority discipline: servers whose job is live and under-allotted
/// (i.e. the repair directly restores lost gang capacity, §II-B) jump
/// ahead of servers that would only drain back to the pools; FIFO within
/// each class.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobFirst;

impl RepairPolicy for JobFirst {
    fn name(&self) -> &'static str {
        "job_first"
    }

    fn pick_next(
        &self,
        queue: &mut VecDeque<ServerId>,
        fleet: &[Server],
        jobs: &[Job],
        p: &Params,
    ) -> Option<ServerId> {
        let idx = queue
            .iter()
            .position(|&id| job_is_waiting(id, fleet, jobs, p))
            .unwrap_or(0);
        queue.remove(idx)
    }
}

/// What happens when an automated repair completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoResult {
    /// Resolved at the automated stage; if the server was bad,
    /// `fixed` says whether the defect was actually cured.
    Resolved { fixed: bool },
    /// Beyond automated scope: escalate to manual repair.
    Escalate,
}

/// Sample the outcome of a completed automated repair.
pub fn auto_outcome(p: &Params, rng: &mut Rng) -> AutoResult {
    if rng.bernoulli(p.auto_repair_prob) {
        AutoResult::Resolved { fixed: !rng.bernoulli(p.auto_repair_fail_prob) }
    } else {
        AutoResult::Escalate
    }
}

/// Sample whether a completed manual repair actually fixed a bad server.
pub fn manual_fixed(p: &Params, rng: &mut Rng) -> bool {
    !rng.bernoulli(p.manual_repair_fail_prob)
}

/// Sample a repair duration for the given stage (assumption 4).
pub fn duration(p: &Params, stage: RepairStage, rng: &mut Rng) -> Time {
    let mean = match stage {
        RepairStage::Automated => p.auto_repair_time,
        RepairStage::Manual => p.manual_repair_time,
    };
    Dist::exp_mean(mean).sample(rng)
}

/// Admission decision from the shop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Start immediately; caller schedules RepairDone after the duration.
    Start,
    /// Capacity exhausted; the server waits in the stage's FIFO queue.
    Queued,
}

/// Finite-capacity repair shop (capacity 0 = unlimited).
#[derive(Clone, Debug, Default)]
pub struct RepairShop {
    in_auto: u32,
    in_manual: u32,
    queue_auto: VecDeque<ServerId>,
    queue_manual: VecDeque<ServerId>,
    /// Stats: completed repairs per stage.
    pub completed_auto: u64,
    pub completed_manual: u64,
    /// Stats: total queueing delay experienced (minutes · servers).
    pub max_queue_auto: usize,
    pub max_queue_manual: usize,
}

impl RepairShop {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear all state for a new run, retaining queue allocations (the
    /// batched replication runner reuses the shop).
    pub fn reset(&mut self) {
        self.in_auto = 0;
        self.in_manual = 0;
        self.queue_auto.clear();
        self.queue_manual.clear();
        self.completed_auto = 0;
        self.completed_manual = 0;
        self.max_queue_auto = 0;
        self.max_queue_manual = 0;
    }

    fn cap(p: &Params, stage: RepairStage) -> u32 {
        match stage {
            RepairStage::Automated => p.auto_repair_capacity,
            RepairStage::Manual => p.manual_repair_capacity,
        }
    }

    /// Try to admit `server` into `stage`.
    pub fn admit(&mut self, p: &Params, stage: RepairStage, server: ServerId) -> Admission {
        let cap = Self::cap(p, stage);
        let (busy, queue) = match stage {
            RepairStage::Automated => (&mut self.in_auto, &mut self.queue_auto),
            RepairStage::Manual => (&mut self.in_manual, &mut self.queue_manual),
        };
        if cap == 0 || *busy < cap {
            *busy += 1;
            Admission::Start
        } else {
            queue.push_back(server);
            match stage {
                RepairStage::Automated => {
                    self.max_queue_auto = self.max_queue_auto.max(queue.len())
                }
                RepairStage::Manual => {
                    self.max_queue_manual = self.max_queue_manual.max(queue.len())
                }
            }
            Admission::Queued
        }
    }

    /// A repair of `stage` completed: free the slot and return the next
    /// queued server per the queue discipline (if any), which the caller
    /// must now start.
    pub fn complete(
        &mut self,
        p: &Params,
        stage: RepairStage,
        policy: &dyn RepairPolicy,
        fleet: &[Server],
        jobs: &[Job],
    ) -> Option<ServerId> {
        let (busy, queue, completed) = match stage {
            RepairStage::Automated => {
                (&mut self.in_auto, &mut self.queue_auto, &mut self.completed_auto)
            }
            RepairStage::Manual => {
                (&mut self.in_manual, &mut self.queue_manual, &mut self.completed_manual)
            }
        };
        debug_assert!(*busy > 0);
        *busy -= 1;
        *completed += 1;
        let next = policy.pick_next(queue, fleet, jobs, p);
        if next.is_some() {
            *busy += 1;
        }
        next
    }

    /// Servers currently inside the shop (busy + queued) — used by the
    /// conservation property tests.
    pub fn population(&self) -> usize {
        (self.in_auto + self.in_manual) as usize
            + self.queue_auto.len()
            + self.queue_manual.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::job::JobPhase;
    use crate::model::server::Home;

    fn test_fleet(n: u32) -> Vec<Server> {
        (0..n).map(|i| Server::new(i, false, Home::Working)).collect()
    }

    /// One pending job that still wants servers (job 0, empty allotment).
    fn waiting_job(p: &Params) -> Vec<Job> {
        vec![Job::new(p.job_len)]
    }

    #[test]
    fn unlimited_capacity_always_starts() {
        let p = Params::small_test(); // capacities 0
        let mut shop = RepairShop::new();
        for id in 0..1000 {
            assert_eq!(shop.admit(&p, RepairStage::Automated, id), Admission::Start);
        }
        assert_eq!(shop.population(), 1000);
    }

    #[test]
    fn finite_capacity_queues() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 2;
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 1), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 3), Admission::Queued);
        // Completion hands the slot to the FIFO head.
        let next = |shop: &mut RepairShop| {
            shop.complete(&p, RepairStage::Automated, &Fifo, &fleet, &jobs)
        };
        assert_eq!(next(&mut shop), Some(2));
        assert_eq!(next(&mut shop), Some(3));
        assert_eq!(next(&mut shop), None);
        assert_eq!(next(&mut shop), None);
        assert_eq!(shop.population(), 0);
        assert_eq!(shop.completed_auto, 4);
    }

    #[test]
    fn stages_have_independent_capacity() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 1;
        p.manual_repair_capacity = 1;
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 1), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 3), Admission::Queued);
    }

    #[test]
    fn lifo_pops_freshest_arrival() {
        let p = Params::small_test();
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut q: VecDeque<ServerId> = [0, 1, 2].into_iter().collect();
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p), Some(2));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p), Some(1));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p), Some(0));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p), None);
    }

    #[test]
    fn job_first_jumps_servers_a_live_job_waits_on() {
        // All four servers carry `assigned_job` (every server in a real
        // shop does); what discriminates is the *job's* state. Job 0 is
        // done, job 1 is under-allotted and waiting.
        let p = Params::small_test();
        let mut fleet = test_fleet(4);
        let mut done = Job::with_id(0, p.job_len);
        done.phase = JobPhase::Done;
        let waiting = Job::with_id(1, p.job_len);
        let jobs = vec![done, waiting];
        for s in fleet.iter_mut() {
            s.assigned_job = Some(0); // their job finished without them
        }
        fleet[2].assigned_job = Some(1); // job 1 wants this one back
        let mut q: VecDeque<ServerId> = [0, 1, 2, 3].into_iter().collect();
        // Server 2 jumps ahead of 0 and 1.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), Some(2));
        // Nobody else is awaited: FIFO order resumes.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), Some(0));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), Some(1));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), Some(3));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), None);
    }

    #[test]
    fn job_first_ignores_fully_allotted_jobs() {
        // A running, fully-allotted job is not waiting on its repaired
        // server (reintegration would route it back to the pools), so
        // job_first must not reorder for it.
        let mut p = Params::small_test();
        p.job_size = 2;
        p.warm_standbys = 0;
        let mut fleet = test_fleet(4);
        let mut job = Job::with_id(0, p.job_len);
        job.phase = JobPhase::Running;
        job.active = vec![0, 1]; // allotted == target
        let jobs = vec![job];
        for s in fleet.iter_mut() {
            s.assigned_job = Some(0);
        }
        let mut q: VecDeque<ServerId> = [2, 3].into_iter().collect();
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p), Some(2), "plain FIFO");
    }

    #[test]
    fn reset_clears_everything() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 1;
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut shop = RepairShop::new();
        shop.admit(&p, RepairStage::Automated, 0);
        shop.admit(&p, RepairStage::Automated, 1);
        let _ = shop.complete(&p, RepairStage::Automated, &Fifo, &fleet, &jobs);
        assert!(shop.population() > 0 || shop.completed_auto > 0);
        shop.reset();
        assert_eq!(shop.population(), 0);
        assert_eq!(shop.completed_auto, 0);
        assert_eq!(shop.max_queue_auto, 0);
    }

    #[test]
    fn outcome_rates_match_probabilities() {
        let mut p = Params::small_test();
        p.auto_repair_prob = 0.8;
        p.auto_repair_fail_prob = 0.4;
        p.manual_repair_fail_prob = 0.2;
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mut escalated = 0;
        let mut fixed = 0;
        let mut resolved = 0;
        for _ in 0..n {
            match auto_outcome(&p, &mut rng) {
                AutoResult::Escalate => escalated += 1,
                AutoResult::Resolved { fixed: f } => {
                    resolved += 1;
                    if f {
                        fixed += 1;
                    }
                }
            }
        }
        assert!((escalated as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((fixed as f64 / resolved as f64 - 0.6).abs() < 0.01);
        let man_fixed = (0..n).filter(|_| manual_fixed(&p, &mut rng)).count();
        assert!((man_fixed as f64 / n as f64 - 0.8).abs() < 0.01);
    }

    #[test]
    fn durations_have_configured_means() {
        let p = Params::small_test();
        let mut rng = Rng::new(2);
        let n = 100_000;
        let auto: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Automated, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((auto - p.auto_repair_time).abs() / p.auto_repair_time < 0.02);
        let man: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Manual, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((man - p.manual_repair_time).abs() / p.manual_repair_time < 0.02);
    }
}
