//! Paper module 4 — **Repairs**: the serial automated→manual pipeline.
//!
//! Every failed (diagnosed) server first undergoes automated test & repair;
//! with probability `1 - auto_repair_prob` the problem is beyond the
//! automated scope and escalates to manual repair (§II-B). Either stage may
//! *silently* fail on a bad server (`*_repair_fail_prob`): the status says
//! repaired but the systematic defect persists, and the server is
//! reintegrated anyway [Lin et al., DSN-W'18].
//!
//! Repair durations are exponentially distributed with the configured
//! means (assumption 4); repairs are stateless (assumption 5).
//!
//! The `RepairShop` additionally models *finite repair capacity* (an
//! extension knob, 0 = unlimited): at most `auto_repair_capacity`
//! concurrent automated fixtures and `manual_repair_capacity` technicians,
//! with a [`RepairQueue`] in front of each stage. The queue keeps a
//! per-job index alongside arrival order, so the `job_first` discipline
//! finds "the earliest-queued server a live job is waiting on" in
//! O(num_jobs) instead of the old O(n) scan + `VecDeque::remove` shift.

use crate::config::Params;
use crate::model::events::{RepairStage, ServerId};
use crate::model::job::Job;
use crate::model::server::{Server, ServerState};
use crate::sim::dist::Dist;
use crate::sim::rng::Rng;
use crate::sim::Time;
use std::collections::{BTreeSet, VecDeque};

/// Order-preserving repair queue with a per-job index.
///
/// Every assigned entry lives in two places: the global arrival deque
/// (FIFO/LIFO pops) and its job's bucket (the `job_first` index), tied
/// together by a unique arrival sequence number. FIFO/LIFO pops remove
/// the bucket twin eagerly (it is always at that bucket's front/back —
/// buckets hold live entries only), so those disciplines allocate
/// nothing extra; a `job_first` bucket pick tombstones its global twin,
/// which later global pops reclaim lazily. Memory is O(live entries +
/// unreclaimed tombstones), never O(all admissions of the run).
///
/// Entries carry their enqueue time, so age-aware disciplines
/// ([`SlaAged`]) can compare the head's wait against an SLA without any
/// extra bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct RepairQueue {
    /// Global arrival order: `(seq, server, assigned job, enqueued at)`.
    fifo: VecDeque<(u64, ServerId, Option<u32>, Time)>,
    /// Live entries per assigned job (index = job id), in arrival order.
    /// Servers with no assigned job live only in `fifo`.
    by_job: Vec<VecDeque<(u64, ServerId)>>,
    /// Seqs picked via a job bucket whose `fifo` copy is not yet
    /// reclaimed (lazy deletion). Only ever probed by key (never
    /// iterated), but kept a `BTreeSet` so sim-core stays free of
    /// hash-ordered containers by construction.
    dead: BTreeSet<u64>,
    next_seq: u64,
    len: usize,
}

impl RepairQueue {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Clear all entries, retaining allocations (replication reuse).
    pub fn clear(&mut self) {
        self.fifo.clear();
        for q in &mut self.by_job {
            q.clear();
        }
        self.dead.clear();
        self.next_seq = 0;
        self.len = 0;
    }

    /// Enqueue `server` at time `at`, indexed under its assigned `job`
    /// (if any). The assignment must not change while the server is
    /// queued — true in the simulation, where a shop-bound server belongs
    /// to no pool or gang list.
    pub fn push(&mut self, server: ServerId, job: Option<u32>, at: Time) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.fifo.push_back((seq, server, job, at));
        if let Some(j) = job {
            let j = j as usize;
            if j >= self.by_job.len() {
                self.by_job.resize_with(j + 1, VecDeque::new);
            }
            self.by_job[j].push_back((seq, server));
        }
        self.len += 1;
    }

    /// Oldest entry (FIFO discipline).
    pub fn pop_front(&mut self) -> Option<ServerId> {
        while let Some((seq, server, job, _)) = self.fifo.pop_front() {
            if self.dead.remove(&seq) {
                continue; // already taken via the job index
            }
            if let Some(j) = job {
                // The oldest live entry overall is the oldest live entry
                // of its job: the twin sits at that bucket's front.
                let q = &mut self.by_job[j as usize];
                debug_assert_eq!(q.front().map(|&(s, _)| s), Some(seq));
                q.pop_front();
            }
            self.len -= 1;
            return Some(server);
        }
        None
    }

    /// Newest entry (LIFO discipline).
    pub fn pop_back(&mut self) -> Option<ServerId> {
        while let Some((seq, server, job, _)) = self.fifo.pop_back() {
            if self.dead.remove(&seq) {
                continue;
            }
            if let Some(j) = job {
                // Symmetric to pop_front: the newest live entry overall
                // is the newest live entry of its job.
                let q = &mut self.by_job[j as usize];
                debug_assert_eq!(q.back().map(|&(s, _)| s), Some(seq));
                q.pop_back();
            }
            self.len -= 1;
            return Some(server);
        }
        None
    }

    /// Enqueue time of the oldest live entry (the head the FIFO
    /// discipline would pop). Reclaims any tombstones sitting at the
    /// front so the answer is about a live entry.
    pub fn front_enqueued_at(&mut self) -> Option<Time> {
        while self
            .fifo
            .front()
            .is_some_and(|(s, _, _, _)| self.dead.contains(s))
        {
            let (s, ..) = self.fifo.pop_front().expect("front checked");
            self.dead.remove(&s);
        }
        self.fifo.front().map(|&(_, _, _, at)| at)
    }

    /// The earliest-queued server whose assigned job satisfies `waiting`
    /// (evaluated now — job state is time-varying); falls back to the
    /// overall front when no job is waiting. This is `job_first` in
    /// O(jobs) comparisons: buckets hold live entries in arrival order,
    /// so comparing bucket heads finds the global earliest.
    pub fn pop_first_waiting(&mut self, waiting: impl Fn(usize) -> bool) -> Option<ServerId> {
        self.pop_first_waiting_only(waiting).or_else(|| self.pop_front())
    }

    /// Like [`RepairQueue::pop_first_waiting`] but with *no* FIFO
    /// fallback: `None` when no queued server's job is waiting, even if
    /// the queue holds pool-bound entries. [`PoolAware`] uses this to
    /// defer drain-back repairs while the spare pool is flush.
    pub fn pop_first_waiting_only(
        &mut self,
        waiting: impl Fn(usize) -> bool,
    ) -> Option<ServerId> {
        let mut best: Option<(u64, usize)> = None;
        for (j, q) in self.by_job.iter().enumerate() {
            let Some(&(seq, _)) = q.front() else { continue };
            if !waiting(j) {
                continue;
            }
            if best.is_none_or(|(b, _)| seq < b) {
                best = Some((seq, j));
            }
        }
        let (_, j) = best?;
        let (seq, server) = self.by_job[j].pop_front().expect("head checked");
        self.dead.insert(seq); // the fifo copy becomes a tombstone
        // Reclaim any tombstones this pick exposed at the front.
        while self
            .fifo
            .front()
            .is_some_and(|(s, _, _, _)| self.dead.contains(s))
        {
            let (s, ..) = self.fifo.pop_front().expect("front checked");
            self.dead.remove(&s);
        }
        self.len -= 1;
        Some(server)
    }

    /// Remove and return the live entry minimizing `key(server)`, ties
    /// broken by arrival order (the [`ShortestFirst`] discipline). An
    /// O(live + tombstones) scan: entries already taken via a job bucket
    /// are skipped (and left for the lazy front reclamation); the winner
    /// is removed from *both* its homes, so no tombstone is created.
    pub fn pop_min_by(&mut self, mut key: impl FnMut(ServerId) -> f64) -> Option<ServerId> {
        let mut best: Option<(f64, u64, usize)> = None;
        for (i, &(seq, server, _, _)) in self.fifo.iter().enumerate() {
            if self.dead.contains(&seq) {
                continue;
            }
            let k = key(server);
            let better = match best {
                None => true,
                Some((bk, bseq, _)) => k < bk || (k == bk && seq < bseq),
            };
            if better {
                best = Some((k, seq, i));
            }
        }
        let (_, seq, i) = best?;
        let (_, server, job, _) = self.fifo.remove(i).expect("index from the scan above");
        if let Some(j) = job {
            let q = &mut self.by_job[j as usize];
            let pos = q
                .iter()
                .position(|&(s, _)| s == seq)
                .expect("live entry has a bucket twin");
            q.remove(pos);
        }
        self.len -= 1;
        Some(server)
    }
}

/// Queue discipline for a repair stage: which queued server starts when a
/// slot frees up. Selected by name (see [`crate::model::policy`]):
///
/// | name | policy |
/// |---|---|
/// | `fifo`      | [`Fifo`] — arrival order (default) |
/// | `lifo`      | [`Lifo`] — most recent arrival first |
/// | `job_first` | [`JobFirst`] — servers a live job is waiting on jump the queue |
/// | `sla_aged`  | [`SlaAged`] — freshest first, until the head breaches `repair_sla_minutes` |
/// | `shortest_first` | [`ShortestFirst`] — shortest pre-drawn repair duration first (SPT) |
/// | `pool_aware` | [`PoolAware`] — defer drain-back repairs while the spare pool is above `repair_pool_high_water` |
pub trait RepairPolicy {
    /// Stable policy name (the YAML/CLI selector).
    fn name(&self) -> &'static str;

    /// Remove and return the next server to repair from `queue`; `now`
    /// is the pick time (age-aware disciplines compare queue waits
    /// against it).
    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        fleet: &[Server],
        jobs: &[Job],
        p: &Params,
        now: Time,
    ) -> Option<ServerId>;
}

/// First-in-first-out (the paper's implicit discipline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl RepairPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        _fleet: &[Server],
        _jobs: &[Job],
        _p: &Params,
        _now: Time,
    ) -> Option<ServerId> {
        queue.pop_front()
    }
}

/// Last-in-first-out: freshest failure first (stack discipline — useful
/// as a worst-case fairness baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Lifo;

impl RepairPolicy for Lifo {
    fn name(&self) -> &'static str {
        "lifo"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        _fleet: &[Server],
        _jobs: &[Job],
        _p: &Params,
        _now: Time,
    ) -> Option<ServerId> {
        queue.pop_back()
    }
}

/// Priority discipline: servers whose job is live and under-allotted
/// (i.e. the repair directly restores lost gang capacity, §II-B) jump
/// ahead of servers that would only drain back to the pools; FIFO within
/// each class. Note that *every* server entering the shop still carries
/// `assigned_job`, so the job's phase/allotment ([`Job::wants_more`]) is
/// what distinguishes urgent repairs from ones that would just drain
/// back — evaluated at pick time via the queue's per-job index.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobFirst;

impl RepairPolicy for JobFirst {
    fn name(&self) -> &'static str {
        "job_first"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        _fleet: &[Server],
        jobs: &[Job],
        p: &Params,
        _now: Time,
    ) -> Option<ServerId> {
        queue.pop_first_waiting(|j| jobs[j].wants_more(p))
    }
}

/// SLA-aged priority: serve the freshest arrival (LIFO keeps the mean
/// wait low under overload) *unless* the oldest queued server has waited
/// `repair_sla_minutes` or longer — then the breacher escalates to the
/// head of service. Because arrivals are time-ordered, the oldest entry
/// is the only one that can breach first, so the check is O(1): compare
/// the queue head's age, pop front on breach, pop back otherwise.
#[derive(Clone, Copy, Debug, Default)]
pub struct SlaAged;

impl RepairPolicy for SlaAged {
    fn name(&self) -> &'static str {
        "sla_aged"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        _fleet: &[Server],
        _jobs: &[Job],
        p: &Params,
        now: Time,
    ) -> Option<ServerId> {
        match queue.front_enqueued_at() {
            Some(at) if now - at >= p.repair_sla_minutes => queue.pop_front(),
            Some(_) => queue.pop_back(),
            None => None,
        }
    }
}

/// Shortest-processing-time-first: serve the queued server whose repair
/// will finish soonest — classic SPT, which minimizes mean queue wait.
/// The ranking key is each server's *pre-drawn* repair duration
/// ([`Server::predrawn_repair`]): when this policy is active, the repair
/// flow draws the stage duration at queue entry and stashes it, and
/// `start_stage` consumes the stash instead of drawing fresh — so the
/// shop "knows" each pending repair's length the way a triage bench
/// estimates work before queueing it. Servers without a pre-drawn
/// duration rank last (infinity); ties fall back to arrival order.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShortestFirst;

impl RepairPolicy for ShortestFirst {
    fn name(&self) -> &'static str {
        "shortest_first"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        fleet: &[Server],
        _jobs: &[Job],
        _p: &Params,
        _now: Time,
    ) -> Option<ServerId> {
        queue.pop_min_by(|s| fleet[s as usize].predrawn_repair.unwrap_or(f64::INFINITY))
    }
}

/// Pool-aware repair throttle: while the spare pool is flush — holding
/// at least `repair_pool_high_water × spare_pool` idle servers — a
/// repair slot is spent only on servers a live job is waiting on (the
/// `job_first` scan with *no* FIFO fallback); repairs that would merely
/// drain back to the already-full pools stay queued. Once the pool dips
/// below the mark, plain FIFO resumes. Deferred servers are never
/// stranded by the policy itself: they are reconsidered at every later
/// completion, and dispatch as soon as the pool drains below the mark
/// or their job starts wanting capacity. (Capacity 0 — the default
/// unlimited shop — never consults any discipline, so this knob only
/// acts alongside `auto_repair_capacity`/`manual_repair_capacity`.)
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolAware;

impl RepairPolicy for PoolAware {
    fn name(&self) -> &'static str {
        "pool_aware"
    }

    fn pick_next(
        &self,
        queue: &mut RepairQueue,
        fleet: &[Server],
        jobs: &[Job],
        p: &Params,
        _now: Time,
    ) -> Option<ServerId> {
        let spares = fleet
            .iter()
            .filter(|s| s.state == ServerState::SparePool)
            .count();
        if spares as f64 >= p.repair_pool_high_water * p.spare_pool as f64 {
            queue.pop_first_waiting_only(|j| jobs[j].wants_more(p))
        } else {
            queue.pop_front()
        }
    }
}

/// What happens when an automated repair completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoResult {
    /// Resolved at the automated stage; if the server was bad,
    /// `fixed` says whether the defect was actually cured.
    Resolved { fixed: bool },
    /// Beyond automated scope: escalate to manual repair.
    Escalate,
}

/// Sample the outcome of a completed automated repair.
pub fn auto_outcome(p: &Params, rng: &mut Rng) -> AutoResult {
    if rng.bernoulli(p.auto_repair_prob) {
        AutoResult::Resolved { fixed: !rng.bernoulli(p.auto_repair_fail_prob) }
    } else {
        AutoResult::Escalate
    }
}

/// Sample whether a completed manual repair actually fixed a bad server.
pub fn manual_fixed(p: &Params, rng: &mut Rng) -> bool {
    !rng.bernoulli(p.manual_repair_fail_prob)
}

/// Sample a repair duration for the given stage (assumption 4).
pub fn duration(p: &Params, stage: RepairStage, rng: &mut Rng) -> Time {
    let mean = match stage {
        RepairStage::Automated => p.auto_repair_time,
        RepairStage::Manual => p.manual_repair_time,
    };
    Dist::exp_mean(mean).sample(rng)
}

/// Admission decision from the shop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Start immediately; caller schedules RepairDone after the duration.
    Start,
    /// Capacity exhausted; the server waits in the stage's queue.
    Queued,
}

/// Finite-capacity repair shop (capacity 0 = unlimited).
#[derive(Clone, Debug, Default)]
pub struct RepairShop {
    in_auto: u32,
    in_manual: u32,
    queue_auto: RepairQueue,
    queue_manual: RepairQueue,
    /// Stats: completed repairs per stage.
    pub completed_auto: u64,
    pub completed_manual: u64,
    /// Stats: peak queue lengths per stage.
    pub max_queue_auto: usize,
    pub max_queue_manual: usize,
}

impl RepairShop {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear all state for a new run, retaining queue allocations (the
    /// batched replication runner reuses the shop).
    pub fn reset(&mut self) {
        self.in_auto = 0;
        self.in_manual = 0;
        self.queue_auto.clear();
        self.queue_manual.clear();
        self.completed_auto = 0;
        self.completed_manual = 0;
        self.max_queue_auto = 0;
        self.max_queue_manual = 0;
    }

    fn cap(p: &Params, stage: RepairStage) -> u32 {
        match stage {
            RepairStage::Automated => p.auto_repair_capacity,
            RepairStage::Manual => p.manual_repair_capacity,
        }
    }

    /// Try to admit `server` into `stage` at time `now`; `job` is the
    /// server's assigned job (the queue's index key for `job_first`).
    pub fn admit(
        &mut self,
        p: &Params,
        stage: RepairStage,
        server: ServerId,
        job: Option<u32>,
        now: Time,
    ) -> Admission {
        let cap = Self::cap(p, stage);
        let (busy, queue) = match stage {
            RepairStage::Automated => (&mut self.in_auto, &mut self.queue_auto),
            RepairStage::Manual => (&mut self.in_manual, &mut self.queue_manual),
        };
        if cap == 0 || *busy < cap {
            *busy += 1;
            Admission::Start
        } else {
            queue.push(server, job, now);
            match stage {
                RepairStage::Automated => {
                    self.max_queue_auto = self.max_queue_auto.max(queue.len())
                }
                RepairStage::Manual => {
                    self.max_queue_manual = self.max_queue_manual.max(queue.len())
                }
            }
            Admission::Queued
        }
    }

    /// A repair of `stage` completed at time `now`: free the slot and
    /// return the next queued server per the queue discipline (if any),
    /// which the caller must now start.
    pub fn complete(
        &mut self,
        p: &Params,
        stage: RepairStage,
        policy: &dyn RepairPolicy,
        fleet: &[Server],
        jobs: &[Job],
        now: Time,
    ) -> Option<ServerId> {
        let (busy, queue, completed) = match stage {
            RepairStage::Automated => {
                (&mut self.in_auto, &mut self.queue_auto, &mut self.completed_auto)
            }
            RepairStage::Manual => {
                (&mut self.in_manual, &mut self.queue_manual, &mut self.completed_manual)
            }
        };
        debug_assert!(*busy > 0);
        *busy -= 1;
        *completed += 1;
        let next = policy.pick_next(queue, fleet, jobs, p, now);
        if next.is_some() {
            *busy += 1;
        }
        next
    }

    /// Servers currently inside the shop (busy + queued) — used by the
    /// conservation property tests.
    pub fn population(&self) -> usize {
        (self.in_auto + self.in_manual) as usize
            + self.queue_auto.len()
            + self.queue_manual.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::job::JobPhase;
    use crate::model::server::Home;

    fn test_fleet(n: u32) -> Vec<Server> {
        (0..n).map(|i| Server::new(i, false, Home::Working)).collect()
    }

    /// One pending job that still wants servers (job 0, empty allotment).
    fn waiting_job(p: &Params) -> Vec<Job> {
        vec![Job::new(p.job_len)]
    }

    /// Build a queue from (server, job) pairs in arrival order (all
    /// enqueued at t = 0).
    fn queue_of(entries: &[(ServerId, Option<u32>)]) -> RepairQueue {
        let mut q = RepairQueue::default();
        for &(s, j) in entries {
            q.push(s, j, 0.0);
        }
        q
    }

    #[test]
    fn unlimited_capacity_always_starts() {
        let p = Params::small_test(); // capacities 0
        let mut shop = RepairShop::new();
        for id in 0..1000 {
            assert_eq!(
                shop.admit(&p, RepairStage::Automated, id, Some(0), 0.0),
                Admission::Start
            );
        }
        assert_eq!(shop.population(), 1000);
    }

    #[test]
    fn finite_capacity_queues() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 2;
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0, Some(0), 0.0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 1, Some(0), 0.0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2, Some(0), 0.0), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 3, Some(0), 0.0), Admission::Queued);
        // Completion hands the slot to the FIFO head.
        let next = |shop: &mut RepairShop| {
            shop.complete(&p, RepairStage::Automated, &Fifo, &fleet, &jobs, 0.0)
        };
        assert_eq!(next(&mut shop), Some(2));
        assert_eq!(next(&mut shop), Some(3));
        assert_eq!(next(&mut shop), None);
        assert_eq!(next(&mut shop), None);
        assert_eq!(shop.population(), 0);
        assert_eq!(shop.completed_auto, 4);
    }

    #[test]
    fn stages_have_independent_capacity() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 1;
        p.manual_repair_capacity = 1;
        let mut shop = RepairShop::new();
        assert_eq!(shop.admit(&p, RepairStage::Automated, 0, None, 0.0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 1, None, 0.0), Admission::Start);
        assert_eq!(shop.admit(&p, RepairStage::Automated, 2, None, 0.0), Admission::Queued);
        assert_eq!(shop.admit(&p, RepairStage::Manual, 3, None, 0.0), Admission::Queued);
    }

    #[test]
    fn lifo_pops_freshest_arrival() {
        let p = Params::small_test();
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut q = queue_of(&[(0, Some(0)), (1, Some(0)), (2, Some(0))]);
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        assert_eq!(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
    }

    #[test]
    fn job_first_jumps_servers_a_live_job_waits_on() {
        // All four servers carry `assigned_job` (every server in a real
        // shop does); what discriminates is the *job's* state. Job 0 is
        // done, job 1 is under-allotted and waiting.
        let p = Params::small_test();
        let fleet = test_fleet(4);
        let mut done = Job::with_id(0, p.job_len);
        done.phase = JobPhase::Done;
        let waiting = Job::with_id(1, p.job_len);
        let jobs = vec![done, waiting];
        // Arrival order 0, 1, 2, 3; only server 2 belongs to job 1.
        let mut q =
            queue_of(&[(0, Some(0)), (1, Some(0)), (2, Some(1)), (3, Some(0))]);
        // Server 2 jumps ahead of 0 and 1.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2));
        // Nobody else is awaited: FIFO order resumes.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(3));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
    }

    #[test]
    fn job_first_ignores_fully_allotted_jobs() {
        // A running, fully-allotted job is not waiting on its repaired
        // server (reintegration would route it back to the pools), so
        // job_first must not reorder for it.
        let mut p = Params::small_test();
        p.job_size = 2;
        p.warm_standbys = 0;
        let fleet = test_fleet(4);
        let mut job = Job::with_id(0, p.job_len);
        job.phase = JobPhase::Running;
        job.active = vec![0, 1]; // allotted == target
        let jobs = vec![job];
        let mut q = queue_of(&[(2, Some(0)), (3, Some(0))]);
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2), "plain FIFO");
    }

    #[test]
    fn job_first_prefers_earliest_arrival_across_waiting_jobs() {
        // Two waiting jobs: the earliest-queued awaited server wins, not
        // the lowest job id.
        let p = Params::small_test();
        let fleet = test_fleet(4);
        let jobs = vec![Job::with_id(0, p.job_len), Job::with_id(1, p.job_len)];
        let mut q = queue_of(&[(3, Some(1)), (0, Some(0)), (1, None)]);
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(3));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        // Unassigned server only via the FIFO fallback.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
    }

    #[test]
    fn sla_aged_serves_freshest_until_the_head_breaches() {
        let mut p = Params::small_test();
        p.repair_sla_minutes = 100.0;
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut q = RepairQueue::default();
        q.push(0, Some(0), 10.0);
        q.push(1, None, 50.0);
        q.push(2, Some(0), 60.0);
        // At t=90 nobody has waited 100 minutes: freshest first.
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 90.0), Some(2));
        // At t=115 server 0 has waited 105 >= 100: it escalates.
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 115.0), Some(0));
        // Head (server 1, waited 65) is within SLA again: LIFO resumes —
        // and with one entry left, both ends coincide.
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 115.0), Some(1));
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 115.0), None);
        // Exact-boundary wait counts as breached (>=).
        q.push(3, None, 200.0);
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 300.0), Some(3));
    }

    #[test]
    fn sla_aged_head_age_skips_job_first_tombstones() {
        // A job_first pick tombstones the global head; the SLA check must
        // see the oldest *live* entry's age, not the tombstone's.
        let mut p = Params::small_test();
        p.repair_sla_minutes = 100.0;
        let fleet = test_fleet(3);
        let jobs = waiting_job(&p);
        let mut q = RepairQueue::default();
        q.push(0, Some(0), 0.0); // will be taken via the job index
        q.push(1, None, 500.0);
        q.push(2, None, 510.0);
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 520.0), Some(0));
        // t=550: the live head (1, waited 50) is within SLA -> LIFO. If
        // the dead entry at t=0 were consulted, it would force FIFO.
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 550.0), Some(2));
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 550.0), Some(1));
        assert!(q.is_empty());
    }

    #[test]
    fn sla_zero_degenerates_to_fifo() {
        // Every queued server breaches instantly: pure arrival order.
        let mut p = Params::small_test();
        p.repair_sla_minutes = 0.0;
        let fleet = test_fleet(3);
        let jobs = waiting_job(&p);
        let mut q = queue_of(&[(0, None), (1, None), (2, None)]);
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(SlaAged.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2));
    }

    #[test]
    fn mixed_pop_orders_stay_consistent() {
        // Interleaving disciplines on one queue must never duplicate or
        // lose a server (the tombstone bookkeeping).
        let p = Params::small_test();
        let fleet = test_fleet(6);
        let jobs = vec![Job::with_id(0, p.job_len)];
        let mut q = queue_of(&[
            (0, Some(0)),
            (1, None),
            (2, Some(0)),
            (3, None),
            (4, Some(0)),
            (5, None),
        ]);
        let mut got = Vec::new();
        got.push(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 0
        got.push(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 5
        got.push(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 2
        got.push(Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 1
        got.push(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 4
        got.push(Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0).unwrap()); // 3
        assert_eq!(got, vec![0, 5, 2, 1, 4, 3]);
        assert!(q.is_empty());
        assert_eq!(Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
    }

    #[test]
    fn plain_disciplines_leave_no_residue() {
        // FIFO/LIFO pops remove bucket twins eagerly and job_first
        // tombstones are reclaimed — internal storage must drain back to
        // empty, not accumulate per admission (a long-run memory leak).
        let p = Params::small_test();
        let fleet = test_fleet(8);
        let jobs = vec![Job::with_id(0, p.job_len)];
        let mut q = RepairQueue::default();
        for round in 0..50u32 {
            for s in 0..8 {
                q.push(s, if s % 3 == 0 { None } else { Some(0) }, 0.0);
            }
            for _ in 0..4 {
                assert!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0).is_some());
            }
            for _ in 0..2 {
                assert!(Lifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0).is_some());
            }
            while Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0).is_some() {}
            assert!(q.is_empty(), "round {round}");
            assert!(q.fifo.is_empty(), "fifo residue at round {round}");
            assert!(q.dead.is_empty(), "tombstone residue at round {round}");
            assert!(q.by_job.iter().all(|b| b.is_empty()), "bucket residue at round {round}");
        }
    }

    #[test]
    fn shortest_first_picks_minimal_predrawn_duration() {
        let p = Params::small_test();
        let jobs = waiting_job(&p);
        let mut fleet = test_fleet(4);
        fleet[0].predrawn_repair = Some(50.0);
        fleet[1].predrawn_repair = Some(10.0);
        fleet[2].predrawn_repair = None; // never pre-drawn: ranks last
        fleet[3].predrawn_repair = Some(10.0); // tie: arrival order wins
        let mut q = queue_of(&[(0, Some(0)), (1, None), (2, Some(0)), (3, None)]);
        let mut next =
            |q: &mut RepairQueue| ShortestFirst.pick_next(q, &fleet, &jobs, &p, 0.0);
        assert_eq!(next(&mut q), Some(1));
        assert_eq!(next(&mut q), Some(3), "10.0 tie broken by arrival order");
        assert_eq!(next(&mut q), Some(0));
        assert_eq!(next(&mut q), Some(2));
        assert_eq!(next(&mut q), None);
        assert!(q.is_empty());
    }

    #[test]
    fn shortest_first_skips_tombstones_and_keeps_consistency() {
        // Interleave with job_first so the scan must step over dead
        // entries and remove bucket twins from the middle of a bucket.
        let p = Params::small_test();
        let jobs = waiting_job(&p);
        let mut fleet = test_fleet(4);
        fleet[0].predrawn_repair = Some(5.0);
        fleet[1].predrawn_repair = Some(1.0);
        fleet[2].predrawn_repair = Some(2.0);
        fleet[3].predrawn_repair = Some(9.0);
        // 3 arrives first so the job_first tombstone lands mid-queue
        // (not at the reclaimable front).
        let mut q = queue_of(&[(3, None), (0, Some(0)), (1, Some(0)), (2, Some(0))]);
        // job_first takes the bucket head (0) and tombstones its fifo copy.
        assert_eq!(JobFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        // shortest_first must skip the dead entry and take 1 (mid-bucket).
        assert_eq!(ShortestFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        // Remaining entries still pop consistently under other orders.
        assert_eq!(Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(3));
        assert_eq!(ShortestFirst.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2));
        assert!(q.is_empty());
        // A final front pop reclaims the remaining tombstone: no residue.
        assert_eq!(Fifo.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
        assert!(q.fifo.is_empty() && q.dead.is_empty());
        assert!(q.by_job.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn pool_aware_defers_drain_backs_while_pool_is_flush() {
        let mut p = Params::small_test();
        p.spare_pool = 4;
        p.repair_pool_high_water = 0.5; // mark = 2 idle spares
        // Job 0 is done (its servers would drain back); job 1 is waiting.
        let mut done = Job::with_id(0, p.job_len);
        done.phase = JobPhase::Done;
        let jobs = vec![done, Job::with_id(1, p.job_len)];
        let mut fleet = test_fleet(6);
        fleet[4].state = ServerState::SparePool;
        fleet[5].state = ServerState::SparePool; // 2 >= mark: flush
        let mut q = queue_of(&[(0, Some(0)), (1, Some(1)), (2, Some(0))]);
        // Flush pool: only the awaited server dispatches, drain-backs defer.
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
        assert_eq!(q.len(), 2, "deferred servers stay queued");
        // The pool dips below the mark: plain FIFO resumes.
        fleet[5].state = ServerState::JobActive;
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(0));
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(2));
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
        assert!(q.is_empty());
    }

    #[test]
    fn pool_aware_boundary_counts_exact_mark_as_flush() {
        // `>=` at the mark: exactly high_water × spare_pool idle spares
        // still throttles (the pool is "full enough").
        let mut p = Params::small_test();
        p.spare_pool = 2;
        p.repair_pool_high_water = 1.0; // mark = 2
        let jobs = waiting_job(&p);
        let mut fleet = test_fleet(4);
        fleet[2].state = ServerState::SparePool;
        fleet[3].state = ServerState::SparePool;
        let mut q = queue_of(&[(0, None), (1, Some(0))]);
        // Unassigned server 0 is a pure drain-back: deferred. The awaited
        // server 1 (job 0 is waiting) dispatches out of arrival order.
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), Some(1));
        assert_eq!(PoolAware.pick_next(&mut q, &fleet, &jobs, &p, 0.0), None);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn reset_clears_everything() {
        let mut p = Params::small_test();
        p.auto_repair_capacity = 1;
        let fleet = test_fleet(4);
        let jobs = waiting_job(&p);
        let mut shop = RepairShop::new();
        shop.admit(&p, RepairStage::Automated, 0, Some(0), 0.0);
        shop.admit(&p, RepairStage::Automated, 1, Some(0), 0.0);
        let _ = shop.complete(&p, RepairStage::Automated, &Fifo, &fleet, &jobs, 0.0);
        assert!(shop.population() > 0 || shop.completed_auto > 0);
        shop.reset();
        assert_eq!(shop.population(), 0);
        assert_eq!(shop.completed_auto, 0);
        assert_eq!(shop.max_queue_auto, 0);
    }

    #[test]
    fn outcome_rates_match_probabilities() {
        let mut p = Params::small_test();
        p.auto_repair_prob = 0.8;
        p.auto_repair_fail_prob = 0.4;
        p.manual_repair_fail_prob = 0.2;
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mut escalated = 0;
        let mut fixed = 0;
        let mut resolved = 0;
        for _ in 0..n {
            match auto_outcome(&p, &mut rng) {
                AutoResult::Escalate => escalated += 1,
                AutoResult::Resolved { fixed: f } => {
                    resolved += 1;
                    if f {
                        fixed += 1;
                    }
                }
            }
        }
        assert!((escalated as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((fixed as f64 / resolved as f64 - 0.6).abs() < 0.01);
        let man_fixed = (0..n).filter(|_| manual_fixed(&p, &mut rng)).count();
        assert!((man_fixed as f64 / n as f64 - 0.8).abs() < 0.01);
    }

    #[test]
    fn durations_have_configured_means() {
        let p = Params::small_test();
        let mut rng = Rng::new(2);
        let n = 100_000;
        let auto: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Automated, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((auto - p.auto_repair_time).abs() / p.auto_repair_time < 0.02);
        let man: f64 = (0..n)
            .map(|_| duration(&p, RepairStage::Manual, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((man - p.manual_repair_time).abs() / p.manual_repair_time < 0.02);
    }
}
