//! The cluster simulation: the event loop and dispatch glue.
//!
//! This is the executable form of the paper's Figure 1 flowchart, reduced
//! to *mechanism*: [`Simulation`] pops events and routes each one to the
//! right flow ([`crate::model::lifecycle`] for the job lifecycle,
//! [`crate::model::repair_flow`] for the repair pipeline). All *policy*
//! lives behind the four trait objects in [`PolicySet`] — host selection,
//! repair queueing, checkpoint semantics, and failure clocks — and all
//! shared state in [`SimCtx`].
//!
//! One [`Simulation`] = one cluster with one seed running
//! `Params::num_jobs` gang-scheduled jobs (all jobs contend for the same
//! working/spare pools and repair shop). [`crate::sweep`] runs many,
//! through the buffer-reusing [`ReplicationRunner`].

use crate::config::Params;
use crate::model::ctx::SimCtx;
use crate::model::events::{Ev, FailureKind};
use crate::model::failure::PerServerClocks;
use crate::model::job::{Job, JobPhase};
use crate::model::lifecycle as flow;
use crate::model::outputs::RunOutputs;
use crate::model::policy::{PolicySet, PolicySpec};
use crate::model::repair_flow;
use crate::model::selection::SelectionPolicy;
use crate::model::server::Server;
use crate::model::workload::WORKLOAD_STREAM;
use crate::sim::engine::{Engine, QueueKind};
use crate::sim::rng::Rng;
use crate::sim::Time;
use crate::trace::inject::{Injection, InjectionPlan};
use crate::trace::{Observer, Trace};

/// One simulation run in progress: the shared state ([`SimCtx`]) plus the
/// pluggable policy subsystems ([`PolicySet`]) and the injection script.
pub struct Simulation {
    ctx: SimCtx,
    policies: PolicySet,
    injections: InjectionPlan,
    /// Injections indexed by their `Ev::Inject { idx }` payload.
    injection_buf: Vec<Injection>,
}

impl Simulation {
    /// Build a simulation from parameters and a seed, with the paper's
    /// default policies.
    pub fn new(p: &Params, seed: u64) -> Simulation {
        Self::with_rng(p, Rng::new(seed))
    }

    /// Build with a pre-derived RNG stream (sweeps use
    /// `Rng::derived(master, &[point, replication])`).
    pub fn with_rng(p: &Params, rng: Rng) -> Simulation {
        Self::from_spec(p, &PolicySpec::default(), rng)
            .expect("default policy spec always builds")
    }

    /// Build with named policies (the Scenario/sweep entry point).
    pub fn from_spec(p: &Params, spec: &PolicySpec, rng: Rng) -> Result<Simulation, String> {
        Self::from_spec_warm(p, spec, rng, None)
    }

    /// [`Simulation::from_spec`] with fleet/topology construction routed
    /// through a serve-layer warm cache (`None` = cold build; warm and
    /// cold runs are byte-identical).
    pub fn from_spec_warm(
        p: &Params,
        spec: &PolicySpec,
        rng: Rng,
        warm: Option<&crate::serve::cache::WarmHandle>,
    ) -> Result<Simulation, String> {
        Ok(Simulation {
            ctx: SimCtx::new_warm(p, rng, warm),
            policies: spec.build(p)?,
            injections: InjectionPlan::default(),
            injection_buf: Vec::new(),
        })
    }

    /// Force per-server failure clocks even for exponential distributions
    /// (perf A/B testing; results are distribution-identical but not
    /// draw-identical to the gang fast path).
    pub fn with_per_server_clocks(mut self) -> Self {
        self.policies.failure = Box::new(PerServerClocks);
        self
    }

    /// Run on an explicit event-queue implementation (A/B benchmarking
    /// and the cross-queue equivalence suite; both orders are identical,
    /// so outputs are byte-equal either way). Must be called before any
    /// events are scheduled — i.e. right after construction.
    pub fn with_queue(mut self, kind: QueueKind) -> Self {
        debug_assert_eq!(
            self.ctx.engine.pending(),
            0,
            "queue swap after events were scheduled"
        );
        self.ctx.engine = Engine::with_queue(kind, self.ctx.p.job_size as usize + 64);
        self
    }

    /// Use a non-default host-selection policy object.
    pub fn with_selection(mut self, policy: Box<dyn SelectionPolicy>) -> Self {
        self.policies.selection = policy;
        self
    }

    /// Record a structured trace of the run.
    pub fn with_trace(mut self) -> Self {
        self.ctx.trace = Some(Trace::default());
        self
    }

    /// Install an event observer ([`crate::trace::Observer`]): it sees
    /// every traced decision point — failures, repairs, preemptions,
    /// stalls — as the run executes. Use [`crate::trace::Shared`] to keep
    /// a handle on the data afterwards. Observers never affect the run
    /// (no draws, no event-order changes).
    pub fn with_observer(mut self, observer: Box<dyn Observer>) -> Self {
        self.ctx.observer = Some(observer);
        self
    }

    /// Script failure injections (see [`crate::trace::inject`]); each
    /// injection names its target job.
    pub fn with_injections(mut self, plan: InjectionPlan) -> Self {
        self.injections = plan;
        self
    }

    /// Re-initialize in place for a new run, reusing the previous run's
    /// allocations (the [`ReplicationRunner`] path).
    fn reset(
        &mut self,
        p: &Params,
        spec: &PolicySpec,
        rng: Rng,
        warm: Option<&crate::serve::cache::WarmHandle>,
    ) -> Result<(), String> {
        self.ctx.reset_warm(p, rng, warm);
        self.policies = spec.build(p)?;
        self.injections = InjectionPlan::default();
        self.injection_buf.clear();
        Ok(())
    }

    /// Run to completion (or the `max_sim_time` horizon) and return the
    /// measured outputs.
    pub fn run(mut self) -> RunOutputs {
        self.run_in_place()
    }

    /// Run and also return the trace (empty unless `with_trace`).
    pub fn run_traced(mut self) -> (RunOutputs, Trace) {
        let out = self.run_in_place();
        let trace = self.ctx.trace.take().unwrap_or_default();
        (out, trace)
    }

    /// Stamp the run's arrival plan, when a `workload:` is configured.
    ///
    /// Draws the plan from a dedicated [`Rng::derived`] stream seeded by
    /// one `next_u64` off the master RNG — the *only* extra draw, taken
    /// only when a workload exists, so no-workload runs stay
    /// byte-identical. Each planned job gets its resolved shape stamped
    /// and a `JobArrival` event scheduled; a replay workload also joins
    /// its recorded failures to the injection schedule as
    /// server-targeted injections. Returns true when arrivals are
    /// open-loop (the caller skips the legacy all-at-t=0 start).
    fn init_workload(&mut self) -> bool {
        let Some(spec) = self.ctx.p.workload.clone() else {
            return false;
        };
        let wseed = self.ctx.rng.next_u64();
        let mut wrng = Rng::derived(wseed, &[WORKLOAD_STREAM]);
        let plan = spec.plan(&self.ctx.p, &mut wrng);
        assert_eq!(
            plan.len(),
            self.ctx.jobs.len(),
            "workload plan size must match num_jobs (config loading keeps them in sync)"
        );
        for (job, s) in self.ctx.jobs.iter_mut().zip(&plan) {
            job.size = s.size;
            job.standbys_target = s.standbys;
            job.len = s.len;
            job.remaining = s.len;
            job.arrived = false;
            job.admitted = false;
        }
        for (j, s) in plan.iter().enumerate() {
            self.ctx.engine.schedule_at(s.at, Ev::JobArrival { job: j as u32 });
        }
        for f in spec.replay_failures() {
            let kind = if f.systematic {
                FailureKind::Systematic
            } else {
                FailureKind::Random
            };
            let idx = self.injection_buf.len();
            self.ctx.engine.schedule_at(f.at, Ev::Inject { idx });
            self.injection_buf.push(Injection::for_server(f.at, f.server, kind));
        }
        true
    }

    /// The event loop (both the consuming and the buffer-reusing entry
    /// points land here).
    fn run_in_place(&mut self) -> RunOutputs {
        // Schedule scripted injections.
        let mut k = 0usize;
        while let Some(inj) = self.injections.pop() {
            self.ctx.engine.schedule_at(inj.at, Ev::Inject { idx: k });
            self.injection_buf.push(inj);
            k += 1;
        }
        // Open-loop arrivals (and replayed failures), when configured.
        let open_loop = self.init_workload();
        // Periodic bad-server regeneration.
        if self.ctx.p.bad_regen_interval > 0.0 {
            self.ctx.engine.schedule_in(self.ctx.p.bad_regen_interval, Ev::BadRegen);
        }
        // Global failure clocks (correlated domain outages; no-op — and
        // no draw — for the plain models).
        self.policies.failure.on_sim_start(&mut self.ctx);
        // Initial host selection for every job (in id order: earlier jobs
        // get first pick of the pools). Open-loop jobs instead enter at
        // their scheduled `JobArrival`.
        self.ctx.out.per_job_makespans = vec![0.0; self.ctx.jobs.len()];
        if !open_loop {
            for j in 0..self.ctx.jobs.len() {
                flow::attempt_start(&mut self.ctx, &mut self.policies, j);
            }
        }

        while let Some((now, ev)) = self.ctx.engine.pop() {
            if now > self.ctx.p.max_sim_time {
                break;
            }
            self.dispatch(ev);
            if self.ctx.all_done() {
                break;
            }
        }

        // Horizon cut: a job still mid-burst has computed real work since
        // its last pause that `remaining` does not yet reflect — fold the
        // partial burst into the checkpoint accounting so `work_done` and
        // `goodput_fraction` see it (a failure-free job that ran the whole
        // horizon must not report zero goodput). Only the new checkpoint
        // fields move; the legacy outputs (burst stats, work_lost) stay
        // byte-identical to the pre-cost simulator.
        if !self.ctx.all_done() {
            let horizon = self.ctx.p.max_sim_time;
            for j in 0..self.ctx.jobs.len() {
                if self.ctx.jobs[j].phase != JobPhase::Running {
                    continue;
                }
                let r0 = self.ctx.jobs[j].remaining;
                let wall = (horizon - self.ctx.jobs[j].run_start).max(0.0);
                let acct = self
                    .policies
                    .checkpoint
                    .account_burst(j, self.ctx.jobs[j].len - r0, wall, true);
                self.ctx.out.checkpoints_committed += acct.commits;
                self.ctx.out.checkpoint_overhead += acct.overhead;
                self.ctx.jobs[j].remaining = (r0 - acct.work).max(0.0);
            }
        }

        self.ctx.finalize();
        std::mem::take(&mut self.ctx.out)
    }

    /// Route one event to its flow handler.
    fn dispatch(&mut self, ev: Ev) {
        let ctx = &mut self.ctx;
        let pol = &mut self.policies;
        match ev {
            Ev::Fail { server, gen, kind } => flow::on_fail(ctx, pol, server, gen, kind),
            Ev::GangFail { job, gang_gen } => {
                flow::on_gang_fail(ctx, pol, job as usize, gang_gen)
            }
            Ev::JobComplete { job, gen } => {
                flow::on_job_complete(ctx, pol, job as usize, gen)
            }
            Ev::RecoveryDone { job, gen } => {
                flow::on_recovery_done(ctx, pol, job as usize, gen)
            }
            Ev::SelectionDone { job, gen } => {
                flow::on_selection_done(ctx, pol, job as usize, gen)
            }
            Ev::PreemptArrive { server } => flow::on_preempt_arrive(ctx, pol, server),
            Ev::RepairDone { server, stage } => {
                repair_flow::on_repair_done(ctx, pol, server, stage)
            }
            Ev::BadRegen => flow::on_bad_regen(ctx, pol),
            Ev::DomainOutage => flow::on_domain_outage(ctx, pol),
            Ev::Inject { idx } => flow::on_inject(ctx, pol, self.injection_buf[idx]),
            Ev::JobArrival { job } => flow::on_job_arrival(ctx, pol, job as usize),
        }
    }

    // ---------------------------------------------------------------- //
    // Introspection (tests, property checks)
    // ---------------------------------------------------------------- //

    /// Server-conservation invariant (see [`SimCtx::conservation_ok`]).
    pub fn conservation_ok(&self) -> bool {
        self.ctx.conservation_ok()
    }

    /// Current simulation time (test hook).
    pub fn now(&self) -> Time {
        self.ctx.now()
    }

    /// Immutable view of job 0 (test hook; single-job configurations).
    pub fn job(&self) -> &Job {
        &self.ctx.jobs[0]
    }

    /// Immutable view of all jobs (test hook).
    pub fn jobs(&self) -> &[Job] {
        &self.ctx.jobs
    }

    /// Immutable view of the fleet (test hook).
    pub fn fleet(&self) -> &[Server] {
        &self.ctx.fleet
    }

    /// Step the simulation by exactly one event (test hook). Returns false
    /// when no events remain.
    pub fn step(&mut self) -> bool {
        match self.ctx.engine.pop() {
            Some((_, ev)) => {
                self.dispatch(ev);
                true
            }
            None => false,
        }
    }

    /// Initialize scheduling as `run()` does, without consuming events
    /// (test hook for step-wise execution).
    pub fn prime(&mut self) {
        let open_loop = self.init_workload();
        if self.ctx.p.bad_regen_interval > 0.0 {
            self.ctx.engine.schedule_in(self.ctx.p.bad_regen_interval, Ev::BadRegen);
        }
        self.policies.failure.on_sim_start(&mut self.ctx);
        self.ctx.out.per_job_makespans = vec![0.0; self.ctx.jobs.len()];
        if !open_loop {
            for j in 0..self.ctx.jobs.len() {
                flow::attempt_start(&mut self.ctx, &mut self.policies, j);
            }
        }
    }
}

/// Batched replication runner: reuses one [`Simulation`]'s buffers (event
/// heap, fleet vector, pool free-lists, job server-lists, repair queues)
/// across many replications instead of reallocating per run. Sweep worker
/// threads each own one.
///
/// Byte-equivalence with fresh construction is guaranteed (and tested):
/// `runner.run(p, spec, rng)` produces the same [`RunOutputs`] as
/// `Simulation::from_spec(p, spec, rng).run()`.
#[derive(Default)]
pub struct ReplicationRunner {
    sim: Option<Simulation>,
    /// Warm fleet/topology cache consulted on every (re)build — installed
    /// by the serve layer's execution control. `None` (the default, and
    /// always the CLI path) builds cold; warm and cold runs are
    /// byte-identical.
    pub warm: Option<crate::serve::cache::WarmHandle>,
    /// Cooperative cancellation: when the flag is set, `run` returns
    /// `RunOutputs::default()` without simulating. The pool still fills
    /// every result slot (so `run_pool_ordered`'s completeness invariant
    /// holds); the serve layer discards the whole response anyway.
    pub cancel: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
}

impl ReplicationRunner {
    pub fn new() -> ReplicationRunner {
        ReplicationRunner::default()
    }

    /// Run one replication, reusing buffers from previous runs.
    ///
    /// Panics if `spec` cannot be built for `p` (validate specs up front;
    /// numeric sweeps never change policy validity).
    pub fn run(&mut self, p: &Params, spec: &PolicySpec, rng: Rng) -> RunOutputs {
        use std::sync::atomic::Ordering;
        if self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed)) {
            return RunOutputs::default();
        }
        const MSG: &str = "policy spec must build for swept params";
        match &mut self.sim {
            Some(sim) => sim.reset(p, spec, rng, self.warm.as_ref()).expect(MSG),
            slot @ None => {
                *slot = Some(
                    Simulation::from_spec_warm(p, spec, rng, self.warm.as_ref())
                        .expect(MSG),
                );
            }
        }
        self.sim.as_mut().expect("initialized above").run_in_place()
    }
}
