//! The cluster simulation: the event loop composing all five modules.
//!
//! This is the executable form of the paper's Figure 1 flowchart. One
//! [`Simulation`] = one cluster with one seed running `Params::num_jobs`
//! identical gang-scheduled jobs (assumption 6's single job by default;
//! the multi-job extension the paper names is first-class — all jobs
//! contend for the same working/spare pools and repair shop).
//! [`crate::sweep`] runs many simulations.

use crate::config::Params;
use crate::model::coordinator;
use crate::model::diagnosis::{self, Diagnosis};
use crate::model::events::{Ev, FailureKind, RepairStage, ServerId};
use crate::model::job::{Job, JobPhase};
use crate::model::outputs::RunOutputs;
use crate::model::pool::Pools;
use crate::model::regen;
use crate::model::repair::{self, Admission, AutoResult, RepairShop};
use crate::model::retirement;
use crate::model::scheduler::{self, SelectionPolicy};
use crate::model::server::{build_fleet, Server, ServerState};
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::Time;
use crate::trace::inject::{Injection, InjectionPlan};
use crate::trace::{Trace, TraceKind};

/// One simulation run in progress.
pub struct Simulation {
    p: Params,
    policy: SelectionPolicy,
    engine: Engine<Ev>,
    rng: Rng,
    fleet: Vec<Server>,
    pools: Pools,
    jobs: Vec<Job>,
    shop: RepairShop,
    out: RunOutputs,
    burst_sum: Time,
    burst_count: u64,
    trace: Option<Trace>,
    injections: InjectionPlan,
    /// Injections indexed by their `Ev::Inject` payload (target: job 0).
    injection_buf: Vec<Injection>,
    /// Per-job guard for `GangFail` events (bumped on every interrupt and
    /// on every gang-composition change).
    gang_gens: Vec<u64>,
    /// Per-job cached count of bad servers among the active gang (fast
    /// path only; maintained incrementally on swaps, recomputed on
    /// selection/regen).
    gang_n_bads: Vec<usize>,
    /// Use the single-event exponential gang clock instead of per-server
    /// clocks (valid only for the memoryless Exponential family).
    gang_fast_path: bool,
}

impl Simulation {
    /// Build a simulation from parameters and a seed.
    pub fn new(p: &Params, seed: u64) -> Simulation {
        Self::with_rng(p, Rng::new(seed))
    }

    /// Build with a pre-derived RNG stream (sweeps use
    /// `Rng::derived(master, &[point, replication])`).
    pub fn with_rng(p: &Params, mut rng: Rng) -> Simulation {
        let fleet = build_fleet(p, &mut rng);
        let pools = Pools::from_fleet(&fleet);
        let n_jobs = p.num_jobs.max(1) as usize;
        let jobs = (0..n_jobs).map(|j| Job::with_id(j as u32, p.job_len)).collect();
        Simulation {
            p: p.clone(),
            policy: SelectionPolicy::default(),
            engine: Engine::with_capacity(p.job_size as usize + 64),
            rng,
            fleet,
            pools,
            jobs,
            shop: RepairShop::new(),
            out: RunOutputs::default(),
            burst_sum: 0.0,
            burst_count: 0,
            trace: None,
            injections: InjectionPlan::default(),
            injection_buf: Vec::new(),
            gang_gens: vec![0; n_jobs],
            gang_n_bads: vec![0; n_jobs],
            gang_fast_path: matches!(
                p.failure_dist,
                crate::config::DistKind::Exponential
            ),
        }
    }

    /// Force the per-server failure-clock path even for exponential
    /// distributions (perf A/B testing; results are distribution-identical
    /// but not draw-identical to the gang fast path).
    pub fn with_per_server_clocks(mut self) -> Self {
        self.gang_fast_path = false;
        self
    }

    /// Use a non-default host-selection policy.
    pub fn with_policy(mut self, policy: SelectionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Record a structured trace of the run.
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(Trace::default());
        self
    }

    /// Script failure injections against job 0 (see [`crate::trace::inject`]).
    pub fn with_injections(mut self, plan: InjectionPlan) -> Self {
        self.injections = plan;
        self
    }

    #[inline]
    fn tr(&mut self, kind: TraceKind) {
        if let Some(t) = &mut self.trace {
            t.push(self.engine.now(), kind);
        }
    }

    fn all_done(&self) -> bool {
        self.jobs.iter().all(|j| j.phase == JobPhase::Done)
    }

    /// Run to completion (or the `max_sim_time` horizon) and return the
    /// measured outputs.
    pub fn run(self) -> RunOutputs {
        let (out, _) = self.run_traced();
        out
    }

    /// Run and also return the trace (empty unless `with_trace`).
    pub fn run_traced(mut self) -> (RunOutputs, Trace) {
        // Schedule scripted injections.
        let mut k = 0usize;
        while let Some(inj) = self.injections.pop() {
            self.engine.schedule_at(inj.at, Ev::Inject { idx: k });
            self.injection_buf.push(inj);
            k += 1;
        }
        // Periodic bad-server regeneration.
        if self.p.bad_regen_interval > 0.0 {
            self.engine.schedule_in(self.p.bad_regen_interval, Ev::BadRegen);
        }
        // Initial host selection for every job (in id order: earlier jobs
        // get first pick of the pools).
        self.out.per_job_makespans = vec![0.0; self.jobs.len()];
        for j in 0..self.jobs.len() {
            self.attempt_start(j);
        }

        while let Some((now, ev)) = self.engine.pop() {
            if now > self.p.max_sim_time {
                break;
            }
            self.dispatch(ev);
            if self.all_done() {
                break;
            }
        }

        self.finish();
        let trace = self.trace.take().unwrap_or_default();
        (self.out, trace)
    }

    fn finish(&mut self) {
        if self.all_done() {
            self.out.completed = true;
            self.out.makespan = self
                .out
                .per_job_makespans
                .iter()
                .cloned()
                .fold(0.0f64, f64::max);
        } else {
            // Horizon hit with at least one job unfinished.
            self.out.completed = false;
            self.out.makespan = self.p.max_sim_time;
            for j in &self.jobs {
                if j.phase == JobPhase::Stalled {
                    self.out.stall_time += self.p.max_sim_time - j.stalled_since;
                }
            }
            self.tr(TraceKind::Horizon);
        }
        self.out.preemptions = self.pools.preemptions;
        self.out.preemption_cost = self.pools.preemption_cost_total;
        self.out.repairs_auto = self.shop.completed_auto;
        self.out.repairs_manual = self.shop.completed_manual;
        self.out.avg_run_duration = if self.burst_count > 0 {
            self.burst_sum / self.burst_count as f64
        } else {
            0.0
        };
        self.out.events_delivered = self.engine.delivered();
    }

    // ---------------------------------------------------------------- //
    // Event dispatch
    // ---------------------------------------------------------------- //

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Fail { server, gen, kind } => self.on_fail(server, gen, kind),
            Ev::GangFail { job, gang_gen } => self.on_gang_fail(job as usize, gang_gen),
            Ev::JobComplete { job, gen } => self.on_job_complete(job as usize, gen),
            Ev::RecoveryDone { job, gen } => self.on_recovery_done(job as usize, gen),
            Ev::SelectionDone { job, gen } => self.on_selection_done(job as usize, gen),
            Ev::PreemptArrive { server } => self.on_preempt_arrive(server),
            Ev::RepairDone { server, stage } => self.on_repair_done(server, stage),
            Ev::BadRegen => self.on_bad_regen(),
            Ev::Inject { idx } => self.on_inject(idx),
        }
    }

    fn on_fail(&mut self, server: ServerId, gen: u64, kind: FailureKind) {
        let s = &self.fleet[server as usize];
        // Lazy cancellation: stale clock, or server no longer computing.
        if s.gen.0 != gen || s.state != ServerState::JobActive {
            return;
        }
        let Some(j) = s.assigned_job.map(|j| j as usize) else {
            return;
        };
        if self.jobs[j].phase != JobPhase::Running {
            return;
        }
        self.handle_failure(j, server, kind);
    }

    fn on_inject(&mut self, idx: usize) {
        // Scripted failure against job 0: resolve the victim now; drop if
        // the job is not running (the injection missed its window).
        if self.jobs[0].phase != JobPhase::Running || self.jobs[0].active.is_empty() {
            return;
        }
        let inj = self.injection_buf[idx];
        let victim = self.jobs[0].active[inj.victim_index % self.jobs[0].active.len()];
        self.handle_failure(0, victim, inj.kind);
    }

    /// Common failure path (stochastic clock or injection) for job `j`.
    fn handle_failure(&mut self, j: usize, server: ServerId, kind: FailureKind) {
        let now = self.engine.now();

        // Count the failure.
        self.out.failures_total += 1;
        match kind {
            FailureKind::Random => self.out.failures_random += 1,
            FailureKind::Systematic => self.out.failures_systematic += 1,
        }
        self.tr(TraceKind::Failure {
            server,
            systematic: kind == FailureKind::Systematic,
        });

        // Module 2 (coordinator): stop the gang, commit progress.
        // Fast path: per-server gen bumps / age banking are dead work when
        // no per-server failure clocks exist (exponential gang clock).
        let burst = if self.gang_fast_path {
            self.jobs[j].pause(now)
        } else {
            coordinator::interrupt(&mut self.jobs[j], &mut self.fleet, now)
        };
        self.burst_sum += burst;
        self.burst_count += 1;
        // Checkpoint granularity (extension): lose uncommitted work.
        let lost = self.jobs[j]
            .apply_checkpoint_loss(self.p.checkpoint_interval, self.p.job_len);
        self.out.work_lost += lost;
        self.jobs[j].gen.bump(); // invalidate JobComplete / stale phase events

        // Diagnosis (inputs 12–13) — allocation-free over the active list
        // (which still contains the failed server at this point).
        let diag = diagnosis::diagnose_in_gang(
            &self.p,
            server,
            &self.jobs[j].active,
            &mut self.rng,
        );

        let to_repair: Option<ServerId> = match diag {
            Diagnosis::Undiagnosed => {
                self.out.undiagnosed += 1;
                None
            }
            Diagnosis::Correct(id) => Some(id),
            Diagnosis::Wrong { blamed, .. } => {
                self.out.wrong_diagnoses += 1;
                Some(blamed)
            }
        };

        match to_repair {
            None => {
                // Restart in place after recovery: nobody leaves the gang.
                self.begin_recovery(j);
            }
            Some(blamed) => {
                // The blamed server leaves the job.
                if self.fleet[blamed as usize].is_bad {
                    self.gang_n_bads[j] -= 1;
                }
                let removed = self.jobs[j].remove(blamed);
                debug_assert!(removed, "blamed server {blamed} not in job {j}");

                // Retirement policy (§II-B): score before repairing.
                let retire = retirement::record_and_decide(
                    &self.p,
                    &mut self.fleet[blamed as usize],
                    now,
                );
                if retire {
                    let sv = &mut self.fleet[blamed as usize];
                    sv.state = ServerState::Retired;
                    sv.assigned_job = None;
                    self.out.retirements += 1;
                    self.tr(TraceKind::Retired { server: blamed });
                } else {
                    self.start_repair(blamed);
                }

                // Replacement: warm standby if available, else selection.
                if let Some(promoted) = self.jobs[j].promote_standby() {
                    if self.fleet[promoted as usize].is_bad {
                        self.gang_n_bads[j] += 1;
                    }
                    self.fleet[promoted as usize].state = ServerState::JobActive;
                    self.out.standby_swaps += 1;
                    self.tr(TraceKind::StandbySwap {
                        failed: blamed,
                        replacement: promoted,
                    });
                    self.begin_recovery(j);
                } else {
                    self.out.host_selections += 1;
                    self.attempt_start(j);
                }
            }
        }
    }

    /// Enter checkpoint-restore recovery (the constant `recovery_time`).
    fn begin_recovery(&mut self, j: usize) {
        self.jobs[j].phase = JobPhase::Recovering;
        self.out.recovery_total += self.p.recovery_time;
        self.engine.schedule_in(
            self.p.recovery_time,
            Ev::RecoveryDone { job: j as u32, gen: self.jobs[j].gen.0 },
        );
    }

    /// (Re-)allocation: Figure 1's host-selection / stall decision.
    fn attempt_start(&mut self, j: usize) {
        let was_stalled = self.jobs[j].phase == JobPhase::Stalled;
        let alloc = scheduler::allocate(
            &self.p,
            self.policy,
            &mut self.jobs[j],
            &mut self.pools,
            &mut self.fleet,
            &mut self.rng,
        );
        for &id in &alloc.preempted {
            self.tr(TraceKind::Preempted { server: id });
            self.engine
                .schedule_in(self.p.waiting_time, Ev::PreemptArrive { server: id });
        }
        if alloc.can_start {
            if was_stalled {
                let waited = self.engine.now() - self.jobs[j].stalled_since;
                self.out.stall_time += waited;
                self.tr(TraceKind::Unstalled { waited });
            }
            self.jobs[j].phase = JobPhase::Selecting;
            self.tr(TraceKind::HostSelection { allotted: self.jobs[j].allotted() });
            self.engine.schedule_in(
                self.p.host_selection_time,
                Ev::SelectionDone { job: j as u32, gen: self.jobs[j].gen.0 },
            );
        } else {
            if !was_stalled {
                self.jobs[j].stalled_since = self.engine.now();
            }
            self.jobs[j].phase = JobPhase::Stalled;
            self.tr(TraceKind::Stalled { allotted: self.jobs[j].allotted() });
        }
    }

    /// Give every stalled job another allocation attempt (a server just
    /// became available somewhere).
    fn retry_stalled(&mut self) {
        for j in 0..self.jobs.len() {
            if self.jobs[j].phase == JobPhase::Stalled {
                self.attempt_start(j);
            }
        }
    }

    fn on_selection_done(&mut self, j: usize, gen: u64) {
        if self.jobs[j].gen.0 != gen || self.jobs[j].phase != JobPhase::Selecting {
            return;
        }
        let ok = scheduler::activate(&self.p, &mut self.jobs[j], &mut self.fleet);
        debug_assert!(ok, "selection completed without enough servers");
        self.recount_gang_bad(j);
        if self.jobs[j].remaining < self.p.job_len {
            // There is a checkpoint to restore.
            self.begin_recovery(j);
        } else {
            self.start_running(j);
        }
    }

    fn on_recovery_done(&mut self, j: usize, gen: u64) {
        if self.jobs[j].gen.0 != gen || self.jobs[j].phase != JobPhase::Recovering {
            return;
        }
        self.tr(TraceKind::RecoveryDone);
        // Standbys may have arrived while recovering; top the gang up.
        let before = self.jobs[j].active.len();
        let ok = scheduler::activate(&self.p, &mut self.jobs[j], &mut self.fleet);
        debug_assert!(ok, "recovery completed without enough servers");
        if self.jobs[j].active.len() != before {
            self.recount_gang_bad(j); // rare: arrivals promoted mid-recovery
        }
        self.start_running(j);
    }

    /// Arm the gang and let job `j` run.
    fn start_running(&mut self, j: usize) {
        let now = self.engine.now();
        debug_assert!(self.jobs[j].active.len() >= self.p.job_size as usize);
        self.jobs[j].resume(now);
        if !self.gang_fast_path {
            // Per-server bookkeeping only matters for age-dependent clocks.
            coordinator::mark_running(&self.jobs[j], &mut self.fleet, now);
        }
        if self.jobs[j].remaining >= self.p.job_len {
            self.tr(TraceKind::JobStarted);
        }
        // Completion clock first (FIFO tie-break: completion wins a tie
        // against a failure at the exact same instant).
        self.engine.schedule_in(
            self.jobs[j].remaining,
            Ev::JobComplete { job: j as u32, gen: self.jobs[j].gen.0 },
        );
        // Failure clocks (module 1).
        if self.gang_fast_path {
            self.schedule_gang_clock(j);
        } else {
            for i in 0..self.jobs[j].active.len() {
                let id = self.jobs[j].active[i];
                let s = &self.fleet[id as usize];
                let (dt, kind) = s.sample_failure(&self.p, &mut self.rng);
                self.engine
                    .schedule_in(dt, Ev::Fail { server: id, gen: s.gen.0, kind });
            }
        }
    }

    /// Exponential fast path: one clock for the whole gang.
    /// min over N Exp clocks = Exp(total rate); the victim and kind are
    /// resolved rate-proportionally when the clock fires.
    fn schedule_gang_clock(&mut self, j: usize) {
        self.gang_gens[j] += 1;
        let n_active = self.jobs[j].active.len();
        let n_bad = self.gang_n_bads[j];
        debug_assert_eq!(n_bad, self.gang_composition(j).1, "gang_n_bad drifted");
        let total_rate = n_active as f64 * self.p.random_failure_rate
            + n_bad as f64 * self.p.systematic_failure_rate;
        if total_rate <= 0.0 {
            return; // failure-free configuration
        }
        let dt = -self.rng.next_open_f64().ln() / total_rate;
        self.engine.schedule_in(
            dt,
            Ev::GangFail { job: j as u32, gang_gen: self.gang_gens[j] },
        );
    }

    fn gang_composition(&self, j: usize) -> (usize, usize) {
        let n_active = self.jobs[j].active.len();
        let n_bad = self.jobs[j]
            .active
            .iter()
            .filter(|&&id| self.fleet[id as usize].is_bad)
            .count();
        (n_active, n_bad)
    }

    /// Re-derive the cached bad-active count (selection / regen paths —
    /// the standby-swap hot path maintains it incrementally).
    fn recount_gang_bad(&mut self, j: usize) {
        self.gang_n_bads[j] = self.gang_composition(j).1;
    }

    fn on_gang_fail(&mut self, j: usize, gang_gen: u64) {
        if gang_gen != self.gang_gens[j] || self.jobs[j].phase != JobPhase::Running {
            return;
        }
        // Resolve victim + kind rate-proportionally.
        let n_active = self.jobs[j].active.len();
        let n_bad = self.gang_n_bads[j];
        let rate_random = n_active as f64 * self.p.random_failure_rate;
        let rate_sys = n_bad as f64 * self.p.systematic_failure_rate;
        let total = rate_random + rate_sys;
        debug_assert!(total > 0.0);
        let (victim, kind) = if self.rng.next_f64() * total < rate_random {
            // A random clock fired: uniform victim over all active.
            let k = self.rng.next_below(n_active as u64) as usize;
            (self.jobs[j].active[k], FailureKind::Random)
        } else {
            // A systematic clock fired: uniform victim over bad actives.
            let k = self.rng.next_below(n_bad as u64) as usize;
            let victim = self.jobs[j]
                .active
                .iter()
                .copied()
                .filter(|&id| self.fleet[id as usize].is_bad)
                .nth(k)
                .expect("bad-active count changed under us");
            (victim, FailureKind::Systematic)
        };
        self.gang_gens[j] += 1; // retire this clock before the interrupt
        self.handle_failure(j, victim, kind);
    }

    fn on_job_complete(&mut self, j: usize, gen: u64) {
        if self.jobs[j].gen.0 != gen || self.jobs[j].phase != JobPhase::Running {
            return;
        }
        let now = self.engine.now();
        let burst = self.jobs[j].pause(now);
        self.burst_sum += burst;
        self.burst_count += 1;
        debug_assert!(self.jobs[j].remaining <= 1e-6);
        self.jobs[j].phase = JobPhase::Done;
        self.out.per_job_makespans[j] = now;
        self.tr(TraceKind::JobCompleted { makespan: now });

        // Release the job's servers back to the pools (other jobs may be
        // waiting on them).
        let mut released: Vec<ServerId> = self.jobs[j].active.drain(..).collect();
        released.extend(self.jobs[j].standbys.drain(..));
        for id in released {
            let s = &mut self.fleet[id as usize];
            s.gen.bump(); // retire any in-flight per-server clocks
            s.assigned_job = None;
            self.pools.route_freed(&mut self.fleet, id);
        }
        self.gang_n_bads[j] = 0;
        self.retry_stalled();
    }

    fn on_preempt_arrive(&mut self, server: ServerId) {
        self.pools.arrive(&mut self.fleet, server);
        self.tr(TraceKind::PreemptArrived { server });
        let target = (self.p.job_size + self.p.warm_standbys) as usize;
        // Offer the arrival to the neediest job (stalled first, then any
        // under-allotted one), in id order.
        let pick = (0..self.jobs.len())
            .filter(|&j| {
                self.jobs[j].phase != JobPhase::Done && self.jobs[j].allotted() < target
            })
            .min_by_key(|&j| (self.jobs[j].phase != JobPhase::Stalled, j));
        match pick {
            Some(j) => {
                let s = &mut self.fleet[server as usize];
                s.state = ServerState::JobStandby;
                s.assigned_job = Some(j as u32);
                self.jobs[j].standbys.push(server);
                if self.jobs[j].phase == JobPhase::Stalled {
                    self.attempt_start(j);
                }
            }
            None => {
                // No longer needed: drain back.
                self.pools.route_freed(&mut self.fleet, server);
                self.retry_stalled();
            }
        }
    }

    // ---------------------------------------------------------------- //
    // Repair pipeline (module 4)
    // ---------------------------------------------------------------- //

    /// Admission into a repair stage (possibly queueing on capacity).
    fn enter_stage(&mut self, server: ServerId, stage: RepairStage) {
        match self.shop.admit(&self.p, stage, server) {
            Admission::Start => self.start_stage(server, stage),
            Admission::Queued => {
                self.fleet[server as usize].state = ServerState::RepairQueued;
            }
        }
    }

    fn start_stage(&mut self, server: ServerId, stage: RepairStage) {
        let s = &mut self.fleet[server as usize];
        s.state = match stage {
            RepairStage::Automated => ServerState::AutoRepair,
            RepairStage::Manual => ServerState::ManualRepair,
        };
        let d = repair::duration(&self.p, stage, &mut self.rng);
        self.tr(TraceKind::RepairStart {
            server,
            manual: stage == RepairStage::Manual,
        });
        self.engine.schedule_in(d, Ev::RepairDone { server, stage });
    }

    fn start_repair(&mut self, server: ServerId) {
        // Every failure goes to automated testing first (assumption 3).
        self.enter_stage(server, RepairStage::Automated);
    }

    fn on_repair_done(&mut self, server: ServerId, stage: RepairStage) {
        // Free the shop slot; the FIFO head (if any) starts its repair.
        if let Some(next) = self.shop.complete(stage) {
            self.start_stage(next, stage);
        }

        match stage {
            RepairStage::Automated => match repair::auto_outcome(&self.p, &mut self.rng) {
                AutoResult::Escalate => {
                    self.enter_stage(server, RepairStage::Manual);
                }
                AutoResult::Resolved { fixed } => {
                    self.reintegrate(server, false, fixed);
                }
            },
            RepairStage::Manual => {
                let fixed = repair::manual_fixed(&self.p, &mut self.rng);
                self.reintegrate(server, true, fixed);
            }
        }
    }

    /// Return a repaired server to service (assumption 5: a successful
    /// repair turns a bad server good; a silent failure leaves it bad).
    fn reintegrate(&mut self, server: ServerId, manual: bool, fixed: bool) {
        {
            let s = &mut self.fleet[server as usize];
            if fixed && s.is_bad {
                s.is_bad = false;
            }
            s.renew();
        }
        self.tr(TraceKind::RepairDone { server, manual, fixed });

        let target = (self.p.job_size + self.p.warm_standbys) as usize;
        let assigned = self.fleet[server as usize]
            .assigned_job
            .map(|j| j as usize)
            .filter(|&j| {
                self.jobs[j].phase != JobPhase::Done && self.jobs[j].allotted() < target
            });
        match assigned {
            Some(j) => {
                // §II-B: returns to *its* job without host selection.
                self.fleet[server as usize].state = ServerState::JobStandby;
                self.jobs[j].standbys.push(server);
                if self.jobs[j].phase == JobPhase::Stalled {
                    self.attempt_start(j);
                }
            }
            None => {
                self.fleet[server as usize].assigned_job = None;
                self.pools.route_freed(&mut self.fleet, server);
                self.retry_stalled();
            }
        }
    }

    fn on_bad_regen(&mut self) {
        let converted = regen::regenerate(&self.p, &mut self.fleet, &mut self.rng);
        self.out.regenerated_bad += converted as u64;
        self.tr(TraceKind::Regenerated { converted });
        if converted > 0 {
            for j in 0..self.jobs.len() {
                // Conversions may touch active servers regardless of phase.
                self.recount_gang_bad(j);
                // Newly-bad computing servers get a systematic clock now.
                if self.jobs[j].phase != JobPhase::Running {
                    continue;
                }
                if self.gang_fast_path {
                    // Memoryless: re-draw the gang clock against the new
                    // composition (the old one is retired by the gen bump).
                    self.schedule_gang_clock(j);
                } else {
                    let now = self.engine.now();
                    for i in 0..self.jobs[j].active.len() {
                        let id = self.jobs[j].active[i];
                        let s = &self.fleet[id as usize];
                        if s.is_bad {
                            let age = s.run_age + (now - s.active_since);
                            let d = self
                                .p
                                .failure_dist
                                .with_rate(self.p.systematic_failure_rate);
                            let dt = d.sample_remaining(&mut self.rng, age);
                            self.engine.schedule_in(
                                dt,
                                Ev::Fail {
                                    server: id,
                                    gen: s.gen.0,
                                    kind: FailureKind::Systematic,
                                },
                            );
                        }
                    }
                }
            }
        }
        self.engine.schedule_in(self.p.bad_regen_interval, Ev::BadRegen);
    }

    // ---------------------------------------------------------------- //
    // Introspection (tests, property checks)
    // ---------------------------------------------------------------- //

    /// Server-conservation invariant: every server is in exactly one
    /// logical place and the counts add up to the fleet size.
    pub fn conservation_ok(&self) -> bool {
        let mut counts = [0usize; 9];
        for s in &self.fleet {
            let i = match s.state {
                ServerState::WorkingIdle => 0,
                ServerState::JobActive => 1,
                ServerState::JobStandby => 2,
                ServerState::SparePool => 3,
                ServerState::SpareTransit => 4,
                ServerState::AutoRepair => 5,
                ServerState::ManualRepair => 6,
                ServerState::RepairQueued => 7,
                ServerState::Retired => 8,
            };
            counts[i] += 1;
        }
        let total: usize = counts.iter().sum();
        let active: usize = self.jobs.iter().map(|j| j.active.len()).sum();
        let standby: usize = self.jobs.iter().map(|j| j.standbys.len()).sum();
        total == self.fleet.len()
            && counts[0] == self.pools.idle_count()
            && counts[3] == self.pools.spare_count()
            && counts[4] == self.pools.in_transit as usize
            && counts[1] == active
            && counts[2] == standby
            && counts[5] + counts[6] + counts[7] == self.shop.population()
    }

    /// Current simulation time (test hook).
    pub fn now(&self) -> Time {
        self.engine.now()
    }

    /// Immutable view of job 0 (test hook; single-job configurations).
    pub fn job(&self) -> &Job {
        &self.jobs[0]
    }

    /// Immutable view of all jobs (test hook).
    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// Immutable view of the fleet (test hook).
    pub fn fleet(&self) -> &[Server] {
        &self.fleet
    }

    /// Step the simulation by exactly one event (test hook). Returns false
    /// when no events remain.
    pub fn step(&mut self) -> bool {
        match self.engine.pop() {
            Some((_, ev)) => {
                self.dispatch(ev);
                true
            }
            None => false,
        }
    }

    /// Initialize scheduling as `run()` does, without consuming events
    /// (test hook for step-wise execution).
    pub fn prime(&mut self) {
        if self.p.bad_regen_interval > 0.0 {
            self.engine.schedule_in(self.p.bad_regen_interval, Ev::BadRegen);
        }
        self.out.per_job_makespans = vec![0.0; self.jobs.len()];
        for j in 0..self.jobs.len() {
            self.attempt_start(j);
        }
    }
}
