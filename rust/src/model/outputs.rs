//! Measured outputs of one simulation run (§III-B): total training time,
//! failure counts by kind, preemptions, repair counts, run durations —
//! plus the extended accounting the examples and benches report.

use crate::sim::Time;

/// Everything one run measures.
///
/// `PartialEq` supports the refactor-equivalence suite: two code paths
/// (fresh construction vs batched reuse, any thread count) must produce
/// **byte-identical** outputs for the same `(params, seed)`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunOutputs {
    /// Output 1: total time to train the job (wall-clock minutes).
    /// With `num_jobs > 1`: the time the *last* job finishes.
    pub makespan: Time,
    /// Per-job completion times (length = `num_jobs`; 0.0 if unfinished).
    pub per_job_makespans: Vec<Time>,
    /// Did every job finish before `max_sim_time`?
    pub completed: bool,

    /// Output 2: failures, total and by kind.
    pub failures_total: u64,
    pub failures_random: u64,
    pub failures_systematic: u64,

    /// Output 3: spare-pool preemptions.
    pub preemptions: u64,
    /// Preemption cost charged (minutes of other-job work, assumption 7).
    pub preemption_cost: f64,

    /// Output 4: repairs by stage.
    pub repairs_auto: u64,
    pub repairs_manual: u64,

    /// Output 5: mean time between interruptions while running.
    pub avg_run_duration: Time,

    // ---- extended accounting ----
    /// Host selections performed (standby-exhausted restarts).
    pub host_selections: u64,
    /// Failures absorbed by a warm-standby swap (no host selection).
    pub standby_swaps: u64,
    /// Total time the job sat stalled waiting for servers.
    pub stall_time: Time,
    /// Total time spent in checkpoint-restore recovery.
    pub recovery_total: Time,
    /// Servers permanently retired.
    pub retirements: u64,
    /// Failures where no server was identified (restart in place).
    pub undiagnosed: u64,
    /// Failures where the wrong server was blamed.
    pub wrong_diagnoses: u64,
    /// Servers that turned bad via regeneration ticks.
    pub regenerated_bad: u64,
    /// Useful work lost to checkpoint granularity (minutes; 0 under the
    /// paper's continuous asynchronous checkpointing).
    pub work_lost: Time,
    /// Checkpoints committed across all jobs (and, for `tiered`, tiers).
    pub checkpoints_committed: u64,
    /// Wall-clock spent writing checkpoints (gangs stalled mid-run;
    /// minutes; 0 when `checkpoint_cost` is 0).
    pub checkpoint_overhead: Time,
    /// Useful work completed and retained across all jobs at end of run
    /// (minutes; `num_jobs * job_len` when every job finished).
    pub work_done: Time,

    // ---- correlated domain outages (topology subsystem; all zero when
    // no `topology:` is configured) ----
    /// Domain-outage events delivered (rack/switch/... level clocks).
    pub domain_failures: u64,
    /// Up-servers taken down by domain outages, summed over events.
    pub domain_servers_lost: u64,
    /// Most up-servers lost to a single domain outage (blast radius).
    pub domain_max_blast: u64,
    /// Whole-job interruptions: domain outages a job could not absorb
    /// with warm standbys (forced back into host selection or a stall).
    pub domain_job_interruptions: u64,
    /// Job downtime attributable to correlated domain outages (minutes
    /// from each domain-caused stop until the job runs again).
    pub domain_downtime: Time,

    // ---- admission queue (workload subsystem; all zero when no
    // `workload:` is configured — legacy jobs are born admitted) ----
    /// Open-loop job arrivals delivered before the horizon.
    pub jobs_arrived: u64,
    /// Arrivals admitted (first successful allocation).
    pub jobs_admitted: u64,
    /// Total admission-queue wait (minutes), summed over admitted jobs;
    /// jobs still queued at the horizon contribute their censored wait,
    /// so this equals the time-integral of the queue depth.
    pub queue_wait_total: Time,
    /// Peak admission-queue depth.
    pub queue_depth_max: u64,
    /// Median admission wait of admitted jobs (P² streaming estimate;
    /// exact below 5 samples).
    pub queue_wait_p50: Time,
    /// 99th-percentile admission wait of admitted jobs (P² estimate).
    pub queue_wait_p99: Time,

    /// Events the engine delivered (perf accounting).
    pub events_delivered: u64,
    /// Events scheduled into the engine — the thinned failure model's
    /// whole point is to shrink this relative to `per_server` (includes
    /// lazily-cancelled clocks that were never delivered).
    pub events_scheduled: u64,
}

impl RunOutputs {
    /// Effective utilization: failure-free length / makespan.
    pub fn utilization(&self, job_len: Time) -> f64 {
        if self.makespan > 0.0 {
            job_len / self.makespan
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_basic() {
        let o = RunOutputs { makespan: 2000.0, ..Default::default() };
        assert!((o.utilization(1000.0) - 0.5).abs() < 1e-12);
        let z = RunOutputs::default();
        assert_eq!(z.utilization(1000.0), 0.0);
    }
}
