//! Pure-Rust analytical CTMC baseline — the same mathematics as the
//! JAX/Pallas artifact (`python/compile/model.py`), kept bit-comparable so
//! the PJRT runtime can be cross-validated against it, and usable as a
//! no-artifact fallback.
//!
//! See `model.py`'s module docstring for the state space, the serial
//! repair pipeline rates, and the output definitions; the two must stay in
//! lockstep (tests `tests/cross_layer.rs` enforce it numerically).

use crate::config::Params;

/// Number of CTMC states (7 live + 1 pad to match the artifact layout).
pub const STATES: usize = 8;
/// Squaring steps: horizon = delta * 2^M_STEPS (matches the kernel).
pub const M_STEPS: usize = 16;
/// Taylor terms for the base-step series.
pub const K_TERMS: usize = 24;

/// Parameter-vector column order — must equal `model.PARAM_NAMES`.
pub const PARAM_NAMES: [&str; 16] = [
    "lambda_r", "lambda_s", "frac_bad", "recovery_time",
    "job_size", "job_len", "warm_standbys", "p_auto",
    "p_auto_fail", "p_man_fail", "auto_time", "man_time",
    "host_selection_time", "waiting_time", "working_pool", "p_retire",
];

/// Output column order — must equal `model.OUTPUT_NAMES`.
pub const OUTPUT_NAMES: [&str; 8] = [
    "avail_T", "avail_avg", "frac_bad_T", "rbar",
    "exp_failures", "makespan_est", "overhead_frac", "pi_retired",
];

type Mat = [[f64; STATES]; STATES];
type Vecs = [f64; STATES];

/// Analytical metrics for one configuration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AnalyticOutputs {
    pub avail_t: f64,
    pub avail_avg: f64,
    pub frac_bad_t: f64,
    pub rbar: f64,
    pub exp_failures: f64,
    pub makespan_est: f64,
    pub overhead_frac: f64,
    pub pi_retired: f64,
}

impl AnalyticOutputs {
    pub fn to_array(self) -> [f64; 8] {
        [
            self.avail_t,
            self.avail_avg,
            self.frac_bad_t,
            self.rbar,
            self.exp_failures,
            self.makespan_est,
            self.overhead_frac,
            self.pi_retired,
        ]
    }

    pub fn from_array(a: &[f64]) -> Self {
        AnalyticOutputs {
            avail_t: a[0],
            avail_avg: a[1],
            frac_bad_t: a[2],
            rbar: a[3],
            exp_failures: a[4],
            makespan_est: a[5],
            overhead_frac: a[6],
            pi_retired: a[7],
        }
    }
}

/// Flatten [`Params`] into the artifact's 16-column parameter vector.
pub fn param_vector(p: &Params) -> [f64; 16] {
    [
        p.random_failure_rate,
        p.systematic_failure_rate,
        p.systematic_fraction,
        p.recovery_time,
        p.job_size as f64,
        p.job_len,
        p.warm_standbys as f64,
        p.auto_repair_prob,
        p.auto_repair_fail_prob,
        p.manual_repair_fail_prob,
        p.auto_repair_time,
        p.manual_repair_time,
        p.host_selection_time,
        p.waiting_time,
        p.working_pool as f64,
        0.0, // p_retire: the threshold policy has no direct CTMC rate
    ]
}

/// Build the generator matrix Q and the initial distribution pi0.
/// Mirrors `model.build_generator` (serial auto→manual pipeline).
pub fn build_generator(v: &[f64; 16]) -> (Mat, Vecs) {
    let lam_r = v[0];
    let lam_s = v[1];
    let frac_bad = v[2];
    let p_auto = v[7];
    let p_auto_fail = v[8];
    let p_man_fail = v[9];
    let mu_a = 1.0 / v[10].max(1e-6);
    let mu_m = 1.0 / v[11].max(1e-6);
    let p_retire = v[15];
    let lam_bad = lam_r + lam_s;

    let mut q: Mat = [[0.0; STATES]; STATES];
    q[0][2] = lam_r;
    q[1][3] = lam_bad;
    q[2][0] = mu_a * p_auto;
    q[2][4] = mu_a * (1.0 - p_auto);
    q[3][0] = mu_a * p_auto * (1.0 - p_auto_fail);
    q[3][1] = mu_a * p_auto * p_auto_fail;
    q[3][5] = mu_a * (1.0 - p_auto);
    q[4][0] = mu_m;
    q[5][0] = mu_m * (1.0 - p_man_fail);
    q[5][1] = mu_m * p_man_fail * (1.0 - p_retire);
    q[5][6] = mu_m * p_man_fail * p_retire;
    for i in 0..STATES {
        let row_sum: f64 = q[i].iter().sum();
        q[i][i] -= row_sum;
    }

    let mut pi0: Vecs = [0.0; STATES];
    pi0[0] = 1.0 - frac_bad;
    pi0[1] = frac_bad;
    (q, pi0)
}

fn mat_mul(a: &Mat, b: &Mat) -> Mat {
    let mut c: Mat = [[0.0; STATES]; STATES];
    for i in 0..STATES {
        for k in 0..STATES {
            let aik = a[i][k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..STATES {
                c[i][j] += aik * b[k][j];
            }
        }
    }
    c
}

fn vec_mat(v: &Vecs, m: &Mat) -> Vecs {
    let mut out: Vecs = [0.0; STATES];
    for i in 0..STATES {
        let vi = v[i];
        if vi == 0.0 {
            continue;
        }
        for j in 0..STATES {
            out[j] += vi * m[i][j];
        }
    }
    out
}

/// expm(Q * delta) via the uniformized Taylor series (mirrors
/// `model._expm_uniformized`).
pub fn expm_uniformized(q: &Mat, delta: f64) -> Mat {
    let q_unif = (0..STATES)
        .map(|i| -q[i][i])
        .fold(0.0f64, f64::max)
        * 1.01
        + 1e-12;
    let mut p: Mat = [[0.0; STATES]; STATES];
    for i in 0..STATES {
        for j in 0..STATES {
            p[i][j] = q[i][j] / q_unif + if i == j { 1.0 } else { 0.0 };
        }
    }
    let qt = q_unif * delta;
    let mut a: Mat = [[0.0; STATES]; STATES];
    let mut pk: Mat = [[0.0; STATES]; STATES];
    for (i, row) in pk.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    let mut w = (-qt).exp();
    for k in 0..K_TERMS {
        for i in 0..STATES {
            for j in 0..STATES {
                a[i][j] += w * pk[i][j];
            }
        }
        pk = mat_mul(&pk, &p);
        w *= qt / (k as f64 + 1.0);
    }
    for i in 0..STATES {
        for j in 0..STATES {
            a[i][j] += w * pk[i][j];
        }
    }
    a
}

/// Dyadic transient captures: `caps[i] = pi0 * A^(2^i)` for i = 0..=m
/// (the Pallas kernel's squaring chain, scalar form).
pub fn dyadic_transients(a0: &Mat, pi0: &Vecs, m_steps: usize) -> Vec<Vecs> {
    let mut a = *a0;
    let mut caps = Vec::with_capacity(m_steps + 1);
    for _ in 0..m_steps {
        caps.push(vec_mat(pi0, &a));
        a = mat_mul(&a, &a);
    }
    caps.push(vec_mat(pi0, &a));
    caps
}

/// Standard-normal survival function (matches `jax.scipy.stats.norm.sf`).
fn norm_sf(z: f64) -> f64 {
    1.0 - crate::sim::dist::normal_cdf(z)
}

/// The full analytical estimator for one parameter vector — the scalar
/// mirror of `model.analytic_metrics`.
pub fn analytic_metrics(v: &[f64; 16]) -> AnalyticOutputs {
    let lam_r = v[0];
    let lam_s = v[1];
    let recovery = v[3];
    let job_size = v[4];
    let job_len = v[5];
    let warm = v[6];
    let host_sel = v[12];
    let waiting = v[13];
    let working_pool = v[14];

    let (q, pi0) = build_generator(v);
    let horizon = job_len.max(1.0);
    let delta = horizon / (1u64 << M_STEPS) as f64;
    let a0 = expm_uniformized(&q, delta);
    let caps = dyadic_transients(&a0, &pi0, M_STEPS);

    let pi_t = caps[M_STEPS];
    let avail_t = pi_t[0] + pi_t[1];
    let frac_bad_t = pi_t[1] / avail_t.max(1e-9);
    let pi_retired = pi_t[6];

    // Trapezoid time-average over the dyadic grid {0, d, 2d, 4d, ...}.
    let mut times = vec![0.0f64];
    for i in 0..=M_STEPS {
        times.push((1u64 << i) as f64);
    }
    let mut traj: Vec<Vecs> = vec![pi0];
    traj.extend(caps.iter().copied());
    let mut pi_avg: Vecs = [0.0; STATES];
    for k in 0..=M_STEPS {
        let w = times[k + 1] - times[k];
        for s in 0..STATES {
            pi_avg[s] += w * 0.5 * (traj[k][s] + traj[k + 1][s]);
        }
    }
    let norm = (1u64 << M_STEPS) as f64;
    for s in pi_avg.iter_mut() {
        *s /= norm;
    }

    let avail_avg = pi_avg[0] + pi_avg[1];
    let rbar = pi_avg[0] * lam_r + pi_avg[1] * (lam_r + lam_s);

    let big_r = job_size * rbar;
    let unavail_frac = 1.0 - avail_avg;
    let u = working_pool * unavail_frac;
    let slack_ws = warm.max(1.0);
    let slack_wp = (working_pool - job_size).max(1.0);
    let p_hs = norm_sf((slack_ws - u) / u.max(1e-6).sqrt());
    let p_wait = norm_sf((slack_wp - u) / u.max(1e-6).sqrt());
    let cost = recovery + p_hs * host_sel + p_wait * waiting;

    // Failures only accrue while the job computes (assumption 7), and the
    // job computes for exactly L minutes in total, so E[failures] = R*L
    // and the makespan is L plus the per-failure costs: M = L * (1 + R*C).
    let overhead = big_r * cost;
    let makespan = job_len * (1.0 + overhead);
    let exp_failures = big_r * job_len;

    AnalyticOutputs {
        avail_t,
        avail_avg,
        frac_bad_t,
        rbar,
        exp_failures,
        makespan_est: makespan,
        overhead_frac: overhead,
        pi_retired,
    }
}

/// Convenience: analytical metrics straight from [`Params`].
pub fn analyze(p: &Params) -> AnalyticOutputs {
    analytic_metrics(&param_vector(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_rows_sum_to_zero() {
        let p = Params::table1_defaults();
        let (q, pi0) = build_generator(&param_vector(&p));
        for row in &q {
            let s: f64 = row.iter().sum();
            assert!(s.abs() < 1e-12);
        }
        assert!((pi0.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((pi0[1] - 0.15).abs() < 1e-12);
    }

    #[test]
    fn expm_zero_delta_is_identity() {
        let p = Params::table1_defaults();
        let (q, _) = build_generator(&param_vector(&p));
        let a = expm_uniformized(&q, 0.0);
        for i in 0..STATES {
            for j in 0..STATES {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((a[i][j] - want).abs() < 1e-9, "a[{i}][{j}]={}", a[i][j]);
            }
        }
    }

    #[test]
    fn expm_rows_are_stochastic() {
        let p = Params::table1_defaults();
        let (q, _) = build_generator(&param_vector(&p));
        let a = expm_uniformized(&q, 37.0);
        for i in 0..7 {
            let s: f64 = a[i].iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "row {i} sums to {s}");
            for &x in &a[i] {
                assert!(x >= -1e-12);
            }
        }
    }

    #[test]
    fn transients_preserve_mass() {
        let p = Params::table1_defaults();
        let v = param_vector(&p);
        let (q, pi0) = build_generator(&v);
        let a0 = expm_uniformized(&q, p.job_len / (1u64 << M_STEPS) as f64);
        for cap in dyadic_transients(&a0, &pi0, M_STEPS) {
            let s: f64 = cap.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "mass {s}");
        }
    }

    #[test]
    fn zero_failure_rate_is_failure_free() {
        let mut p = Params::table1_defaults();
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        let o = analyze(&p);
        assert!((o.avail_t - 1.0).abs() < 1e-9);
        assert!(o.exp_failures.abs() < 1e-6);
        assert!((o.makespan_est - p.job_len).abs() / p.job_len < 1e-9);
    }

    #[test]
    fn makespan_grows_with_recovery_time() {
        let mut m = Vec::new();
        for rec in [10.0, 20.0, 30.0] {
            let mut p = Params::table1_defaults();
            p.recovery_time = rec;
            m.push(analyze(&p).makespan_est);
        }
        assert!(m[0] < m[1] && m[1] < m[2], "{m:?}");
    }

    #[test]
    fn defaults_give_sane_availability() {
        let o = analyze(&Params::table1_defaults());
        assert!(o.avail_avg > 0.9 && o.avail_avg < 1.0, "{o:?}");
        assert!(o.rbar > 0.0 && o.rbar < 1e-3);
        assert!(o.makespan_est > Params::table1_defaults().job_len);
    }
}
