//! The discrete-event engine: a pending-event set with a monotone clock.
//!
//! Generic over the event payload so the model layer owns its vocabulary.
//! The pending set is a calendar queue by default ([`CalendarQueue`],
//! amortized O(1) per operation) with the original binary heap available
//! behind [`QueueKind::Heap`] / the `heap-queue` cargo feature for A/B
//! benchmarking; both deliver the exact `(at, seq)` earliest-first FIFO
//! order, so the choice is invisible to every oracle and golden file.
//! Cancellation is lazy (generation counters at the model layer), which
//! profiles far better than tombstone removal for this workload — failure
//! clocks are invalidated in bulk at every job interruption.

use crate::sim::calendar::CalendarQueue;
use crate::sim::event::Scheduled;
use crate::sim::Time;
use std::collections::BinaryHeap;

/// Which pending-event structure the engine runs on. Both orders are
/// bit-identical; the calendar is faster at scale, the heap is the
/// reference implementation kept for A/B runs (`benches/engine.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    Calendar,
    Heap,
}

impl Default for QueueKind {
    fn default() -> Self {
        if cfg!(feature = "heap-queue") {
            QueueKind::Heap
        } else {
            QueueKind::Calendar
        }
    }
}

#[derive(Debug)]
enum Queue<E> {
    Calendar(CalendarQueue<E>),
    Heap(BinaryHeap<Scheduled<E>>),
}

/// Event queue + simulation clock.
#[derive(Debug)]
pub struct Engine<E> {
    queue: Queue<E>,
    now: Time,
    seq: u64,
    delivered: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Self::with_queue(QueueKind::default(), 0)
    }

    /// Pre-size the queue (perf: avoids rehoming during the warm-up burst
    /// when every server schedules its first failure clock).
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_queue(QueueKind::default(), cap)
    }

    /// Build on an explicit queue implementation (A/B benchmarking and
    /// the cross-queue equivalence tests).
    pub fn with_queue(kind: QueueKind, cap: usize) -> Self {
        let queue = match kind {
            QueueKind::Calendar => Queue::Calendar(CalendarQueue::with_capacity(cap)),
            QueueKind::Heap => Queue::Heap(BinaryHeap::with_capacity(cap)),
        };
        Engine { queue, now: 0.0, seq: 0, delivered: 0 }
    }

    /// Which queue implementation this engine runs on.
    pub fn queue_kind(&self) -> QueueKind {
        match self.queue {
            Queue::Calendar(_) => QueueKind::Calendar,
            Queue::Heap(_) => QueueKind::Heap,
        }
    }

    /// Clear all state for a new run, retaining (and growing to at least
    /// `capacity`) the queue allocation — the batched replication runner
    /// resets engines instead of rebuilding them. The queue kind (and the
    /// calendar's learned bucket shape) carries over.
    pub fn reset(&mut self, capacity: usize) {
        match &mut self.queue {
            Queue::Calendar(c) => c.reset(),
            Queue::Heap(h) => {
                h.clear();
                if h.capacity() < capacity {
                    h.reserve(capacity);
                }
            }
        }
        self.now = 0.0;
        self.seq = 0;
        self.delivered = 0;
    }

    /// Current simulation time (minutes).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Events delivered so far (throughput metric for the perf harness).
    #[inline]
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Events scheduled so far — the other half of the perf ledger: the
    /// thinned failure model's whole point is to shrink this number.
    #[inline]
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// Pending events (including lazily-cancelled ones).
    #[inline]
    pub fn pending(&self) -> usize {
        match &self.queue {
            Queue::Calendar(c) => c.len(),
            Queue::Heap(h) => h.len(),
        }
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: Time, payload: E) {
        debug_assert!(
            at >= self.now,
            "scheduling into the past: at={at} now={}",
            self.now
        );
        debug_assert!(!at.is_nan(), "scheduling at NaN");
        let seq = self.seq;
        self.seq += 1;
        let ev = Scheduled { at, seq, payload };
        match &mut self.queue {
            Queue::Calendar(c) => c.push(ev),
            Queue::Heap(h) => h.push(ev),
        }
    }

    /// Schedule `payload` after a delay from now. Infinite delays are
    /// silently dropped (an Exponential with rate 0 "never fires").
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        if delay.is_finite() {
            self.schedule_at(self.now + delay, payload);
        }
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// simulation has run out of events.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let ev = match &mut self.queue {
            Queue::Calendar(c) => c.pop()?,
            Queue::Heap(h) => h.pop()?,
        };
        debug_assert!(ev.at >= self.now, "clock went backwards");
        self.now = ev.at;
        self.delivered += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek at the next event time without advancing. (`&mut`: the
    /// calendar may advance its cursor and lazily sort a bucket.)
    pub fn peek_time(&mut self) -> Option<Time> {
        match &mut self.queue {
            Queue::Calendar(c) => c.peek_time(),
            Queue::Heap(h) => h.peek().map(|e| e.at),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both_kinds() -> [QueueKind; 2] {
        [QueueKind::Calendar, QueueKind::Heap]
    }

    #[test]
    fn delivers_in_time_order() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_queue(kind, 0);
            e.schedule_at(5.0, 5);
            e.schedule_at(1.0, 1);
            e.schedule_at(3.0, 3);
            let order: Vec<u32> =
                std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, vec![1, 3, 5], "{kind:?}");
        }
    }

    #[test]
    fn fifo_on_simultaneous_events() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_queue(kind, 0);
            for i in 0..100 {
                e.schedule_at(7.0, i);
            }
            let order: Vec<u32> =
                std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, (0..100).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn clock_is_monotone() {
        for kind in both_kinds() {
            let mut e: Engine<()> = Engine::with_queue(kind, 0);
            let mut rng = crate::sim::rng::Rng::new(1);
            for _ in 0..1000 {
                e.schedule_at(rng.next_f64() * 100.0, ());
            }
            let mut last = 0.0;
            while let Some((t, _)) = e.pop() {
                assert!(t >= last, "{kind:?}");
                last = t;
            }
            assert_eq!(e.delivered(), 1000);
            assert_eq!(e.scheduled(), 1000);
        }
    }

    #[test]
    fn schedule_in_relative_to_now() {
        let mut e: Engine<&'static str> = Engine::new();
        e.schedule_in(10.0, "a");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 10.0);
        e.schedule_in(5.0, "b");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn infinite_delay_is_dropped() {
        let mut e: Engine<()> = Engine::new();
        e.schedule_in(f64::INFINITY, ());
        assert_eq!(e.pending(), 0);
        assert_eq!(e.scheduled(), 0);
        assert!(e.pop().is_none());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_queue(kind, 0);
            e.schedule_at(1.0, 1);
            e.schedule_at(10.0, 10);
            assert_eq!(e.pop().unwrap().1, 1);
            // Schedule between the popped time and the remaining event.
            e.schedule_at(5.0, 5);
            assert_eq!(e.pop().unwrap().1, 5);
            assert_eq!(e.pop().unwrap().1, 10, "{kind:?}");
        }
    }

    #[test]
    fn reset_preserves_queue_kind() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_queue(kind, 8);
            e.schedule_at(1.0, 1);
            e.reset(16);
            assert_eq!(e.queue_kind(), kind);
            assert_eq!(e.pending(), 0);
            assert_eq!((e.now(), e.scheduled(), e.delivered()), (0.0, 0, 0));
        }
    }

    #[test]
    fn default_kind_tracks_feature() {
        let expect = if cfg!(feature = "heap-queue") {
            QueueKind::Heap
        } else {
            QueueKind::Calendar
        };
        let e: Engine<()> = Engine::new();
        assert_eq!(e.queue_kind(), expect);
    }
}
