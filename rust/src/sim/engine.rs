//! The discrete-event engine: a pending-event set with a monotone clock.
//!
//! Generic over the event payload so the model layer owns its vocabulary.
//! The queue is a binary heap with stable FIFO tie-breaking ([`Scheduled`]);
//! cancellation is lazy (generation counters at the model layer), which
//! profiles far better than tombstone removal for this workload — failure
//! clocks are invalidated in bulk at every job interruption.

use crate::sim::event::Scheduled;
use crate::sim::Time;
use std::collections::BinaryHeap;

/// Event queue + simulation clock.
#[derive(Debug)]
pub struct Engine<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: Time,
    seq: u64,
    delivered: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine { heap: BinaryHeap::new(), now: 0.0, seq: 0, delivered: 0 }
    }

    /// Pre-size the heap (perf: avoids rehoming during the warm-up burst
    /// when every server schedules its first failure clock).
    pub fn with_capacity(cap: usize) -> Self {
        Engine {
            heap: BinaryHeap::with_capacity(cap),
            now: 0.0,
            seq: 0,
            delivered: 0,
        }
    }

    /// Clear all state for a new run, retaining (and growing to at least
    /// `capacity`) the heap allocation — the batched replication runner
    /// resets engines instead of rebuilding them.
    pub fn reset(&mut self, capacity: usize) {
        self.heap.clear();
        if self.heap.capacity() < capacity {
            self.heap.reserve(capacity);
        }
        self.now = 0.0;
        self.seq = 0;
        self.delivered = 0;
    }

    /// Current simulation time (minutes).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Events delivered so far (throughput metric for the perf harness).
    #[inline]
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Pending events (including lazily-cancelled ones).
    #[inline]
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: Time, payload: E) {
        debug_assert!(
            at >= self.now,
            "scheduling into the past: at={at} now={}",
            self.now
        );
        debug_assert!(!at.is_nan(), "scheduling at NaN");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
    }

    /// Schedule `payload` after a delay from now. Infinite delays are
    /// silently dropped (an Exponential with rate 0 "never fires").
    pub fn schedule_in(&mut self, delay: Time, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        if delay.is_finite() {
            self.schedule_at(self.now + delay, payload);
        }
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// simulation has run out of events.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "clock went backwards");
        self.now = ev.at;
        self.delivered += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(5.0, 5);
        e.schedule_at(1.0, 1);
        e.schedule_at(3.0, 3);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 3, 5]);
    }

    #[test]
    fn fifo_on_simultaneous_events() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule_at(7.0, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_is_monotone() {
        let mut e: Engine<()> = Engine::new();
        let mut rng = crate::sim::rng::Rng::new(1);
        for _ in 0..1000 {
            e.schedule_at(rng.next_f64() * 100.0, ());
        }
        let mut last = 0.0;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(e.delivered(), 1000);
    }

    #[test]
    fn schedule_in_relative_to_now() {
        let mut e: Engine<&'static str> = Engine::new();
        e.schedule_in(10.0, "a");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 10.0);
        e.schedule_in(5.0, "b");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn infinite_delay_is_dropped() {
        let mut e: Engine<()> = Engine::new();
        e.schedule_in(f64::INFINITY, ());
        assert_eq!(e.pending(), 0);
        assert!(e.pop().is_none());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(1.0, 1);
        e.schedule_at(10.0, 10);
        assert_eq!(e.pop().unwrap().1, 1);
        // Schedule between the popped time and the remaining event.
        e.schedule_at(5.0, 5);
        assert_eq!(e.pop().unwrap().1, 5);
        assert_eq!(e.pop().unwrap().1, 10);
    }
}
