//! Calendar (bucket) event queue: amortized O(1) schedule/pop.
//!
//! A ring of time buckets of fixed `width`; an event at time `t` lives in
//! virtual bucket `⌊t/width⌋`, mapped onto the ring modulo the bucket
//! count. The cursor walks virtual buckets in order; within a bucket,
//! events are lazily sorted with the *same* comparator the binary heap
//! uses ([`Scheduled`]'s reversed `(at, seq)` order), so delivery — time
//! order with FIFO ties — is bit-identical to the heap's. The property
//! suite in `tests/queue_equivalence.rs` pins exactly that.
//!
//! Events beyond one ring revolution from the cursor ("far-future
//! outliers": domain-outage clocks, horizon sentinels) go to an overflow
//! list guarded by a min-virtual-bucket watermark; they migrate into the
//! ring the moment the cursor reaches the watermark, which is checked on
//! every cursor step — exact, with no boundary-crossing bookkeeping.
//!
//! The structure resizes itself from the live event population (grow at
//! 2 events/bucket, shrink at 1/8 — a 16× hysteresis band so alternating
//! schedule/pop bursts don't thrash) and re-derives `width` from the
//! observed schedule-horizon span on each rebuild. `reset()` keeps both
//! learned parameters, so batched replication runners start the next run
//! pre-adapted.

use crate::sim::event::Scheduled;
use crate::sim::Time;

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;

/// Bucketed pending-event set with heap-identical delivery order.
#[derive(Debug)]
pub struct CalendarQueue<E> {
    /// Ring of buckets; each holds events whose virtual bucket maps here.
    buckets: Vec<Vec<Scheduled<E>>>,
    /// Lazy-sort flags: a bucket is re-sorted only when next examined.
    sorted: Vec<bool>,
    /// Events more than one revolution ahead of the cursor.
    overflow: Vec<Scheduled<E>>,
    /// Min virtual bucket over `overflow` (u64::MAX when empty): the
    /// migration watermark.
    overflow_min_v: u64,
    /// Bucket width in simulated minutes (re-learned on rebuilds).
    width: f64,
    /// Cursor: the virtual bucket currently being drained.
    cur_v: u64,
    len: usize,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::with_capacity(0)
    }
}

impl<E> CalendarQueue<E> {
    pub fn with_capacity(cap: usize) -> Self {
        let nb = cap.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        CalendarQueue {
            buckets: (0..nb).map(|_| Vec::new()).collect(),
            sorted: vec![true; nb],
            overflow: Vec::new(),
            overflow_min_v: u64::MAX,
            width: 1.0,
            cur_v: 0,
            len: 0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Clear all events, keeping allocations AND the learned bucket
    /// count/width (the next replication has the same horizon scale).
    pub fn reset(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.sorted.iter_mut().for_each(|s| *s = true);
        self.overflow.clear();
        self.overflow_min_v = u64::MAX;
        self.cur_v = 0;
        self.len = 0;
    }

    /// Virtual bucket of an event time, saturating so cursor arithmetic
    /// (`cur_v + nb`) can never overflow.
    #[inline]
    fn vbucket(&self, at: Time) -> u64 {
        let v = (at / self.width).floor();
        if v >= (u64::MAX - 1) as f64 {
            u64::MAX - 1
        } else {
            v as u64
        }
    }

    pub fn push(&mut self, ev: Scheduled<E>) {
        let v = self.vbucket(ev.at);
        if self.len == 0 {
            // Empty queue: teleport the cursor instead of scanning to it.
            self.cur_v = v;
        } else if v < self.cur_v {
            // The cursor over-scanned past this time while peeking empty
            // buckets; pull it back so delivery order stays exact.
            self.cur_v = v;
        }
        self.place(ev, v);
        self.len += 1;
        let nb = self.buckets.len();
        if self.len > 2 * nb && nb < MAX_BUCKETS {
            self.rebuild(nb * 2);
        }
    }

    /// Put an event into its bucket or the overflow list. Does not touch
    /// `len` or trigger resizing (shared by `push` and `rebuild`).
    fn place(&mut self, ev: Scheduled<E>, v: u64) {
        let nb = self.buckets.len() as u64;
        if v >= self.cur_v.saturating_add(nb) {
            self.overflow_min_v = self.overflow_min_v.min(v);
            self.overflow.push(ev);
        } else {
            let idx = (v % nb) as usize;
            self.buckets[idx].push(ev);
            self.sorted[idx] = false;
        }
    }

    /// Pop the earliest event (FIFO among ties), identical to the heap.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let idx = self.locate()?;
        let ev = self.buckets[idx].pop().expect("located bucket is non-empty");
        self.len -= 1;
        let nb = self.buckets.len();
        if self.len > 0 && self.len < nb / 8 && nb > MIN_BUCKETS {
            let want = (2 * self.len).next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
            if want < nb {
                self.rebuild(want);
            }
        }
        Some(ev)
    }

    /// Time of the earliest event without removing it.
    pub fn peek_time(&mut self) -> Option<Time> {
        let idx = self.locate()?;
        self.buckets[idx].last().map(|e| e.at)
    }

    /// Advance the cursor to the bucket whose sorted back is the global
    /// minimum, returning its physical index. Migrates overflow events as
    /// the cursor reaches the watermark.
    fn locate(&mut self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        let nb = self.buckets.len() as u64;
        let mut scanned: u64 = 0;
        loop {
            if self.overflow_min_v <= self.cur_v {
                self.migrate_overflow();
            }
            let idx = (self.cur_v % nb) as usize;
            if !self.buckets[idx].is_empty() {
                if !self.sorted[idx] {
                    // Ascending under Scheduled's reversed Ord puts the
                    // earliest (at, then lowest seq) at the back: O(1)
                    // pop with exactly the heap's tie-breaking. (at, seq)
                    // pairs are unique, so unstable sort is deterministic.
                    self.buckets[idx].sort_unstable();
                    self.sorted[idx] = true;
                }
                let back_at = self.buckets[idx].last().expect("non-empty").at;
                if self.vbucket(back_at) == self.cur_v {
                    return Some(idx);
                }
                // Only wrap-around (future-revolution) events here.
            }
            self.cur_v += 1;
            scanned += 1;
            if scanned >= nb {
                // Sparse region: one O(n) scan beats revolving the ring.
                self.jump_to_min();
                scanned = 0;
            }
        }
    }

    /// Move every overflow event now within one revolution of the cursor
    /// into its bucket, and recompute the watermark.
    fn migrate_overflow(&mut self) {
        let nb = self.buckets.len() as u64;
        let limit = self.cur_v.saturating_add(nb);
        let mut new_min = u64::MAX;
        let mut i = 0;
        while i < self.overflow.len() {
            let v = self.vbucket(self.overflow[i].at);
            if v < limit {
                let ev = self.overflow.swap_remove(i);
                let idx = (v % nb) as usize;
                self.buckets[idx].push(ev);
                self.sorted[idx] = false;
            } else {
                new_min = new_min.min(v);
                i += 1;
            }
        }
        self.overflow_min_v = new_min;
    }

    /// Set the cursor directly onto the earliest event's virtual bucket.
    fn jump_to_min(&mut self) {
        let mut min_at = f64::INFINITY;
        for b in &self.buckets {
            for e in b {
                if e.at < min_at {
                    min_at = e.at;
                }
            }
        }
        for e in &self.overflow {
            if e.at < min_at {
                min_at = e.at;
            }
        }
        if min_at.is_finite() {
            self.cur_v = self.vbucket(min_at);
        }
    }

    /// Re-partition everything into `new_nb` buckets, re-deriving the
    /// bucket width from the live events' time span.
    fn rebuild(&mut self, new_nb: usize) {
        let mut all: Vec<Scheduled<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.overflow);
        self.overflow_min_v = u64::MAX;

        let mut min_at = f64::INFINITY;
        let mut max_at = f64::NEG_INFINITY;
        for e in &all {
            min_at = min_at.min(e.at);
            max_at = max_at.max(e.at);
        }
        let span = max_at - min_at;
        if span.is_finite() && span > 0.0 {
            // Aim for the live population to span ~half a revolution.
            self.width = (2.0 * span / new_nb as f64).max(1e-9);
        }

        self.buckets.resize_with(new_nb, Vec::new);
        self.sorted.clear();
        self.sorted.resize(new_nb, true);
        if min_at.is_finite() {
            self.cur_v = self.vbucket(min_at);
        } else {
            self.cur_v = 0;
        }
        for ev in all {
            let v = self.vbucket(ev.at);
            self.place(ev, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;
    use std::collections::BinaryHeap;

    fn ev(at: Time, seq: u64) -> Scheduled<u64> {
        Scheduled { at, seq, payload: seq }
    }

    /// Drive the calendar and a BinaryHeap with identical operations and
    /// assert element-wise identical pops.
    fn against_heap(ops: impl Iterator<Item = Option<(Time, u64)>>) {
        let mut cal: CalendarQueue<u64> = CalendarQueue::with_capacity(4);
        let mut heap: BinaryHeap<Scheduled<u64>> = BinaryHeap::new();
        for op in ops {
            match op {
                Some((at, seq)) => {
                    cal.push(ev(at, seq));
                    heap.push(ev(at, seq));
                }
                None => {
                    let a = cal.pop().map(|e| (e.at, e.seq, e.payload));
                    let b = heap.pop().map(|e| (e.at, e.seq, e.payload));
                    assert_eq!(a, b, "calendar diverged from heap");
                }
            }
            assert_eq!(cal.len(), heap.len());
        }
        loop {
            let a = cal.pop().map(|e| (e.at, e.seq, e.payload));
            let b = heap.pop().map(|e| (e.at, e.seq, e.payload));
            assert_eq!(a, b, "drain diverged");
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn matches_heap_on_random_interleavings() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let mut seq = 0u64;
            let mut last_pop = 0.0f64;
            let ops: Vec<Option<(Time, u64)>> = (0..600)
                .map(|_| {
                    if rng.next_f64() < 0.6 {
                        let at = last_pop + rng.next_f64() * 500.0;
                        seq += 1;
                        Some((at, seq))
                    } else {
                        last_pop += rng.next_f64() * 5.0;
                        None
                    }
                })
                .collect();
            against_heap(ops.into_iter());
        }
    }

    #[test]
    fn fifo_ties_match_heap() {
        let ops: Vec<Option<(Time, u64)>> = (0..64)
            .map(|i| Some((7.0, i)))
            .chain((0..64).map(|_| None))
            .collect();
        against_heap(ops.into_iter());
    }

    #[test]
    fn far_future_outliers_go_through_overflow_and_back() {
        let mut cal: CalendarQueue<u64> = CalendarQueue::with_capacity(4);
        cal.push(ev(1.0, 0));
        cal.push(ev(1.0e9, 1)); // way past one revolution: overflow
        cal.push(ev(2.0, 2));
        assert!(!cal.overflow.is_empty(), "outlier should land in overflow");
        assert_eq!(cal.pop().unwrap().seq, 0);
        assert_eq!(cal.pop().unwrap().seq, 2);
        assert_eq!(cal.pop().unwrap().seq, 1);
        assert!(cal.pop().is_none());
    }

    #[test]
    fn grow_and_shrink_preserve_order() {
        let mut cal: CalendarQueue<u64> = CalendarQueue::with_capacity(4);
        let mut rng = Rng::new(9);
        let mut evs: Vec<(Time, u64)> =
            (0..5000).map(|i| (rng.next_f64() * 1e6, i)).collect();
        for &(at, seq) in &evs {
            cal.push(ev(at, seq));
        }
        // Sort ascending by (at, seq) — the delivery order.
        evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        for &(at, seq) in &evs {
            let got = cal.pop().unwrap();
            assert_eq!((got.at, got.seq), (at, seq));
        }
        assert!(cal.is_empty());
    }

    #[test]
    fn reset_keeps_learned_shape_and_empties() {
        let mut cal: CalendarQueue<u64> = CalendarQueue::with_capacity(4);
        for i in 0..1000 {
            cal.push(ev(i as f64 * 3.0, i));
        }
        let nb = cal.buckets.len();
        let width = cal.width;
        cal.reset();
        assert!(cal.is_empty());
        assert_eq!(cal.buckets.len(), nb);
        assert_eq!(cal.width, width);
        cal.push(ev(5.0, 0));
        assert_eq!(cal.pop().unwrap().seq, 0);
    }

    #[test]
    fn identical_times_identical_seqs_unique() {
        // Ties broken strictly by seq even across resize boundaries.
        let mut cal: CalendarQueue<u64> = CalendarQueue::with_capacity(4);
        for i in 0..200 {
            cal.push(ev(if i % 2 == 0 { 10.0 } else { 20.0 }, i));
        }
        let mut prev = (0.0, 0);
        let mut first = true;
        while let Some(e) = cal.pop() {
            if !first {
                assert!(
                    e.at > prev.0 || (e.at == prev.0 && e.seq > prev.1),
                    "order violated: {:?} after {prev:?}",
                    (e.at, e.seq)
                );
            }
            prev = (e.at, e.seq);
            first = false;
        }
    }
}
