//! Deterministic, splittable pseudo-random number generation.
//!
//! The offline environment carries no `rand` crate; more importantly the
//! simulator wants *stream-per-(experiment, point, replication)* semantics
//! so that changing one sweep axis never perturbs another axis' draws.
//! We use SplitMix64 to expand seeds and xoshiro256++ as the bulk
//! generator — both public-domain algorithms with well-studied statistical
//! quality.

/// SplitMix64 step: the standard 64-bit finalizer-based generator.
/// Used for seed expansion and key derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — the crate's bulk PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream from a key path, e.g.
    /// `Rng::derived(master, &[experiment_id, point_id, replication])`.
    /// Each key is mixed through SplitMix64 so nearby paths decorrelate.
    pub fn derived(seed: u64, path: &[u64]) -> Self {
        let mut sm = seed ^ 0xA076_1D64_78BD_642F;
        for &k in path {
            sm ^= splitmix64(&mut { k ^ sm });
            sm = sm.wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(17);
        }
        Rng::new(sm)
    }

    /// Snapshot the full generator state. Together with [`Rng::set_state`]
    /// this lets a cache key on "the stream position a deterministic
    /// consumer started from" and replay the consumer's draws by restoring
    /// the position it ended at (the serve layer's warm fleet cache).
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Restore a state captured by [`Rng::state`]. The caller must only
    /// feed back states that came from `state()` — xoshiro256++ has one
    /// forbidden all-zero state, which no reachable stream position is.
    #[inline]
    pub fn set_state(&mut self, s: [u64; 4]) {
        debug_assert!(s != [0; 4], "all-zero is not a reachable xoshiro state");
        self.s = s;
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in the **open** interval `(0, 1)` — safe for `ln()`.
    #[inline]
    pub fn next_open_f64(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in `[0, n)` via Lemire's nearly-divisionless method.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (the simulator draws normals rarely —
    /// only for LogNormal durations — so no ziggurat needed).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_open_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle (used by host-selection policies).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derived_streams_are_independent() {
        let mut a = Rng::derived(42, &[1, 0, 0]);
        let mut b = Rng::derived(42, &[1, 0, 1]);
        let mut c = Rng::derived(42, &[1, 1, 0]);
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..64).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
        assert_ne!(xs, zs);
        assert_ne!(ys, zs);
    }

    #[test]
    fn state_roundtrip_replays_the_stream() {
        let mut a = Rng::new(11);
        let snap = a.state();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let after = a.state();
        let mut b = Rng::new(999);
        b.set_state(snap);
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_eq!(b.state(), after);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
