//! Duration distributions for failure inter-arrivals and repair times.
//!
//! The paper assumes Exponential arrivals (assumption 2) but explicitly
//! supports LogNormal and Weibull and "user-specified distributions"; all
//! four are provided here, plus Deterministic (useful in tests) and
//! Empirical (resampling from a trace).
//!
//! Non-exponential failure clocks need *age-conditional* sampling: when a
//! job is interrupted and later resumed, the server's remaining lifetime
//! must be drawn conditional on having survived its accumulated run age —
//! [`Dist::sample_remaining`] implements the conditional inverse-CDF for
//! each family (for Exponential it degenerates to memoryless resampling).

use crate::sim::rng::Rng;
use crate::sim::Time;

/// A positive-duration distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    /// `rate` events per minute; mean = 1/rate. `rate == 0` means "never".
    Exponential { rate: f64 },
    /// Weibull with `shape` k and `scale` λ (mean = λ·Γ(1+1/k)).
    Weibull { shape: f64, scale: f64 },
    /// LogNormal with the *underlying normal's* `mu` and `sigma`.
    LogNormal { mu: f64, sigma: f64 },
    /// Always exactly `value` (tests, fixed service times).
    Deterministic { value: f64 },
    /// Resample uniformly from an observed trace of durations.
    Empirical { samples: Vec<f64> },
}

impl Dist {
    /// Exponential with the given **mean** duration (minutes).
    pub fn exp_mean(mean: f64) -> Dist {
        assert!(mean > 0.0, "exp_mean requires mean > 0, got {mean}");
        Dist::Exponential { rate: 1.0 / mean }
    }

    /// Exponential with the given **rate** (per minute); 0 = never fires.
    pub fn exp_rate(rate: f64) -> Dist {
        assert!(rate >= 0.0, "rate must be non-negative, got {rate}");
        Dist::Exponential { rate }
    }

    /// Mean of the distribution (used by the analytical cross-check).
    pub fn mean(&self) -> f64 {
        match self {
            Dist::Exponential { rate } => {
                if *rate == 0.0 {
                    f64::INFINITY
                } else {
                    1.0 / rate
                }
            }
            Dist::Weibull { shape, scale } => scale * gamma(1.0 + 1.0 / shape),
            Dist::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
            Dist::Deterministic { value } => *value,
            Dist::Empirical { samples } => {
                samples.iter().sum::<f64>() / samples.len().max(1) as f64
            }
        }
    }

    /// Draw a fresh duration.
    pub fn sample(&self, rng: &mut Rng) -> Time {
        self.sample_remaining(rng, 0.0)
    }

    /// Draw a remaining duration *conditional on having survived `age`*:
    /// `P(X - age > t | X > age)` via the conditional inverse CDF.
    pub fn sample_remaining(&self, rng: &mut Rng, age: f64) -> Time {
        debug_assert!(age >= 0.0);
        match self {
            Dist::Exponential { rate } => {
                if *rate == 0.0 {
                    f64::INFINITY
                } else {
                    // Memoryless: age is irrelevant.
                    -rng.next_open_f64().ln() / rate
                }
            }
            Dist::Weibull { shape, scale } => {
                // Survival S(x) = exp(-(x/λ)^k). Conditional inverse:
                // x = λ·((age/λ)^k - ln U)^(1/k) - age, U ~ (0,1).
                let u = rng.next_open_f64();
                let a = (age / scale).powf(*shape);
                scale * (a - u.ln()).powf(1.0 / shape) - age
            }
            Dist::LogNormal { mu, sigma } => {
                if age == 0.0 {
                    (mu + sigma * rng.next_normal()).exp()
                } else {
                    // Conditional inverse CDF via the normal quantile:
                    // X = exp(mu + sigma·Φ⁻¹(Φ(z_age) + U·(1-Φ(z_age)))).
                    let z_age = (age.ln() - mu) / sigma;
                    let p_age = normal_cdf(z_age);
                    let u = p_age + rng.next_f64() * (1.0 - p_age);
                    let x = (mu + sigma * normal_quantile(u.clamp(1e-15, 1.0 - 1e-15))).exp();
                    (x - age).max(0.0)
                }
            }
            Dist::Deterministic { value } => (value - age).max(0.0),
            Dist::Empirical { samples } => {
                assert!(!samples.is_empty(), "Empirical dist needs samples");
                // Conditional resampling: draw among samples exceeding age,
                // falling back to an unconditional draw if none do.
                let over: Vec<f64> =
                    samples.iter().copied().filter(|&s| s > age).collect();
                if over.is_empty() {
                    samples[rng.next_below(samples.len() as u64) as usize]
                } else {
                    over[rng.next_below(over.len() as u64) as usize] - age
                }
            }
        }
    }

    /// Hazard (instantaneous failure-intensity) function
    /// `h(x) = f(x)/S(x)`: the failure rate at age `x` conditional on
    /// survival to `x`. This is what the thinned aggregate failure clocks
    /// ([`crate::model::failure`]) accept/reject candidates against.
    ///
    /// Defined for the parametric families (Exponential, Weibull,
    /// LogNormal) and Deterministic; panics for Empirical, whose hazard is
    /// a sum of point masses no thinning envelope can majorize.
    pub fn hazard(&self, x: f64) -> f64 {
        debug_assert!(x >= 0.0, "hazard at negative age {x}");
        match self {
            Dist::Exponential { rate } => *rate,
            Dist::Weibull { shape, scale } => {
                // h(x) = (k/λ)·(x/λ)^(k-1): increasing for k > 1, constant
                // at k = 1, decreasing (and diverging at 0) for k < 1.
                if x == 0.0 {
                    return match shape.partial_cmp(&1.0) {
                        Some(std::cmp::Ordering::Greater) => 0.0,
                        Some(std::cmp::Ordering::Equal) => 1.0 / scale,
                        _ => f64::INFINITY,
                    };
                }
                (shape / scale) * (x / scale).powf(shape - 1.0)
            }
            Dist::LogNormal { mu, sigma } => {
                if x <= 0.0 {
                    return 0.0; // h(0+) = 0: the density vanishes at 0
                }
                let z = (x.ln() - mu) / sigma;
                if z > 5.0 {
                    // Deep right tail: 1 - Φ(z) underflows the erf
                    // approximation; use the Mills-ratio asymptotic
                    // S(z) ≈ φ(z)/z · (1 - 1/z²), accurate to ~z⁻⁴ there.
                    return z / (x * sigma * (1.0 - 1.0 / (z * z)));
                }
                let sf = 1.0 - normal_cdf(z);
                let pdf = (-0.5 * z * z).exp()
                    / ((2.0 * std::f64::consts::PI).sqrt() * x * sigma);
                pdf / sf
            }
            Dist::Deterministic { value } => {
                if x < *value {
                    0.0
                } else {
                    f64::INFINITY
                }
            }
            Dist::Empirical { .. } => {
                panic!("hazard() is undefined for empirical distributions")
            }
        }
    }

    /// Age at which the hazard attains its maximum (`+∞` when the hazard
    /// is non-decreasing, so callers clamp it to their window's right
    /// edge). Closed-form for Exponential and Weibull; the LogNormal
    /// hazard is unimodal with no closed-form mode, located here by
    /// golden-section search — not free, so callers cache the result per
    /// distribution (the thinned model computes it once at build time).
    pub fn hazard_peak(&self) -> f64 {
        match self {
            Dist::Exponential { .. } => 0.0, // constant hazard: any point
            Dist::Weibull { shape, .. } => {
                if *shape >= 1.0 {
                    f64::INFINITY // non-decreasing
                } else {
                    0.0 // decreasing, diverges at 0
                }
            }
            Dist::LogNormal { mu, sigma } => {
                // Unimodal on (0, ∞); search over t = ln x (the monotone
                // transform preserves the maximizer).
                let (mut lo, mut hi) = (mu - 8.0 * sigma, mu + 12.0 * sigma);
                const INV_PHI: f64 = 0.618_033_988_749_894_8;
                for _ in 0..120 {
                    let m1 = hi - INV_PHI * (hi - lo);
                    let m2 = lo + INV_PHI * (hi - lo);
                    if self.hazard(m1.exp()) < self.hazard(m2.exp()) {
                        lo = m1;
                    } else {
                        hi = m2;
                    }
                }
                (0.5 * (lo + hi)).exp()
            }
            Dist::Deterministic { value } => *value,
            Dist::Empirical { .. } => {
                panic!("hazard_peak() is undefined for empirical distributions")
            }
        }
    }

    /// A majorizing bound on the hazard over the age window `[a, b]`.
    /// Every supported family's hazard is monotone or unimodal, so the
    /// window max is attained at the peak clamped into the window. `peak`
    /// must come from [`Dist::hazard_peak`] on the same distribution.
    pub fn hazard_max(&self, a: f64, b: f64, peak: f64) -> f64 {
        debug_assert!(a <= b, "empty hazard window [{a}, {b}]");
        self.hazard(peak.clamp(a, b))
    }
}

/// Lanczos approximation of the Gamma function (for Weibull means).
pub fn gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = G[0];
        let t = x + 7.5;
        for (i, &g) in G.iter().enumerate().skip(1) {
            a += g / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// Standard normal CDF via erf (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Acklam's inverse-normal-CDF approximation (|rel err| < 1.15e-9).
pub fn normal_quantile(p: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&p));
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_69e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.024_25;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -normal_quantile(1.0 - p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mean(d: &Dist, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn exponential_mean() {
        let d = Dist::exp_mean(30.0);
        let m = sample_mean(&d, 200_000, 1);
        assert!((m - 30.0).abs() / 30.0 < 0.02, "m={m}");
    }

    #[test]
    fn exponential_zero_rate_never_fires() {
        let d = Dist::exp_rate(0.0);
        let mut rng = Rng::new(2);
        assert_eq!(d.sample(&mut rng), f64::INFINITY);
        assert_eq!(d.mean(), f64::INFINITY);
    }

    #[test]
    fn exponential_memoryless() {
        // Conditional sampling with any age has the same distribution.
        let d = Dist::exp_mean(10.0);
        let mut rng = Rng::new(3);
        let n = 100_000;
        let m: f64 = (0..n)
            .map(|_| d.sample_remaining(&mut rng, 123.0))
            .sum::<f64>()
            / n as f64;
        assert!((m - 10.0).abs() / 10.0 < 0.02, "m={m}");
    }

    #[test]
    fn weibull_mean_matches_gamma_formula() {
        let d = Dist::Weibull { shape: 1.5, scale: 20.0 };
        let m = sample_mean(&d, 200_000, 4);
        let want = d.mean(); // 20·Γ(1+2/3)
        assert!((m - want).abs() / want < 0.02, "m={m} want={want}");
    }

    #[test]
    fn weibull_shape_one_is_exponential() {
        let w = Dist::Weibull { shape: 1.0, scale: 15.0 };
        let m = sample_mean(&w, 200_000, 5);
        assert!((m - 15.0).abs() / 15.0 < 0.02, "m={m}");
    }

    #[test]
    fn weibull_conditional_consistency() {
        // E[X - a | X > a] computed two ways: direct conditional draws vs
        // rejection sampling of fresh draws.
        let d = Dist::Weibull { shape: 2.0, scale: 50.0 };
        let age = 30.0;
        let mut rng = Rng::new(6);
        let n = 200_000;
        let cond: f64 = (0..n)
            .map(|_| d.sample_remaining(&mut rng, age))
            .sum::<f64>()
            / n as f64;
        let mut rej_sum = 0.0;
        let mut rej_n = 0usize;
        while rej_n < n {
            let x = d.sample(&mut rng);
            if x > age {
                rej_sum += x - age;
                rej_n += 1;
            }
        }
        let rej = rej_sum / rej_n as f64;
        assert!((cond - rej).abs() / rej < 0.03, "cond={cond} rej={rej}");
    }

    #[test]
    fn lognormal_mean() {
        let d = Dist::LogNormal { mu: 3.0, sigma: 0.5 };
        let m = sample_mean(&d, 300_000, 7);
        let want = d.mean();
        assert!((m - want).abs() / want < 0.02, "m={m} want={want}");
    }

    #[test]
    fn lognormal_conditional_consistency() {
        let d = Dist::LogNormal { mu: 3.0, sigma: 0.6 };
        let age = 15.0;
        let mut rng = Rng::new(8);
        let n = 200_000;
        let cond: f64 = (0..n)
            .map(|_| d.sample_remaining(&mut rng, age))
            .sum::<f64>()
            / n as f64;
        let mut rej_sum = 0.0;
        let mut rej_n = 0usize;
        while rej_n < n {
            let x = d.sample(&mut rng);
            if x > age {
                rej_sum += x - age;
                rej_n += 1;
            }
        }
        let rej = rej_sum / rej_n as f64;
        assert!((cond - rej).abs() / rej < 0.03, "cond={cond} rej={rej}");
    }

    #[test]
    fn deterministic_and_empirical() {
        let mut rng = Rng::new(9);
        let d = Dist::Deterministic { value: 42.0 };
        assert_eq!(d.sample(&mut rng), 42.0);
        assert_eq!(d.sample_remaining(&mut rng, 10.0), 32.0);

        let e = Dist::Empirical { samples: vec![1.0, 2.0, 3.0] };
        for _ in 0..100 {
            let s = e.sample(&mut rng);
            assert!([1.0, 2.0, 3.0].contains(&s));
        }
        assert!((e.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_known_values() {
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma(5.0) - 24.0).abs() < 1e-8);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn normal_cdf_quantile_roundtrip() {
        for &p in &[0.001, 0.01, 0.2, 0.5, 0.8, 0.99, 0.999] {
            let z = normal_quantile(p);
            let back = normal_cdf(z);
            assert!((back - p).abs() < 1e-6, "p={p} back={back}");
        }
    }

    /// Analytic survival functions for the hazard finite-difference check.
    fn survival(d: &Dist, x: f64) -> f64 {
        match d {
            Dist::Exponential { rate } => (-rate * x).exp(),
            Dist::Weibull { shape, scale } => (-(x / scale).powf(*shape)).exp(),
            Dist::LogNormal { mu, sigma } => {
                1.0 - normal_cdf((x.ln() - mu) / sigma)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn hazard_matches_finite_difference_of_log_survival() {
        // h(x) = -d/dx ln S(x); central difference on the analytic S.
        let dists = [
            Dist::exp_rate(0.07),
            Dist::Weibull { shape: 1.5, scale: 40.0 },
            Dist::Weibull { shape: 3.0, scale: 25.0 },
            Dist::LogNormal { mu: 3.0, sigma: 0.6 },
        ];
        for d in &dists {
            for &x in &[0.5, 2.0, 10.0, 35.0, 90.0] {
                let eps = 1e-5 * x.max(1.0);
                let fd = (survival(d, x - eps).ln() - survival(d, x + eps).ln())
                    / (2.0 * eps);
                let h = d.hazard(x);
                assert!(
                    (h - fd).abs() / fd.abs().max(1e-12) < 1e-3,
                    "{d:?} at x={x}: hazard={h} finite-diff={fd}"
                );
            }
        }
    }

    #[test]
    fn weibull_shape_one_hazard_is_constant_rate() {
        let d = Dist::Weibull { shape: 1.0, scale: 15.0 };
        for &x in &[0.0, 1.0, 100.0, 1e6] {
            assert!((d.hazard(x) - 1.0 / 15.0).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn hazard_edge_cases() {
        // Increasing Weibull starts at 0; decreasing diverges at 0.
        assert_eq!(Dist::Weibull { shape: 2.0, scale: 10.0 }.hazard(0.0), 0.0);
        assert_eq!(
            Dist::Weibull { shape: 0.5, scale: 10.0 }.hazard(0.0),
            f64::INFINITY
        );
        // LogNormal hazard vanishes at 0 and stays finite deep in the
        // right tail (the Mills-ratio branch) instead of 0/0 → NaN.
        let ln = Dist::LogNormal { mu: 2.0, sigma: 0.5 };
        assert_eq!(ln.hazard(0.0), 0.0);
        let deep = (2.0f64 + 0.5 * 8.0).exp(); // z = 8
        let h = ln.hazard(deep);
        assert!(h.is_finite() && h > 0.0, "deep-tail hazard {h}");
        // Deterministic: zero before the value, infinite at/after it.
        let det = Dist::Deterministic { value: 5.0 };
        assert_eq!(det.hazard(1.0), 0.0);
        assert_eq!(det.hazard(5.0), f64::INFINITY);
    }

    #[test]
    fn hazard_max_majorizes_over_windows() {
        let dists = [
            Dist::exp_rate(0.03),
            Dist::Weibull { shape: 1.0, scale: 30.0 },
            Dist::Weibull { shape: 2.5, scale: 50.0 },
            Dist::LogNormal { mu: 3.0, sigma: 0.8 },
            Dist::LogNormal { mu: 1.0, sigma: 1.4 },
        ];
        for d in &dists {
            let peak = d.hazard_peak();
            for &(a, w) in
                &[(0.0, 5.0), (0.0, 500.0), (3.0, 40.0), (80.0, 120.0), (400.0, 50.0)]
            {
                let b = a + w;
                let bound = d.hazard_max(a, b, peak);
                for i in 0..=400 {
                    let x = a + w * i as f64 / 400.0;
                    let h = d.hazard(x);
                    // 1% slack spans the LogNormal Mills-ratio seam.
                    assert!(
                        h <= bound * 1.01 + 1e-12,
                        "{d:?}: h({x})={h} > bound {bound} on [{a}, {b}]"
                    );
                }
            }
        }
    }

    #[test]
    fn lognormal_hazard_peak_is_a_maximum() {
        for d in [
            Dist::LogNormal { mu: 3.0, sigma: 0.5 },
            Dist::LogNormal { mu: 1.5, sigma: 1.2 },
        ] {
            let peak = d.hazard_peak();
            assert!(peak.is_finite() && peak > 0.0);
            let hp = d.hazard(peak);
            for i in 1..=300 {
                let x = peak * (0.01 + 3.0 * i as f64 / 300.0);
                assert!(
                    d.hazard(x) <= hp * 1.01 + 1e-12,
                    "{d:?}: hazard({x}) exceeds hazard(peak={peak})={hp}"
                );
            }
        }
    }

    #[test]
    fn samples_always_non_negative() {
        let mut rng = Rng::new(10);
        let dists = [
            Dist::exp_mean(5.0),
            Dist::Weibull { shape: 0.8, scale: 10.0 },
            Dist::LogNormal { mu: 1.0, sigma: 1.0 },
        ];
        for d in &dists {
            for _ in 0..10_000 {
                assert!(d.sample(&mut rng) >= 0.0);
                assert!(d.sample_remaining(&mut rng, 7.0) >= 0.0);
            }
        }
    }
}
