//! Deterministic discrete-event simulation core.
//!
//! This is the substrate the paper builds on SimPy for; here it is a
//! from-scratch event-driven engine:
//!
//! * [`rng`] — splittable, counter-seeded PRNG (SplitMix64 → xoshiro256++)
//!   so every replication and every parameter point gets an independent,
//!   reproducible stream.
//! * [`dist`] — the failure/repair duration distributions the paper
//!   supports (Exponential by assumption 2, plus Weibull and LogNormal,
//!   plus deterministic and empirical user-defined distributions).
//! * [`event`] — the event vocabulary and lazy-cancellation tokens.
//! * [`calendar`] — the bucketed calendar queue backing the engine:
//!   amortized O(1) schedule/pop with heap-identical delivery order.
//! * [`engine`] — the pending-event set (calendar by default, binary
//!   heap behind `QueueKind::Heap` for A/B runs) with stable FIFO
//!   tie-breaking and a monotone simulation clock.

pub mod calendar;
pub mod dist;
pub mod engine;
pub mod event;
pub mod rng;

/// Simulation time, in **minutes** (matches the paper's Table I units).
pub type Time = f64;

/// Minutes per day, for converting the paper's per-day failure rates.
pub const MIN_PER_DAY: f64 = 24.0 * 60.0;
