//! Event wrappers: heap entries with stable tie-breaking, and generation
//! tokens for lazy cancellation.
//!
//! Simultaneous events are delivered in schedule order (FIFO), which makes
//! every simulation a deterministic function of (params, seed) — the
//! property the replay tests in `tests/determinism.rs` assert.

use crate::sim::Time;
use std::cmp::Ordering;

/// A scheduled event: ordered by time, then by schedule sequence number.
#[derive(Clone, Debug)]
pub struct Scheduled<E> {
    pub at: Time,
    pub seq: u64,
    pub payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Generation counter for lazy cancellation: events carry the generation
/// they were scheduled under; bumping the counter invalidates everything
/// in flight for that entity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Generation(pub u64);

impl Generation {
    /// Invalidate all outstanding events carrying the old generation.
    #[inline]
    pub fn bump(&mut self) {
        self.0 += 1;
    }

    /// Does an event scheduled under `seen` still apply?
    #[inline]
    pub fn is_current(&self, seen: Generation) -> bool {
        self.0 == seen.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_earliest_first() {
        let a = Scheduled { at: 1.0, seq: 0, payload: () };
        let b = Scheduled { at: 2.0, seq: 1, payload: () };
        assert!(a > b); // max-heap: "greater" pops first
    }

    #[test]
    fn ordering_fifo_on_ties() {
        let a = Scheduled { at: 5.0, seq: 0, payload: () };
        let b = Scheduled { at: 5.0, seq: 1, payload: () };
        assert!(a > b);
    }

    #[test]
    fn generation_invalidates() {
        let mut g = Generation::default();
        let seen = g;
        assert!(g.is_current(seen));
        g.bump();
        assert!(!g.is_current(seen));
    }
}
