//! Hand-rolled JSON writer (the offline environment carries no serde).
//!
//! [`Json`] is an ordered document model — objects keep insertion order,
//! so rendered output is deterministic and diffs stay readable. Emission
//! covers exactly what the sinks need: RFC 8259-valid escaping, and
//! numbers that round-trip (non-finite values — e.g. the ±∞ a one-sample
//! confidence interval produces — render as `null`, the only valid JSON
//! spelling for them).

use std::fmt::Write as _;

/// One JSON value. Objects preserve insertion order (a `Vec`, not a map).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Convenience: an object from (key, value) pairs.
    pub fn obj<K, I>(fields: I) -> Json
    where
        K: Into<String>,
        I: IntoIterator<Item = (K, Json)>,
    {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Render as compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::str(s)
    }
}

fn write_num(v: f64, out: &mut String) {
    if !v.is_finite() {
        // JSON has no NaN/Infinity; null is the lossless-enough spelling.
        out.push_str("null");
    } else if v == v.trunc() && v.abs() < 9.007_199_254_740_992e15 {
        // Exact integers print without a fraction ("3", not "3.0"),
        // within the f64-exact range.
        let _ = write!(out, "{}", v as i64);
    } else {
        // Rust's f64 Display is the shortest decimal that round-trips —
        // always a valid JSON number.
        let _ = write!(out, "{v}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.5).render(), "3.5");
        assert_eq!(Json::Num(-0.25).render(), "-0.25");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::str("hi").render(), "\"hi\"");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::str("\u{1}").render(), "\"\\u0001\"");
        assert_eq!(Json::str("μs").render(), "\"μs\"");
    }

    #[test]
    fn collections_preserve_order() {
        let j = Json::obj([
            ("z", Json::Num(1.0)),
            ("a", Json::Arr(vec![Json::Num(1.0), Json::str("two")])),
        ]);
        assert_eq!(j.render(), r#"{"z":1,"a":[1,"two"]}"#);
    }

    #[test]
    fn large_integers_stay_exact() {
        assert_eq!(Json::Num(9007199254740991.0).render(), "9007199254740991");
        // Beyond 2^53 falls back to float display (still valid JSON).
        let big = Json::Num(1.0e300).render();
        assert!(big.parse::<f64>().is_ok() || big.starts_with('1'));
    }
}
