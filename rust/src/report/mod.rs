//! Reporting: the structured output API plus the legacy text emitters.
//!
//! The structured path is records + sinks: every CLI command builds one
//! typed record ([`record`]) and any `--format` sink ([`sink`]) renders
//! it — text (byte-identical to the pre-redesign tables), JSON
//! (hand-rolled, zero-dep: [`json`]), CSV, or NDJSON. The free functions
//! below ([`text_table`], [`csv`], [`figure_series`], [`sensitivity`])
//! are the text/CSV table primitives the sinks delegate to.

pub mod json;
pub mod record;
pub mod sink;

pub use record::{
    BestConfig, CompareRecord, ComparisonEntry, OptimizeRecord, PrescreenRecord,
    RecordBody, RunRecord, ScenarioRecord, ScreenEffect, StudyChildRecord, StudyRecord,
    SweepRecord, TunePoint, WhatIfRecord,
};
pub use sink::{Format, Sink};

use crate::stats::Summary;
use crate::sweep::SweepResult;

/// Render a sweep as an aligned text table of one metric's summary.
pub fn text_table(result: &SweepResult, metric: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {} — {} ==\n", result.title, metric));
    out.push_str(&format!(
        "{:<44} {:>6} {:>14} {:>12} {:>14} {:>14} {:>14}\n",
        "point", "n", "mean", "std", "median", "p95", "max"
    ));
    for pr in &result.points {
        match pr.summary(metric) {
            Some(s) => out.push_str(&format!(
                "{:<44} {:>6} {:>14.3} {:>12.3} {:>14.3} {:>14.3} {:>14.3}\n",
                pr.point.label(),
                s.n,
                s.mean,
                s.std,
                s.median,
                s.p95,
                s.max
            )),
            None => out.push_str(&format!("{:<44} (no data)\n", pr.point.label())),
        }
    }
    out
}

/// Render a sweep as CSV (all points × one metric's full summary).
pub fn csv(result: &SweepResult, metric: &str) -> String {
    let mut out = String::new();
    // Header: the override parameter names of the first point.
    let param_names: Vec<&str> = result
        .points
        .first()
        .map(|p| p.point.overrides.iter().map(|(n, _)| n.as_str()).collect())
        .unwrap_or_default();
    out.push_str(&param_names.join(","));
    out.push_str(",metric,n,mean,std,min,p25,median,p75,p95,p99,max\n");
    for pr in &result.points {
        let vals: Vec<String> =
            pr.point.overrides.iter().map(|(_, v)| format!("{v}")).collect();
        let s = match pr.summary(metric) {
            Some(s) => s,
            None => continue,
        };
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            vals.join(","),
            metric,
            s.n,
            s.mean,
            s.std,
            s.min,
            s.p25,
            s.median,
            s.p75,
            s.p95,
            s.p99,
            s.max
        ));
    }
    out
}

/// Figure-2-style series: for a two-way sweep with overrides
/// `[(x, vx), (y, vy)]`, print one labelled `(x, y)` column per point with
/// the metric's mean — the same "(waiting time, working pool size)" axis
/// labels the paper's bar charts use.
pub fn figure_series(result: &SweepResult, metric: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {} — {} (mean) ==\n", result.title, metric));
    let max_mean = result
        .points
        .iter()
        .filter_map(|p| p.summary(metric))
        .map(|s| s.mean)
        .fold(0.0f64, f64::max);
    for pr in &result.points {
        let label: Vec<String> =
            pr.point.overrides.iter().map(|(_, v)| format!("{v}")).collect();
        if let Some(s) = pr.summary(metric) {
            let bar_len = if max_mean > 0.0 {
                ((s.mean / max_mean) * 48.0).round() as usize
            } else {
                0
            };
            out.push_str(&format!(
                "({:<20}) {:>14.2} ± {:<10.2} {}\n",
                label.join(", "),
                s.mean,
                s.ci95_halfwidth(),
                "#".repeat(bar_len)
            ));
        }
    }
    out
}

/// Sensitivity ranking (the §IV analysis: which knobs matter): for each
/// one-way sweep result, the relative spread of the metric's mean across
/// the swept values.
pub fn sensitivity(results: &[(String, SweepResult)], metric: &str) -> String {
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (name, res) in results {
        let means: Vec<f64> = res
            .points
            .iter()
            .filter_map(|p| p.summary(metric))
            .map(|s| s.mean)
            .collect();
        if means.is_empty() {
            continue;
        }
        let lo = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let spread = if lo > 0.0 { (hi - lo) / lo } else { 0.0 };
        rows.push((name.clone(), lo, hi, spread));
    }
    rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
    let mut out = String::new();
    out.push_str(&format!(
        "{:<32} {:>14} {:>14} {:>10}\n",
        "parameter", "min mean", "max mean", "spread"
    ));
    for (name, lo, hi, spread) in rows {
        out.push_str(&format!(
            "{:<32} {:>14.2} {:>14.2} {:>9.1}%\n",
            name,
            lo,
            hi,
            spread * 100.0
        ));
    }
    out
}

/// One-line render of a summary (CLI output).
pub fn summary_line(name: &str, s: &Summary) -> String {
    format!(
        "{:<22} n={:<4} mean={:<12.3} std={:<10.3} p50={:<12.3} p95={:<12.3}",
        name, s.n, s.mean, s.std, s.median, s.p95
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Params;
    use crate::sweep::{run_sweep, Sweep};

    fn tiny_result() -> SweepResult {
        let base = Params::small_test();
        let sweep = Sweep::one_way("test", "recovery_time", &[10.0, 30.0], 3, 1);
        run_sweep(&base, &sweep, 2)
    }

    #[test]
    fn text_table_renders_all_points() {
        let r = tiny_result();
        let t = text_table(&r, "makespan");
        assert!(t.contains("recovery_time=10"));
        assert!(t.contains("recovery_time=30"));
        assert!(t.contains("mean"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = tiny_result();
        let c = csv(&r, "failures_total");
        let lines: Vec<&str> = c.trim().lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 points
        assert!(lines[0].starts_with("recovery_time,metric,n,mean"));
        assert!(lines[1].starts_with("10,failures_total,3,"));
    }

    #[test]
    fn figure_series_renders_bars() {
        let r = tiny_result();
        let f = figure_series(&r, "makespan");
        assert!(f.contains('#'));
        assert!(f.contains('±'));
    }

    #[test]
    fn sensitivity_ranks_by_spread() {
        let base = Params::small_test();
        let s1 = run_sweep(
            &base,
            &Sweep::one_way("a", "recovery_time", &[5.0, 240.0], 4, 1),
            2,
        );
        let s2 = run_sweep(
            &base,
            &Sweep::one_way("b", "diagnosis_prob", &[0.79, 0.8], 4, 1),
            2,
        );
        let table = sensitivity(
            &[("recovery_time".into(), s1), ("diagnosis_prob".into(), s2)],
            "makespan",
        );
        // recovery_time's spread should rank first.
        let lines: Vec<&str> = table.trim().lines().collect();
        assert!(lines[1].starts_with("recovery_time"), "got: {table}");
    }
}
