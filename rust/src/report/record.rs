//! The typed record layer: every CLI command produces one of these
//! records, and any [`crate::report::sink::Sink`] renders it. Records own
//! their data (params, policies, outputs, summaries) so sinks are pure
//! `record -> String` functions with no access to live simulation state.

use crate::analytical::AnalyticOutputs;
use crate::config::Params;
use crate::model::{PolicySpec, RunOutputs};
use crate::report::json::Json;
use crate::stats::{metrics, Collector, Summary};
use crate::sweep::{AxisValue, PointResult, SweepResult};
use crate::trace::{event_json, Trace};

/// One simulation run: `airesim run`, and `single`/`inject` scenarios.
pub struct RunRecord {
    pub seed: u64,
    pub params: Params,
    pub policies: PolicySpec,
    pub outputs: RunOutputs,
    /// Empty unless the run was traced.
    pub trace: Trace,
}

impl RunRecord {
    /// Every registry metric evaluated against this run, in registry
    /// order.
    pub fn metric_values(&self) -> impl Iterator<Item = (&'static metrics::Metric, f64)> + '_ {
        metrics::REGISTRY.iter().map(|m| (m, (m.extract)(&self.params, &self.outputs)))
    }

    pub fn to_json(&self) -> Json {
        let metrics_obj = Json::Obj(
            self.metric_values()
                .map(|(m, v)| {
                    (
                        m.name.to_string(),
                        Json::obj([("value", Json::Num(v)), ("unit", Json::str(m.unit))]),
                    )
                })
                .collect(),
        );
        let mut fields = vec![
            ("kind".to_string(), Json::str("run")),
            ("seed".to_string(), self.seed.into()),
            ("policies".to_string(), policies_json(&self.policies)),
            ("metrics".to_string(), metrics_obj),
        ];
        if !self.trace.is_empty() {
            fields.push((
                "trace".to_string(),
                Json::Arr(
                    self.trace
                        .records
                        .iter()
                        .map(|r| event_json(r.at, &r.kind))
                        .collect(),
                ),
            ));
        }
        Json::Obj(fields)
    }
}

/// A sweep's results plus the headline metric text/CSV tables report.
pub struct SweepRecord {
    pub result: SweepResult,
    pub metric: String,
}

impl SweepRecord {
    pub fn new(result: SweepResult, metric: &str) -> SweepRecord {
        SweepRecord { result, metric: metric.to_string() }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("kind", Json::str("sweep")),
            ("title", Json::str(&self.result.title)),
            ("metric", Json::str(&self.metric)),
            (
                "points",
                Json::Arr(self.result.points.iter().map(point_json).collect()),
            ),
        ])
    }
}

/// A what-if comparison: baseline vs scaled parameter.
pub struct WhatIfRecord {
    pub result: SweepResult,
    pub param: String,
    pub factor: f64,
    pub metric: String,
}

impl WhatIfRecord {
    /// (baseline mean, scaled mean, percent change) of the headline
    /// metric, when both points have data.
    pub fn delta(&self) -> Option<(f64, f64, f64)> {
        let a = self.result.points.first()?.summary(&self.metric)?;
        let b = self.result.points.get(1)?.summary(&self.metric)?;
        Some((a.mean, b.mean, (b.mean / a.mean - 1.0) * 100.0))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("kind".to_string(), Json::str("whatif")),
            ("param".to_string(), Json::str(&self.param)),
            ("factor".to_string(), Json::Num(self.factor)),
            ("metric".to_string(), Json::str(&self.metric)),
        ];
        if let Some((base, scaled, pct)) = self.delta() {
            fields.push(("baseline_mean".to_string(), Json::Num(base)));
            fields.push(("scaled_mean".to_string(), Json::Num(scaled)));
            fields.push(("delta_pct".to_string(), Json::Num(pct)));
        }
        fields.push((
            "points".to_string(),
            Json::Arr(self.result.points.iter().map(point_json).collect()),
        ));
        Json::Obj(fields)
    }
}

/// One child of a `multi:` study: its label, the overrides it applies to
/// the shared base config, the policy set it resolved to, and the
/// collected outputs of all of its replications.
#[derive(Clone)]
pub struct StudyChildRecord {
    pub label: String,
    /// (axis, value) overrides on the base config — numeric parameter
    /// names or `policies.<axis>` names, exactly the sweep-point form.
    pub overrides: Vec<(String, AxisValue)>,
    /// The child's fully resolved policy selection (base + overrides).
    pub policies: PolicySpec,
    /// Every registry metric across the child's replications.
    pub collector: Collector,
}

impl StudyChildRecord {
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        self.collector.summary(metric)
    }

    /// The child's overrides as a display string (empty overrides render
    /// as the base config marker).
    pub fn overrides_label(&self) -> String {
        if self.overrides.is_empty() {
            return "(base config)".into();
        }
        self.overrides
            .iter()
            .map(|(n, v)| format!("{n}={v}"))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// One comparison-table cell: a child's mean of one metric, with its
/// delta against the study baseline when one is designated.
#[derive(Clone, Copy, Debug)]
pub struct ComparisonEntry {
    /// Index into [`StudyRecord::children`].
    pub child: usize,
    pub n: usize,
    pub mean: f64,
    pub ci95: f64,
    /// `mean - baseline_mean`; `None` for the baseline row itself (or
    /// when no baseline is designated).
    pub delta: Option<f64>,
    /// Percent change vs the baseline mean; `None` on the baseline row,
    /// without a baseline, or when the baseline mean is 0.
    pub delta_pct: Option<f64>,
    /// 95% half-width on the delta — paired (CRN studies) or Welch
    /// (independent streams). `None` on the baseline row, without a
    /// baseline, or when too few replications make it infinite.
    pub delta_ci: Option<f64>,
    /// Whether the delta CI excludes zero; populated exactly when
    /// `delta_ci` is.
    pub significant: Option<bool>,
}

/// The combined result of a `multi:` study: per-child records plus the
/// derived comparison table (every registry metric, delta vs baseline).
#[derive(Clone)]
pub struct StudyRecord {
    pub replications: usize,
    /// Whether all children ran on common random numbers.
    pub crn: bool,
    /// Index of the designated baseline child, if any.
    pub baseline: Option<usize>,
    /// Show the delta-CI / significance columns in the *text* table
    /// (`show_ci: true`); the machine formats always carry them. Off by
    /// default so the legacy table stays byte-identical.
    pub show_ci: bool,
    pub children: Vec<StudyChildRecord>,
}

impl StudyRecord {
    /// The baseline child's label, if a baseline is designated.
    pub fn baseline_label(&self) -> Option<&str> {
        self.baseline.map(|i| self.children[i].label.as_str())
    }

    /// The comparison table: for every registry metric, one entry per
    /// child (in child order) with delta-vs-baseline columns. Children
    /// missing a metric's summary are skipped in that metric's row set.
    pub fn comparison(&self) -> Vec<(&'static metrics::Metric, Vec<ComparisonEntry>)> {
        use crate::optimize::stats::{paired_delta_ci, welch_delta_ci};
        let mut table = Vec::with_capacity(metrics::REGISTRY.len());
        for m in metrics::REGISTRY {
            let base_mean = self
                .baseline
                .and_then(|i| self.children[i].summary(m.name))
                .map(|s| s.mean);
            let base_vals = self.baseline.and_then(|i| self.children[i].collector.values(m.name));
            let mut entries = Vec::with_capacity(self.children.len());
            for (i, child) in self.children.iter().enumerate() {
                let Some(s) = child.summary(m.name) else { continue };
                let (delta, delta_pct) = match (base_mean, self.baseline) {
                    (Some(b), Some(bi)) if bi != i => (
                        Some(s.mean - b),
                        (b != 0.0).then(|| (s.mean / b - 1.0) * 100.0),
                    ),
                    _ => (None, None),
                };
                // Delta inference: CRN studies pair replication-by-
                // replication (collectors are replication-ordered);
                // independent streams fall back to Welch. Infinite
                // half-widths (too few replications) are suppressed
                // rather than rendered as nulls.
                let (delta_ci, significant) = match (base_vals, self.baseline) {
                    (Some(bv), Some(bi)) if bi != i => {
                        let ci = child.collector.values(m.name).and_then(|v| {
                            if self.crn {
                                paired_delta_ci(bv, v)
                            } else {
                                welch_delta_ci(bv, v)
                            }
                        });
                        match ci {
                            Some(c) if c.half.is_finite() => {
                                (Some(c.half), Some(c.significant()))
                            }
                            _ => (None, None),
                        }
                    }
                    _ => (None, None),
                };
                entries.push(ComparisonEntry {
                    child: i,
                    n: s.n,
                    mean: s.mean,
                    ci95: s.ci95_halfwidth(),
                    delta,
                    delta_pct,
                    delta_ci,
                    significant,
                });
            }
            table.push((m, entries));
        }
        table
    }

    pub fn to_json(&self) -> Json {
        let children = Json::Arr(
            self.children
                .iter()
                .map(|c| {
                    let metrics_obj = Json::Obj(
                        metrics::REGISTRY
                            .iter()
                            .filter_map(|m| {
                                c.summary(m.name)
                                    .map(|s| (m.name.to_string(), summary_json(&s)))
                            })
                            .collect(),
                    );
                    Json::obj([
                        ("label", Json::str(&c.label)),
                        ("overrides", overrides_json(&c.overrides)),
                        ("policies", policies_json(&c.policies)),
                        ("metrics", metrics_obj),
                    ])
                })
                .collect(),
        );
        let comparison = Json::Arr(
            self.comparison()
                .into_iter()
                .map(|(m, entries)| {
                    let rows = Json::Arr(
                        entries
                            .iter()
                            .map(|e| {
                                let mut fields = vec![
                                    (
                                        "label".to_string(),
                                        Json::str(&self.children[e.child].label),
                                    ),
                                    ("mean".to_string(), Json::Num(e.mean)),
                                    ("ci95".to_string(), Json::Num(e.ci95)),
                                ];
                                if let Some(d) = e.delta {
                                    fields.push(("delta".to_string(), Json::Num(d)));
                                }
                                if let Some(pct) = e.delta_pct {
                                    fields.push(("delta_pct".to_string(), Json::Num(pct)));
                                }
                                if let Some(h) = e.delta_ci {
                                    fields.push(("delta_ci".to_string(), Json::Num(h)));
                                }
                                if let Some(sig) = e.significant {
                                    fields.push(("significant".to_string(), Json::Bool(sig)));
                                }
                                Json::Obj(fields)
                            })
                            .collect(),
                    );
                    Json::obj([
                        ("metric", Json::str(m.name)),
                        ("unit", Json::str(m.unit)),
                        ("children", rows),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("kind".to_string(), Json::str("study")),
            ("replications".to_string(), self.replications.into()),
            ("crn".to_string(), Json::Bool(self.crn)),
        ];
        if let Some(label) = self.baseline_label() {
            fields.push(("baseline".to_string(), Json::str(label)));
        }
        fields.push(("children".to_string(), children));
        fields.push(("comparison".to_string(), comparison));
        Json::Obj(fields)
    }
}

/// The analytical CTMC estimate vs the DES mean (`compare` scenarios).
pub struct CompareRecord {
    pub analytic: AnalyticOutputs,
    pub des_makespan: Summary,
    pub replications: usize,
}

impl CompareRecord {
    /// |CTMC − DES| / DES, the headline agreement number.
    pub fn relative_delta(&self) -> f64 {
        (self.analytic.makespan_est - self.des_makespan.mean).abs()
            / self.des_makespan.mean.max(1.0)
    }

    pub fn to_json(&self) -> Json {
        let a = &self.analytic;
        Json::obj([
            ("kind", Json::str("compare")),
            ("replications", self.replications.into()),
            (
                "analytic",
                Json::obj([
                    ("avail_t", Json::Num(a.avail_t)),
                    ("avail_avg", Json::Num(a.avail_avg)),
                    ("frac_bad_t", Json::Num(a.frac_bad_t)),
                    ("rbar", Json::Num(a.rbar)),
                    ("exp_failures", Json::Num(a.exp_failures)),
                    ("makespan_est", Json::Num(a.makespan_est)),
                    ("overhead_frac", Json::Num(a.overhead_frac)),
                    ("pi_retired", Json::Num(a.pi_retired)),
                ]),
            ),
            ("des_makespan", summary_json(&self.des_makespan)),
            ("relative_delta", Json::Num(self.relative_delta())),
        ])
    }
}

/// The `prescreen` workflow: the full grid ranked by the analytical CTMC
/// screen, plus DES validation of the top-k survivors.
pub struct PrescreenRecord {
    /// Every grid point with its analytical outputs, best-ranked first.
    pub ranking: Vec<(String, AnalyticOutputs)>,
    /// (label, makespan-hours summary) of the DES-validated top-k, in
    /// ranking order.
    pub validated: Vec<(String, Summary)>,
    /// DES replications per validated point.
    pub reps: usize,
}

impl PrescreenRecord {
    /// The legacy ranking table, byte for byte. An associated function
    /// over the bare ranking so the CLI can stream it *before* the DES
    /// stage runs (a DES failure must not cost the screening output).
    pub fn ranking_text(ranking: &[(String, AnalyticOutputs)]) -> String {
        let mut s = String::new();
        s.push_str("\nanalytical ranking (best first):\n");
        s.push_str(&format!(
            "{:<44} {:>16} {:>12}\n",
            "point", "CTMC makespan(h)", "exp.failures"
        ));
        for (label, a) in ranking {
            s.push_str(&format!(
                "{:<44} {:>16.1} {:>12.0}\n",
                label,
                a.makespan_est / 60.0,
                a.exp_failures
            ));
        }
        s
    }

    /// The legacy DES-validation table, byte for byte.
    pub fn validation_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "\nDES validation of the top {} ({} replications each):\n",
            self.validated.len(),
            self.reps
        ));
        s.push_str(&format!(
            "{:<44} {:>14} {:>10}\n",
            "point", "DES makespan(h)", "±95%CI"
        ));
        for (label, summary) in &self.validated {
            s.push_str(&format!(
                "{:<44} {:>14.1} {:>10.1}\n",
                label,
                summary.mean,
                summary.ci95_halfwidth()
            ));
        }
        s
    }

    /// Both legacy tables (the full text report).
    pub fn render_text(&self) -> String {
        Self::ranking_text(&self.ranking) + &self.validation_text()
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("kind", Json::str("prescreen")),
            ("reps", self.reps.into()),
            (
                "ranking",
                Json::Arr(
                    self.ranking
                        .iter()
                        .map(|(label, a)| {
                            Json::obj([
                                ("label", Json::str(label)),
                                ("ctmc_makespan_est", Json::Num(a.makespan_est)),
                                ("ctmc_makespan_hours", Json::Num(a.makespan_est / 60.0)),
                                ("exp_failures", Json::Num(a.exp_failures)),
                                ("avail_avg", Json::Num(a.avail_avg)),
                                ("overhead_frac", Json::Num(a.overhead_frac)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "validated",
                Json::Arr(
                    self.validated
                        .iter()
                        .map(|(label, s)| {
                            Json::obj([
                                ("label", Json::str(label)),
                                ("des_makespan_hours", summary_json(s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One ranked row of the factorial screen (`mode: screen`): a knob's
/// main effect on the objective — mean objective at the knob's high
/// level minus at its low level, CRN-paired across replications.
#[derive(Clone, Debug)]
pub struct ScreenEffect {
    /// Knob name (parameter or `policies.*` axis).
    pub knob: String,
    /// Low / high level labels (first / last declared value).
    pub lo: String,
    pub hi: String,
    /// Main effect: mean(objective | hi) − mean(objective | lo).
    pub effect: f64,
    /// 95% half-width on the effect.
    pub ci95: f64,
    /// Observations behind the CI (replications, or design rows when
    /// replications == 1).
    pub n: usize,
    /// 1-based rank by |effect| (1 = most important).
    pub rank: usize,
    /// Whether the effect's CI excludes zero.
    pub significant: bool,
}

/// One evaluated candidate of the successive-halving search
/// (`mode: tune`), in candidate declaration order.
#[derive(Clone, Debug)]
pub struct TunePoint {
    pub label: String,
    pub overrides: Vec<(String, AxisValue)>,
    /// Replications this candidate actually ran before (if) pruning.
    pub n: usize,
    pub mean: f64,
    /// 95% half-width on the candidate's own mean.
    pub ci95: f64,
    /// The halving round that pruned it; `None` = survived to the end.
    pub pruned_round: Option<usize>,
    pub winner: bool,
}

/// The search winner, with its paired verdict against the base config
/// and a runnable `scenario: single` YAML rendition (`--best-out`).
#[derive(Clone, Debug)]
pub struct BestConfig {
    pub label: String,
    pub overrides: Vec<(String, AxisValue)>,
    /// Winner's mean objective.
    pub mean: f64,
    /// Paired delta winner − base (negative = improvement for `min`).
    pub delta_mean: f64,
    /// 95% half-width on the paired delta.
    pub delta_ci95: f64,
    /// Paired replications behind the delta.
    pub delta_n: usize,
    /// Whether the winner beats the base with a CI excluding zero.
    pub significant: bool,
    /// The winning configuration as a runnable YAML document.
    pub yaml: String,
}

/// The `scenario: optimize` result: a ranked main-effects table
/// (`mode: screen`) or a full search trail plus winner (`mode: tune`).
#[derive(Clone, Debug)]
pub struct OptimizeRecord {
    /// `screen | tune`.
    pub mode: String,
    /// Objective metric name and unit (from the registry).
    pub objective: String,
    pub objective_unit: String,
    /// `min | max`.
    pub direction: String,
    pub replications: usize,
    /// Simulator runs actually executed.
    pub total_runs: usize,
    /// Effective run budget (screen: declared cap; tune: declared or
    /// candidates × replications).
    pub budget: usize,
    /// Ranked knob effects (`mode: screen`; empty for tune).
    pub effects: Vec<ScreenEffect>,
    /// Every candidate evaluated (`mode: tune`; empty for screen).
    pub trail: Vec<TunePoint>,
    /// The search winner (`mode: tune` only).
    pub best: Option<BestConfig>,
}

impl OptimizeRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("kind".to_string(), Json::str("optimize")),
            ("mode".to_string(), Json::str(&self.mode)),
            ("objective".to_string(), Json::str(&self.objective)),
            ("objective_unit".to_string(), Json::str(&self.objective_unit)),
            ("direction".to_string(), Json::str(&self.direction)),
            ("replications".to_string(), self.replications.into()),
            ("total_runs".to_string(), self.total_runs.into()),
            ("budget".to_string(), self.budget.into()),
        ];
        if !self.effects.is_empty() {
            fields.push((
                "effects".to_string(),
                Json::Arr(
                    self.effects
                        .iter()
                        .map(|e| {
                            Json::obj([
                                ("rank", e.rank.into()),
                                ("knob", Json::str(&e.knob)),
                                ("lo", Json::str(&e.lo)),
                                ("hi", Json::str(&e.hi)),
                                ("effect", Json::Num(e.effect)),
                                ("ci95", Json::Num(e.ci95)),
                                ("n", e.n.into()),
                                ("significant", Json::Bool(e.significant)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.trail.is_empty() {
            fields.push((
                "trail".to_string(),
                Json::Arr(
                    self.trail
                        .iter()
                        .map(|t| {
                            let mut f = vec![
                                ("label".to_string(), Json::str(&t.label)),
                                ("overrides".to_string(), overrides_json(&t.overrides)),
                                ("n".to_string(), t.n.into()),
                                ("mean".to_string(), Json::Num(t.mean)),
                                ("ci95".to_string(), Json::Num(t.ci95)),
                            ];
                            if let Some(r) = t.pruned_round {
                                f.push(("pruned_round".to_string(), r.into()));
                            }
                            f.push(("winner".to_string(), Json::Bool(t.winner)));
                            Json::Obj(f)
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(b) = &self.best {
            fields.push((
                "best".to_string(),
                Json::obj([
                    ("label", Json::str(&b.label)),
                    ("overrides", overrides_json(&b.overrides)),
                    ("mean", Json::Num(b.mean)),
                    ("delta_mean", Json::Num(b.delta_mean)),
                    ("delta_ci95", Json::Num(b.delta_ci95)),
                    ("delta_n", b.delta_n.into()),
                    ("significant", Json::Bool(b.significant)),
                    ("yaml", Json::str(&b.yaml)),
                ]),
            ));
        }
        Json::Obj(fields)
    }
}

/// What a scenario produced, wrapped with the scenario's metadata.
pub enum RecordBody {
    Run(RunRecord),
    Sweep(SweepRecord),
    WhatIf(WhatIfRecord),
    Compare(CompareRecord),
    Study(StudyRecord),
    Optimize(OptimizeRecord),
}

/// A scenario outcome: metadata + the kind-specific body record.
pub struct ScenarioRecord {
    pub title: String,
    /// `single | sweep | whatif | inject | compare | multi | optimize`.
    pub kind: &'static str,
    pub seed: u64,
    pub policies: PolicySpec,
    pub body: RecordBody,
}

impl ScenarioRecord {
    pub fn to_json(&self) -> Json {
        let body = match &self.body {
            RecordBody::Run(r) => r.to_json(),
            RecordBody::Sweep(r) => r.to_json(),
            RecordBody::WhatIf(r) => r.to_json(),
            RecordBody::Compare(r) => r.to_json(),
            RecordBody::Study(r) => r.to_json(),
            RecordBody::Optimize(r) => r.to_json(),
        };
        Json::obj([
            ("kind", Json::str("scenario")),
            ("scenario", Json::str(self.kind)),
            ("title", Json::str(&self.title)),
            ("seed", self.seed.into()),
            ("policies", policies_json(&self.policies)),
            ("result", body),
        ])
    }
}

/// `{selection, repair, checkpoint, failure}` by name.
pub fn policies_json(spec: &PolicySpec) -> Json {
    Json::obj([
        ("selection", Json::str(&spec.selection)),
        ("repair", Json::str(&spec.repair)),
        ("checkpoint", Json::str(&spec.checkpoint)),
        ("failure", Json::str(&spec.failure)),
    ])
}

/// Full summary statistics of one metric.
pub fn summary_json(s: &Summary) -> Json {
    Json::obj([
        ("n", s.n.into()),
        ("mean", Json::Num(s.mean)),
        ("std", Json::Num(s.std)),
        ("min", Json::Num(s.min)),
        ("p25", Json::Num(s.p25)),
        ("median", Json::Num(s.median)),
        ("p75", Json::Num(s.p75)),
        ("p95", Json::Num(s.p95)),
        ("p99", Json::Num(s.p99)),
        ("max", Json::Num(s.max)),
        ("ci95", Json::Num(s.ci95_halfwidth())),
    ])
}

/// `(axis, value)` overrides as a JSON object (numeric axes as numbers,
/// policy axes as strings) — shared by sweep points and study children.
pub fn overrides_json(overrides: &[(String, AxisValue)]) -> Json {
    Json::Obj(
        overrides
            .iter()
            .map(|(n, v)| {
                let jv = match v {
                    AxisValue::Num(x) => Json::Num(*x),
                    AxisValue::Name(s) => Json::str(s),
                };
                (n.clone(), jv)
            })
            .collect(),
    )
}

/// One sweep point: its label, typed axis overrides, and the summary of
/// **every** registry metric at that point.
pub fn point_json(pr: &PointResult) -> Json {
    let overrides = overrides_json(&pr.point.overrides);
    let metrics_obj = Json::Obj(
        metrics::REGISTRY
            .iter()
            .filter_map(|m| pr.summary(m.name).map(|s| (m.name.to_string(), summary_json(&s))))
            .collect(),
    );
    Json::obj([
        ("label", Json::str(pr.point.label())),
        ("overrides", overrides),
        ("metrics", metrics_obj),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{run_sweep, Sweep};

    #[test]
    fn run_record_covers_every_metric() {
        let p = Params::small_test();
        let outputs = crate::model::cluster::Simulation::new(&p, 7).run();
        let rec = RunRecord {
            seed: 7,
            params: p,
            policies: PolicySpec::default(),
            outputs,
            trace: Trace::default(),
        };
        let names: Vec<&str> = rec.metric_values().map(|(m, _)| m.name).collect();
        assert_eq!(names.len(), metrics::REGISTRY.len());
        let rendered = rec.to_json().render();
        for m in metrics::REGISTRY {
            assert!(rendered.contains(&format!("\"{}\"", m.name)), "missing {}", m.name);
        }
        assert!(!rendered.contains("\"trace\""), "no trace key when untraced");
    }

    #[test]
    fn point_json_labels_policy_axes() {
        let base = Params::small_test();
        let s = Sweep::from_axes(
            "t",
            &[("policies.selection".to_string(), vec!["locality".into()])],
            1,
            3,
        );
        let r = run_sweep(&base, &s, 1);
        let j = point_json(&r.points[0]).render();
        assert!(j.contains(r#""policies.selection":"locality""#), "{j}");
        assert!(j.contains(r#""label":"policies.selection=locality""#), "{j}");
    }
}
