//! Output sinks: one [`Sink`] per `--format`, each rendering the whole
//! record family ([`RunRecord`], [`SweepRecord`], [`WhatIfRecord`],
//! [`CompareRecord`], [`ScenarioRecord`]).
//!
//! The text sink reproduces the pre-redesign CLI tables **byte for
//! byte** (pinned by `tests/output_api.rs` against literal copies of the
//! legacy format strings); the JSON/CSV/NDJSON sinks emit the machine
//! form — every metric in the registry, with units, parseable without a
//! schema.

use crate::report::json::Json;
use crate::report::record::{
    CompareRecord, OptimizeRecord, RecordBody, RunRecord, ScenarioRecord, StudyRecord,
    SweepRecord, WhatIfRecord,
};
use crate::report::{csv, text_table};

/// A selected output format (`--format {text|json|csv|ndjson}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text,
    Json,
    Csv,
    Ndjson,
}

impl Format {
    pub fn parse(s: &str) -> Result<Format, String> {
        match s {
            "text" => Ok(Format::Text),
            "json" => Ok(Format::Json),
            "csv" => Ok(Format::Csv),
            "ndjson" => Ok(Format::Ndjson),
            other => Err(format!(
                "unknown format `{other}` (expected text, json, csv, or ndjson)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Text => "text",
            Format::Json => "json",
            Format::Csv => "csv",
            Format::Ndjson => "ndjson",
        }
    }

    /// The sink implementing this format.
    pub fn sink(self) -> &'static dyn Sink {
        match self {
            Format::Text => &TextSink,
            Format::Json => &JsonSink,
            Format::Csv => &CsvSink,
            Format::Ndjson => &NdjsonSink,
        }
    }
}

/// Render any record of the output data model. Every method returns the
/// complete output text (callers `print!` it verbatim).
pub trait Sink {
    fn run(&self, r: &RunRecord) -> String;
    fn sweep(&self, r: &SweepRecord) -> String;
    fn whatif(&self, r: &WhatIfRecord) -> String;
    fn compare(&self, r: &CompareRecord) -> String;
    fn study(&self, r: &StudyRecord) -> String;
    fn optimize(&self, r: &OptimizeRecord) -> String;
    fn scenario(&self, r: &ScenarioRecord) -> String;

    /// Stream a scenario record: `out` receives ordered text chunks
    /// whose concatenation is exactly [`Sink::scenario`]'s string.
    /// Document formats (text, json, csv) emit one chunk; NDJSON
    /// overrides this to emit line by line, so a serve consumer can act
    /// on records as they arrive instead of waiting for the full body.
    fn scenario_stream(&self, r: &ScenarioRecord, out: &mut dyn FnMut(&str)) {
        out(&self.scenario(r));
    }
}

// ------------------------------------------------------------------ //
// Text: the legacy human tables, byte-identical.
// ------------------------------------------------------------------ //

pub struct TextSink;

/// The `airesim run` output block (trace first, as the legacy CLI
/// printed it).
fn run_outputs_text(r: &RunRecord) -> String {
    let out = &r.outputs;
    let mut s = String::new();
    if !r.trace.is_empty() {
        s.push_str(&r.trace.render());
    }
    s.push_str(&format!("== run outputs (seed {}) ==\n", r.seed));
    s.push_str(&format!(
        "makespan           {:>14.2} min ({:.2} days)\n",
        out.makespan,
        out.makespan / 1440.0
    ));
    s.push_str(&format!("completed          {:>14}\n", out.completed));
    s.push_str(&format!(
        "failures           {:>14} (random {}, systematic {})\n",
        out.failures_total, out.failures_random, out.failures_systematic
    ));
    s.push_str(&format!("standby swaps      {:>14}\n", out.standby_swaps));
    s.push_str(&format!("host selections    {:>14}\n", out.host_selections));
    s.push_str(&format!("preemptions        {:>14}\n", out.preemptions));
    s.push_str(&format!(
        "repairs            {:>14} auto, {} manual\n",
        out.repairs_auto, out.repairs_manual
    ));
    s.push_str(&format!("retirements        {:>14}\n", out.retirements));
    s.push_str(&format!("stall time         {:>14.2} min\n", out.stall_time));
    s.push_str(&format!("recovery total     {:>14.2} min\n", out.recovery_total));
    s.push_str(&format!("avg run duration   {:>14.2} min\n", out.avg_run_duration));
    s.push_str(&format!(
        "utilization        {:>14.4}\n",
        out.utilization(r.params.job_len)
    ));
    s.push_str(&format!("events delivered   {:>14}\n", out.events_delivered));
    s
}

/// The scenario-report output block (shorter than `airesim run`'s; the
/// legacy `Scenario::render` format).
fn scenario_outputs_text(r: &RunRecord) -> String {
    let out = &r.outputs;
    format!(
        "makespan           {:>14.2} min ({:.2} days)\n\
         completed          {:>14}\n\
         failures           {:>14} (random {}, systematic {})\n\
         standby swaps      {:>14}\n\
         host selections    {:>14}\n\
         preemptions        {:>14}\n\
         repairs            {:>14} auto, {} manual\n\
         stall time         {:>14.2} min\n\
         utilization        {:>14.4}\n",
        out.makespan,
        out.makespan / 1440.0,
        out.completed,
        out.failures_total,
        out.failures_random,
        out.failures_systematic,
        out.standby_swaps,
        out.host_selections,
        out.preemptions,
        out.repairs_auto,
        out.repairs_manual,
        out.stall_time,
        out.utilization(r.params.job_len)
    )
}

/// The study report: the child roster, then the combined comparison
/// table — every registry metric, one row per child, Δ% vs the baseline.
fn study_text(r: &StudyRecord) -> String {
    let mut s = String::new();
    let crn = if r.crn { "crn on" } else { "crn off" };
    let baseline = match r.baseline_label() {
        Some(label) => format!(", baseline {label}"),
        None => String::new(),
    };
    s.push_str(&format!(
        "study: {} children x {} replications ({crn}{baseline})\n",
        r.children.len(),
        r.replications
    ));
    s.push_str(&format!("{:<42} overrides\n", "child"));
    for (i, c) in r.children.iter().enumerate() {
        let mark = if Some(i) == r.baseline { "*" } else { " " };
        s.push_str(&format!("{:<40} {mark} {}\n", c.label, c.overrides_label()));
    }
    s.push_str(&format!(
        "\n== comparison — per-child means{} ==\n",
        if r.baseline.is_some() { " (Δ% vs baseline *)" } else { "" }
    ));
    if r.show_ci {
        s.push_str(&format!(
            "{:<24} {:<6} {:<40} {:>14} {:>12} {:>10} {:>14} {:>4}\n",
            "metric", "unit", "child", "mean", "±95%CI", "Δ%", "Δ±95%CI", "sig"
        ));
    } else {
        s.push_str(&format!(
            "{:<24} {:<6} {:<40} {:>14} {:>12} {:>10}\n",
            "metric", "unit", "child", "mean", "±95%CI", "Δ%"
        ));
    }
    for (m, entries) in r.comparison() {
        for (k, e) in entries.iter().enumerate() {
            // Name the metric on its first row only: the blank rows read
            // as one per-metric block.
            let (name, unit) = if k == 0 { (m.name, m.unit) } else { ("", "") };
            let delta = match e.delta_pct {
                Some(pct) => format!("{pct:>+9.2}%"),
                None => format!("{:>10}", "-"),
            };
            let mark = if Some(e.child) == r.baseline { "*" } else { " " };
            s.push_str(&format!(
                "{:<24} {:<6} {:<38} {mark} {:>14.3} {:>12.3} {delta}",
                name, unit, r.children[e.child].label, e.mean, e.ci95
            ));
            if r.show_ci {
                let dci = match e.delta_ci {
                    Some(h) => format!("{h:>14.3}"),
                    None => format!("{:>14}", "-"),
                };
                let sig = match e.significant {
                    Some(true) => "*",
                    Some(false) => "",
                    None => "-",
                };
                s.push_str(&format!(" {dci} {sig:>4}"));
            }
            s.push('\n');
        }
    }
    s
}

/// The optimize report: the run header, then the ranked knob table
/// (`mode: screen`) or the search trail plus winner (`mode: tune`).
fn optimize_text(r: &OptimizeRecord) -> String {
    let mut s = format!(
        "optimize: {} — objective {} ({}), {} replications, {} runs (budget {})\n",
        r.mode, r.objective, r.direction, r.replications, r.total_runs, r.budget
    );
    if !r.effects.is_empty() {
        s.push_str(&format!(
            "\n== knob importance — main effect on {} ({}) ==\n",
            r.objective, r.objective_unit
        ));
        s.push_str(&format!(
            "{:<4} {:<28} {:>14} {:>14} {:>14} {:>12} {:>4}\n",
            "rank", "knob", "lo", "hi", "effect", "±95%CI", "sig"
        ));
        for e in &r.effects {
            s.push_str(&format!(
                "{:<4} {:<28} {:>14} {:>14} {:>+14.3} {:>12.3} {:>4}\n",
                e.rank,
                e.knob,
                e.lo,
                e.hi,
                e.effect,
                e.ci95,
                if e.significant { "*" } else { "" }
            ));
        }
    }
    if !r.trail.is_empty() {
        s.push_str(&format!(
            "\n== search trail — {} per candidate (winner *) ==\n",
            r.objective
        ));
        s.push_str(&format!(
            "{:<44} {:>4} {:>14} {:>12} {:>8}\n",
            "candidate", "n", "mean", "±95%CI", "pruned"
        ));
        for t in &r.trail {
            let mark = if t.winner { "*" } else { " " };
            let pruned = match t.pruned_round {
                Some(round) => format!("r{round}"),
                None => "-".to_string(),
            };
            s.push_str(&format!(
                "{:<42} {mark} {:>4} {:>14.3} {:>12.3} {:>8}\n",
                t.label, t.n, t.mean, t.ci95, pruned
            ));
        }
    }
    if let Some(b) = &r.best {
        s.push_str(&format!(
            "\nwinner: {} — {} {:.3} (Δ vs base {:+.3} ±{:.3}, n {}{})\n",
            b.label,
            r.objective,
            b.mean,
            b.delta_mean,
            b.delta_ci95,
            b.delta_n,
            if b.significant { ", significant" } else { "" }
        ));
    }
    s
}

fn whatif_delta_text(r: &WhatIfRecord) -> String {
    match r.delta() {
        Some((base, scaled, pct)) => format!(
            "\nscaling {} by {} changes mean training time by {:+.2}% ({:.1}h -> {:.1}h)\n",
            r.param, r.factor, pct, base, scaled
        ),
        None => String::new(),
    }
}

impl Sink for TextSink {
    fn run(&self, r: &RunRecord) -> String {
        run_outputs_text(r)
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        text_table(&r.result, &r.metric)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        format!("{}{}", text_table(&r.result, &r.metric), whatif_delta_text(r))
    }

    fn compare(&self, r: &CompareRecord) -> String {
        format!(
            "CTMC makespan_est  {:>14.1} min\n\
             DES  mean makespan {:>14.1} min (±{:.1} 95% CI, {} reps)\n\
             relative delta     {:>14.2}%\n",
            r.analytic.makespan_est,
            r.des_makespan.mean,
            r.des_makespan.ci95_halfwidth(),
            r.replications,
            r.relative_delta() * 100.0
        )
    }

    fn study(&self, r: &StudyRecord) -> String {
        study_text(r)
    }

    fn optimize(&self, r: &OptimizeRecord) -> String {
        optimize_text(r)
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        let mut s = format!(
            "== scenario: {} [{}] ==\npolicies: selection={} repair={} checkpoint={} failure={}\n",
            r.title,
            r.kind,
            r.policies.selection,
            r.policies.repair,
            r.policies.checkpoint,
            r.policies.failure,
        );
        match &r.body {
            RecordBody::Run(rr) => {
                if !rr.trace.is_empty() {
                    s.push_str(&rr.trace.render());
                }
                s.push_str(&scenario_outputs_text(rr));
            }
            RecordBody::Sweep(sr) => s.push_str(&self.sweep(sr)),
            RecordBody::WhatIf(wr) => s.push_str(&self.whatif(wr)),
            RecordBody::Compare(cr) => s.push_str(&self.compare(cr)),
            RecordBody::Study(st) => s.push_str(&self.study(st)),
            RecordBody::Optimize(or) => s.push_str(&self.optimize(or)),
        }
        s
    }
}

// ------------------------------------------------------------------ //
// JSON: one document per invocation.
// ------------------------------------------------------------------ //

pub struct JsonSink;

impl Sink for JsonSink {
    fn run(&self, r: &RunRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn compare(&self, r: &CompareRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn study(&self, r: &StudyRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn optimize(&self, r: &OptimizeRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        r.to_json().render() + "\n"
    }
}

// ------------------------------------------------------------------ //
// CSV: flat tables (the sweep form is the legacy `--csv` output).
// ------------------------------------------------------------------ //

pub struct CsvSink;

/// Standard CSV quoting for free-form columns: child/candidate labels
/// and knob names are user text (one containing a comma would shift
/// every subsequent column); metric names/units come from the registry
/// and never need it.
fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

impl Sink for CsvSink {
    fn run(&self, r: &RunRecord) -> String {
        let mut s = String::from("metric,unit,value\n");
        for (m, v) in r.metric_values() {
            s.push_str(&format!("{},{},{v}\n", m.name, m.unit));
        }
        s
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        csv(&r.result, &r.metric)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        csv(&r.result, &r.metric)
    }

    fn compare(&self, r: &CompareRecord) -> String {
        let a = &r.analytic;
        let mut s = String::from("quantity,value\n");
        s.push_str(&format!("ctmc_makespan_est,{}\n", a.makespan_est));
        s.push_str(&format!("ctmc_exp_failures,{}\n", a.exp_failures));
        s.push_str(&format!("des_mean_makespan,{}\n", r.des_makespan.mean));
        s.push_str(&format!("des_ci95_halfwidth,{}\n", r.des_makespan.ci95_halfwidth()));
        s.push_str(&format!("replications,{}\n", r.replications));
        s.push_str(&format!("relative_delta,{}\n", r.relative_delta()));
        s
    }

    fn study(&self, r: &StudyRecord) -> String {
        // Long form: one row per (metric, child). Delta columns are empty
        // on the baseline row and when no baseline is designated; the
        // delta-CI columns additionally need enough replications for a
        // finite interval.
        let mut s =
            String::from("metric,unit,child,n,mean,std,ci95,delta,delta_pct,delta_ci,significant\n");
        for (m, entries) in r.comparison() {
            for e in &entries {
                let std = r.children[e.child]
                    .summary(m.name)
                    .map(|sm| sm.std)
                    .unwrap_or(0.0);
                let delta = e.delta.map(|d| d.to_string()).unwrap_or_default();
                let pct = e.delta_pct.map(|d| d.to_string()).unwrap_or_default();
                let dci = e.delta_ci.map(|d| d.to_string()).unwrap_or_default();
                let sig = e.significant.map(|b| b.to_string()).unwrap_or_default();
                s.push_str(&format!(
                    "{},{},{},{},{},{},{},{delta},{pct},{dci},{sig}\n",
                    m.name,
                    m.unit,
                    csv_field(&r.children[e.child].label),
                    e.n,
                    e.mean,
                    std,
                    e.ci95
                ));
            }
        }
        s
    }

    fn optimize(&self, r: &OptimizeRecord) -> String {
        if r.mode == "screen" {
            let mut s = String::from("rank,knob,lo,hi,effect,ci95,n,significant\n");
            for e in &r.effects {
                s.push_str(&format!(
                    "{},{},{},{},{},{},{},{}\n",
                    e.rank,
                    csv_field(&e.knob),
                    csv_field(&e.lo),
                    csv_field(&e.hi),
                    e.effect,
                    e.ci95,
                    e.n,
                    e.significant
                ));
            }
            s
        } else {
            let mut s = String::from("candidate,n,mean,ci95,pruned_round,winner\n");
            for t in &r.trail {
                let pruned = t.pruned_round.map(|v| v.to_string()).unwrap_or_default();
                s.push_str(&format!(
                    "{},{},{},{},{pruned},{}\n",
                    csv_field(&t.label),
                    t.n,
                    t.mean,
                    t.ci95,
                    t.winner
                ));
            }
            s
        }
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        match &r.body {
            RecordBody::Run(rr) => self.run(rr),
            RecordBody::Sweep(sr) => self.sweep(sr),
            RecordBody::WhatIf(wr) => self.whatif(wr),
            RecordBody::Compare(cr) => self.compare(cr),
            RecordBody::Study(st) => self.study(st),
            RecordBody::Optimize(or) => self.optimize(or),
        }
    }
}

// ------------------------------------------------------------------ //
// NDJSON: one self-describing JSON object per line (`jq`-friendly).
// ------------------------------------------------------------------ //

pub struct NdjsonSink;

fn ndjson_line(mut fields: Vec<(String, Json)>, type_name: &str) -> String {
    fields.insert(0, ("type".to_string(), Json::str(type_name)));
    Json::Obj(fields).render() + "\n"
}

/// Field lookup on a JSON object (the study sink re-slices the record's
/// document into per-line objects).
fn obj_field<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

/// One `{"type":"point",...}` line per sweep point.
fn point_lines(result: &crate::sweep::SweepResult) -> String {
    let mut s = String::new();
    for (i, pr) in result.points.iter().enumerate() {
        match crate::report::record::point_json(pr) {
            Json::Obj(mut fields) => {
                fields.insert(0, ("index".to_string(), i.into()));
                s.push_str(&ndjson_line(fields, "point"));
            }
            other => s.push_str(&(other.render() + "\n")),
        }
    }
    s
}

impl Sink for NdjsonSink {
    fn run(&self, r: &RunRecord) -> String {
        // Event lines share `Trace::to_ndjson`'s schema exactly, so a
        // `--trace-out` file and a traced `--format ndjson` stream are
        // filterable by the same `jq` program.
        let mut s = r.trace.to_ndjson();
        for (m, v) in r.metric_values() {
            s.push_str(&ndjson_line(
                vec![
                    ("name".to_string(), Json::str(m.name)),
                    ("unit".to_string(), Json::str(m.unit)),
                    ("value".to_string(), Json::Num(v)),
                ],
                "metric",
            ));
        }
        s
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        point_lines(&r.result)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        let mut s = point_lines(&r.result);
        let mut fields = vec![
            ("param".to_string(), Json::str(&r.param)),
            ("factor".to_string(), Json::Num(r.factor)),
            ("metric".to_string(), Json::str(&r.metric)),
        ];
        if let Some((base, scaled, pct)) = r.delta() {
            fields.push(("baseline_mean".to_string(), Json::Num(base)));
            fields.push(("scaled_mean".to_string(), Json::Num(scaled)));
            fields.push(("delta_pct".to_string(), Json::Num(pct)));
        }
        s.push_str(&ndjson_line(fields, "whatif"));
        s
    }

    fn compare(&self, r: &CompareRecord) -> String {
        match r.to_json() {
            Json::Obj(fields) => ndjson_line(
                fields.into_iter().filter(|(k, _)| k != "kind").collect(),
                "compare",
            ),
            other => other.render() + "\n",
        }
    }

    fn study(&self, r: &StudyRecord) -> String {
        // One `{"type":"child",...}` line per child (full summaries),
        // then one `{"type":"comparison",...}` line per registry metric —
        // `jq 'select(.type == "comparison")'` extracts the whole table.
        let mut s = String::new();
        let study_json = r.to_json();
        if let Some(Json::Arr(children)) = obj_field(&study_json, "children") {
            for (i, child) in children.iter().enumerate() {
                if let Json::Obj(fields) = child {
                    let mut fields = fields.clone();
                    fields.insert(0, ("index".to_string(), i.into()));
                    s.push_str(&ndjson_line(fields, "child"));
                }
            }
        }
        if let Some(Json::Arr(rows)) = obj_field(&study_json, "comparison") {
            for row in rows {
                if let Json::Obj(fields) = row {
                    s.push_str(&ndjson_line(fields.clone(), "comparison"));
                }
            }
        }
        s
    }

    fn optimize(&self, r: &OptimizeRecord) -> String {
        // One summary line, then one line per effect (`mode: screen`) or
        // per candidate plus the winner (`mode: tune`) —
        // `jq 'select(.type == "effect")'` extracts the ranked table.
        let mut s = ndjson_line(
            vec![
                ("mode".to_string(), Json::str(&r.mode)),
                ("objective".to_string(), Json::str(&r.objective)),
                ("objective_unit".to_string(), Json::str(&r.objective_unit)),
                ("direction".to_string(), Json::str(&r.direction)),
                ("replications".to_string(), r.replications.into()),
                ("total_runs".to_string(), r.total_runs.into()),
                ("budget".to_string(), r.budget.into()),
            ],
            "optimize",
        );
        let j = r.to_json();
        if let Some(Json::Arr(effects)) = obj_field(&j, "effects") {
            for e in effects {
                if let Json::Obj(fields) = e {
                    s.push_str(&ndjson_line(fields.clone(), "effect"));
                }
            }
        }
        if let Some(Json::Arr(trail)) = obj_field(&j, "trail") {
            for t in trail {
                if let Json::Obj(fields) = t {
                    s.push_str(&ndjson_line(fields.clone(), "candidate"));
                }
            }
        }
        if let Some(Json::Obj(fields)) = obj_field(&j, "best") {
            s.push_str(&ndjson_line(fields.clone(), "best"));
        }
        s
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        let body = match &r.body {
            RecordBody::Run(rr) => self.run(rr),
            RecordBody::Sweep(sr) => self.sweep(sr),
            RecordBody::WhatIf(wr) => self.whatif(wr),
            RecordBody::Compare(cr) => self.compare(cr),
            RecordBody::Study(st) => self.study(st),
            RecordBody::Optimize(or) => self.optimize(or),
        };
        scenario_meta_line(r) + &body
    }

    /// One chunk per NDJSON line: the meta line first, then each body
    /// record as soon as it is rendered (`jq`-able mid-stream).
    fn scenario_stream(&self, r: &ScenarioRecord, out: &mut dyn FnMut(&str)) {
        let full = self.scenario(r);
        for line in full.split_inclusive('\n') {
            out(line);
        }
    }
}

/// The `{"type":"scenario",...}` header line opening every NDJSON
/// scenario stream.
fn scenario_meta_line(r: &ScenarioRecord) -> String {
    ndjson_line(
        vec![
            ("scenario".to_string(), Json::str(r.kind)),
            ("title".to_string(), Json::str(&r.title)),
            ("seed".to_string(), r.seed.into()),
            (
                "policies".to_string(),
                crate::report::record::policies_json(&r.policies),
            ),
        ],
        "scenario",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parses_and_names() {
        for (s, f) in [
            ("text", Format::Text),
            ("json", Format::Json),
            ("csv", Format::Csv),
            ("ndjson", Format::Ndjson),
        ] {
            assert_eq!(Format::parse(s).unwrap(), f);
            assert_eq!(f.name(), s);
        }
        let err = Format::parse("xml").unwrap_err();
        assert!(err.contains("ndjson"), "{err}");
    }
}
