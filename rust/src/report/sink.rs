//! Output sinks: one [`Sink`] per `--format`, each rendering the whole
//! record family ([`RunRecord`], [`SweepRecord`], [`WhatIfRecord`],
//! [`CompareRecord`], [`ScenarioRecord`]).
//!
//! The text sink reproduces the pre-redesign CLI tables **byte for
//! byte** (pinned by `tests/output_api.rs` against literal copies of the
//! legacy format strings); the JSON/CSV/NDJSON sinks emit the machine
//! form — every metric in the registry, with units, parseable without a
//! schema.

use crate::report::json::Json;
use crate::report::record::{
    CompareRecord, RecordBody, RunRecord, ScenarioRecord, StudyRecord, SweepRecord,
    WhatIfRecord,
};
use crate::report::{csv, text_table};

/// A selected output format (`--format {text|json|csv|ndjson}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text,
    Json,
    Csv,
    Ndjson,
}

impl Format {
    pub fn parse(s: &str) -> Result<Format, String> {
        match s {
            "text" => Ok(Format::Text),
            "json" => Ok(Format::Json),
            "csv" => Ok(Format::Csv),
            "ndjson" => Ok(Format::Ndjson),
            other => Err(format!(
                "unknown format `{other}` (expected text, json, csv, or ndjson)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Text => "text",
            Format::Json => "json",
            Format::Csv => "csv",
            Format::Ndjson => "ndjson",
        }
    }

    /// The sink implementing this format.
    pub fn sink(self) -> &'static dyn Sink {
        match self {
            Format::Text => &TextSink,
            Format::Json => &JsonSink,
            Format::Csv => &CsvSink,
            Format::Ndjson => &NdjsonSink,
        }
    }
}

/// Render any record of the output data model. Every method returns the
/// complete output text (callers `print!` it verbatim).
pub trait Sink {
    fn run(&self, r: &RunRecord) -> String;
    fn sweep(&self, r: &SweepRecord) -> String;
    fn whatif(&self, r: &WhatIfRecord) -> String;
    fn compare(&self, r: &CompareRecord) -> String;
    fn study(&self, r: &StudyRecord) -> String;
    fn scenario(&self, r: &ScenarioRecord) -> String;
}

// ------------------------------------------------------------------ //
// Text: the legacy human tables, byte-identical.
// ------------------------------------------------------------------ //

pub struct TextSink;

/// The `airesim run` output block (trace first, as the legacy CLI
/// printed it).
fn run_outputs_text(r: &RunRecord) -> String {
    let out = &r.outputs;
    let mut s = String::new();
    if !r.trace.is_empty() {
        s.push_str(&r.trace.render());
    }
    s.push_str(&format!("== run outputs (seed {}) ==\n", r.seed));
    s.push_str(&format!(
        "makespan           {:>14.2} min ({:.2} days)\n",
        out.makespan,
        out.makespan / 1440.0
    ));
    s.push_str(&format!("completed          {:>14}\n", out.completed));
    s.push_str(&format!(
        "failures           {:>14} (random {}, systematic {})\n",
        out.failures_total, out.failures_random, out.failures_systematic
    ));
    s.push_str(&format!("standby swaps      {:>14}\n", out.standby_swaps));
    s.push_str(&format!("host selections    {:>14}\n", out.host_selections));
    s.push_str(&format!("preemptions        {:>14}\n", out.preemptions));
    s.push_str(&format!(
        "repairs            {:>14} auto, {} manual\n",
        out.repairs_auto, out.repairs_manual
    ));
    s.push_str(&format!("retirements        {:>14}\n", out.retirements));
    s.push_str(&format!("stall time         {:>14.2} min\n", out.stall_time));
    s.push_str(&format!("recovery total     {:>14.2} min\n", out.recovery_total));
    s.push_str(&format!("avg run duration   {:>14.2} min\n", out.avg_run_duration));
    s.push_str(&format!(
        "utilization        {:>14.4}\n",
        out.utilization(r.params.job_len)
    ));
    s.push_str(&format!("events delivered   {:>14}\n", out.events_delivered));
    s
}

/// The scenario-report output block (shorter than `airesim run`'s; the
/// legacy `Scenario::render` format).
fn scenario_outputs_text(r: &RunRecord) -> String {
    let out = &r.outputs;
    format!(
        "makespan           {:>14.2} min ({:.2} days)\n\
         completed          {:>14}\n\
         failures           {:>14} (random {}, systematic {})\n\
         standby swaps      {:>14}\n\
         host selections    {:>14}\n\
         preemptions        {:>14}\n\
         repairs            {:>14} auto, {} manual\n\
         stall time         {:>14.2} min\n\
         utilization        {:>14.4}\n",
        out.makespan,
        out.makespan / 1440.0,
        out.completed,
        out.failures_total,
        out.failures_random,
        out.failures_systematic,
        out.standby_swaps,
        out.host_selections,
        out.preemptions,
        out.repairs_auto,
        out.repairs_manual,
        out.stall_time,
        out.utilization(r.params.job_len)
    )
}

/// The study report: the child roster, then the combined comparison
/// table — every registry metric, one row per child, Δ% vs the baseline.
fn study_text(r: &StudyRecord) -> String {
    let mut s = String::new();
    let crn = if r.crn { "crn on" } else { "crn off" };
    let baseline = match r.baseline_label() {
        Some(label) => format!(", baseline {label}"),
        None => String::new(),
    };
    s.push_str(&format!(
        "study: {} children x {} replications ({crn}{baseline})\n",
        r.children.len(),
        r.replications
    ));
    s.push_str(&format!("{:<42} overrides\n", "child"));
    for (i, c) in r.children.iter().enumerate() {
        let mark = if Some(i) == r.baseline { "*" } else { " " };
        s.push_str(&format!("{:<40} {mark} {}\n", c.label, c.overrides_label()));
    }
    s.push_str(&format!(
        "\n== comparison — per-child means{} ==\n",
        if r.baseline.is_some() { " (Δ% vs baseline *)" } else { "" }
    ));
    s.push_str(&format!(
        "{:<24} {:<6} {:<40} {:>14} {:>12} {:>10}\n",
        "metric", "unit", "child", "mean", "±95%CI", "Δ%"
    ));
    for (m, entries) in r.comparison() {
        for (k, e) in entries.iter().enumerate() {
            // Name the metric on its first row only: the blank rows read
            // as one per-metric block.
            let (name, unit) = if k == 0 { (m.name, m.unit) } else { ("", "") };
            let delta = match e.delta_pct {
                Some(pct) => format!("{pct:>+9.2}%"),
                None => format!("{:>10}", "-"),
            };
            let mark = if Some(e.child) == r.baseline { "*" } else { " " };
            s.push_str(&format!(
                "{:<24} {:<6} {:<38} {mark} {:>14.3} {:>12.3} {delta}\n",
                name, unit, r.children[e.child].label, e.mean, e.ci95
            ));
        }
    }
    s
}

fn whatif_delta_text(r: &WhatIfRecord) -> String {
    match r.delta() {
        Some((base, scaled, pct)) => format!(
            "\nscaling {} by {} changes mean training time by {:+.2}% ({:.1}h -> {:.1}h)\n",
            r.param, r.factor, pct, base, scaled
        ),
        None => String::new(),
    }
}

impl Sink for TextSink {
    fn run(&self, r: &RunRecord) -> String {
        run_outputs_text(r)
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        text_table(&r.result, &r.metric)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        format!("{}{}", text_table(&r.result, &r.metric), whatif_delta_text(r))
    }

    fn compare(&self, r: &CompareRecord) -> String {
        format!(
            "CTMC makespan_est  {:>14.1} min\n\
             DES  mean makespan {:>14.1} min (±{:.1} 95% CI, {} reps)\n\
             relative delta     {:>14.2}%\n",
            r.analytic.makespan_est,
            r.des_makespan.mean,
            r.des_makespan.ci95_halfwidth(),
            r.replications,
            r.relative_delta() * 100.0
        )
    }

    fn study(&self, r: &StudyRecord) -> String {
        study_text(r)
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        let mut s = format!(
            "== scenario: {} [{}] ==\npolicies: selection={} repair={} checkpoint={} failure={}\n",
            r.title,
            r.kind,
            r.policies.selection,
            r.policies.repair,
            r.policies.checkpoint,
            r.policies.failure,
        );
        match &r.body {
            RecordBody::Run(rr) => {
                if !rr.trace.is_empty() {
                    s.push_str(&rr.trace.render());
                }
                s.push_str(&scenario_outputs_text(rr));
            }
            RecordBody::Sweep(sr) => s.push_str(&self.sweep(sr)),
            RecordBody::WhatIf(wr) => s.push_str(&self.whatif(wr)),
            RecordBody::Compare(cr) => s.push_str(&self.compare(cr)),
            RecordBody::Study(st) => s.push_str(&self.study(st)),
        }
        s
    }
}

// ------------------------------------------------------------------ //
// JSON: one document per invocation.
// ------------------------------------------------------------------ //

pub struct JsonSink;

impl Sink for JsonSink {
    fn run(&self, r: &RunRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn compare(&self, r: &CompareRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn study(&self, r: &StudyRecord) -> String {
        r.to_json().render() + "\n"
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        r.to_json().render() + "\n"
    }
}

// ------------------------------------------------------------------ //
// CSV: flat tables (the sweep form is the legacy `--csv` output).
// ------------------------------------------------------------------ //

pub struct CsvSink;

impl Sink for CsvSink {
    fn run(&self, r: &RunRecord) -> String {
        let mut s = String::from("metric,unit,value\n");
        for (m, v) in r.metric_values() {
            s.push_str(&format!("{},{},{v}\n", m.name, m.unit));
        }
        s
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        csv(&r.result, &r.metric)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        csv(&r.result, &r.metric)
    }

    fn compare(&self, r: &CompareRecord) -> String {
        let a = &r.analytic;
        let mut s = String::from("quantity,value\n");
        s.push_str(&format!("ctmc_makespan_est,{}\n", a.makespan_est));
        s.push_str(&format!("ctmc_exp_failures,{}\n", a.exp_failures));
        s.push_str(&format!("des_mean_makespan,{}\n", r.des_makespan.mean));
        s.push_str(&format!("des_ci95_halfwidth,{}\n", r.des_makespan.ci95_halfwidth()));
        s.push_str(&format!("replications,{}\n", r.replications));
        s.push_str(&format!("relative_delta,{}\n", r.relative_delta()));
        s
    }

    fn study(&self, r: &StudyRecord) -> String {
        // Standard CSV quoting for the one free-form column: child
        // labels are user text (a label containing a comma would shift
        // every subsequent column); metric names/units come from the
        // registry and never need it.
        fn csv_field(s: &str) -> String {
            if s.contains([',', '"', '\n', '\r']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        // Long form: one row per (metric, child). Delta columns are empty
        // on the baseline row and when no baseline is designated.
        let mut s = String::from("metric,unit,child,n,mean,std,ci95,delta,delta_pct\n");
        for (m, entries) in r.comparison() {
            for e in &entries {
                let std = r.children[e.child]
                    .summary(m.name)
                    .map(|sm| sm.std)
                    .unwrap_or(0.0);
                let delta = e.delta.map(|d| d.to_string()).unwrap_or_default();
                let pct = e.delta_pct.map(|d| d.to_string()).unwrap_or_default();
                s.push_str(&format!(
                    "{},{},{},{},{},{},{},{delta},{pct}\n",
                    m.name,
                    m.unit,
                    csv_field(&r.children[e.child].label),
                    e.n,
                    e.mean,
                    std,
                    e.ci95
                ));
            }
        }
        s
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        match &r.body {
            RecordBody::Run(rr) => self.run(rr),
            RecordBody::Sweep(sr) => self.sweep(sr),
            RecordBody::WhatIf(wr) => self.whatif(wr),
            RecordBody::Compare(cr) => self.compare(cr),
            RecordBody::Study(st) => self.study(st),
        }
    }
}

// ------------------------------------------------------------------ //
// NDJSON: one self-describing JSON object per line (`jq`-friendly).
// ------------------------------------------------------------------ //

pub struct NdjsonSink;

fn ndjson_line(mut fields: Vec<(String, Json)>, type_name: &str) -> String {
    fields.insert(0, ("type".to_string(), Json::str(type_name)));
    Json::Obj(fields).render() + "\n"
}

/// Field lookup on a JSON object (the study sink re-slices the record's
/// document into per-line objects).
fn obj_field<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

/// One `{"type":"point",...}` line per sweep point.
fn point_lines(result: &crate::sweep::SweepResult) -> String {
    let mut s = String::new();
    for (i, pr) in result.points.iter().enumerate() {
        match crate::report::record::point_json(pr) {
            Json::Obj(mut fields) => {
                fields.insert(0, ("index".to_string(), i.into()));
                s.push_str(&ndjson_line(fields, "point"));
            }
            other => s.push_str(&(other.render() + "\n")),
        }
    }
    s
}

impl Sink for NdjsonSink {
    fn run(&self, r: &RunRecord) -> String {
        // Event lines share `Trace::to_ndjson`'s schema exactly, so a
        // `--trace-out` file and a traced `--format ndjson` stream are
        // filterable by the same `jq` program.
        let mut s = r.trace.to_ndjson();
        for (m, v) in r.metric_values() {
            s.push_str(&ndjson_line(
                vec![
                    ("name".to_string(), Json::str(m.name)),
                    ("unit".to_string(), Json::str(m.unit)),
                    ("value".to_string(), Json::Num(v)),
                ],
                "metric",
            ));
        }
        s
    }

    fn sweep(&self, r: &SweepRecord) -> String {
        point_lines(&r.result)
    }

    fn whatif(&self, r: &WhatIfRecord) -> String {
        let mut s = point_lines(&r.result);
        let mut fields = vec![
            ("param".to_string(), Json::str(&r.param)),
            ("factor".to_string(), Json::Num(r.factor)),
            ("metric".to_string(), Json::str(&r.metric)),
        ];
        if let Some((base, scaled, pct)) = r.delta() {
            fields.push(("baseline_mean".to_string(), Json::Num(base)));
            fields.push(("scaled_mean".to_string(), Json::Num(scaled)));
            fields.push(("delta_pct".to_string(), Json::Num(pct)));
        }
        s.push_str(&ndjson_line(fields, "whatif"));
        s
    }

    fn compare(&self, r: &CompareRecord) -> String {
        match r.to_json() {
            Json::Obj(fields) => ndjson_line(
                fields.into_iter().filter(|(k, _)| k != "kind").collect(),
                "compare",
            ),
            other => other.render() + "\n",
        }
    }

    fn study(&self, r: &StudyRecord) -> String {
        // One `{"type":"child",...}` line per child (full summaries),
        // then one `{"type":"comparison",...}` line per registry metric —
        // `jq 'select(.type == "comparison")'` extracts the whole table.
        let mut s = String::new();
        let study_json = r.to_json();
        if let Some(Json::Arr(children)) = obj_field(&study_json, "children") {
            for (i, child) in children.iter().enumerate() {
                if let Json::Obj(fields) = child {
                    let mut fields = fields.clone();
                    fields.insert(0, ("index".to_string(), i.into()));
                    s.push_str(&ndjson_line(fields, "child"));
                }
            }
        }
        if let Some(Json::Arr(rows)) = obj_field(&study_json, "comparison") {
            for row in rows {
                if let Json::Obj(fields) = row {
                    s.push_str(&ndjson_line(fields.clone(), "comparison"));
                }
            }
        }
        s
    }

    fn scenario(&self, r: &ScenarioRecord) -> String {
        let meta = ndjson_line(
            vec![
                ("scenario".to_string(), Json::str(r.kind)),
                ("title".to_string(), Json::str(&r.title)),
                ("seed".to_string(), r.seed.into()),
                (
                    "policies".to_string(),
                    crate::report::record::policies_json(&r.policies),
                ),
            ],
            "scenario",
        );
        let body = match &r.body {
            RecordBody::Run(rr) => self.run(rr),
            RecordBody::Sweep(sr) => self.sweep(sr),
            RecordBody::WhatIf(wr) => self.whatif(wr),
            RecordBody::Compare(cr) => self.compare(cr),
            RecordBody::Study(st) => self.study(st),
        };
        meta + &body
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parses_and_names() {
        for (s, f) in [
            ("text", Format::Text),
            ("json", Format::Json),
            ("csv", Format::Csv),
            ("ndjson", Format::Ndjson),
        ] {
            assert_eq!(Format::parse(s).unwrap(), f);
            assert_eq!(f.name(), s);
        }
        let err = Format::parse("xml").unwrap_err();
        assert!(err.contains("ndjson"), "{err}");
    }
}
