//! Hand-rolled argument parsing (no clap offline): subcommands, `--flag`,
//! `--key value` / `--key=value`, positionals, and generated help.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::Unknown(o) => write!(f, "unknown option `{o}` (see --help)"),
            CliError::MissingValue(o) => write!(f, "option `{o}` expects a value"),
            CliError::BadValue(o, v) => write!(f, "bad value for `{o}`: {v}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments: flags, key→value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

/// Declarative spec for one accepted option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    /// true: `--name value`; false: boolean `--name`.
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv` against a spec.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form.
                if let Some((k, v)) = name.split_once('=') {
                    let s = spec
                        .iter()
                        .find(|s| s.name == k)
                        .ok_or_else(|| CliError::Unknown(a.clone()))?;
                    if !s.takes_value {
                        return Err(CliError::BadValue(
                            k.to_string(),
                            "flag does not take a value".into(),
                        ));
                    }
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    let s = spec
                        .iter()
                        .find(|s| s.name == name)
                        .ok_or_else(|| CliError::Unknown(a.clone()))?;
                    if s.takes_value {
                        i += 1;
                        let v = argv
                            .get(i)
                            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
                        args.options.insert(name.to_string(), v.clone());
                    } else {
                        args.flags.push(name.to_string());
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => crate::config::yaml::eval_expr(v)
                .map(Some)
                .map_err(|e| CliError::BadValue(name.to_string(), e.to_string())),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|e| CliError::BadValue(name.to_string(), e.to_string())),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|e| CliError::BadValue(name.to_string(), e.to_string())),
        }
    }
}

/// Render generated help text for a subcommand.
pub fn render_help(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\nOptions:\n");
    for o in spec {
        let left = if o.takes_value {
            format!("--{} <value>", o.name)
        } else {
            format!("--{}", o.name)
        };
        s.push_str(&format!("  {left:<28} {}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "seed", takes_value: true, help: "rng seed" },
            OptSpec { name: "trace", takes_value: false, help: "trace" },
            OptSpec { name: "set", takes_value: true, help: "override" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(&sv(&["run", "--seed", "42", "--trace", "cfg.yaml"]), &spec())
            .unwrap();
        assert_eq!(a.positional, vec!["run", "cfg.yaml"]);
        assert_eq!(a.get_u64("seed").unwrap(), Some(42));
        assert!(a.flag("trace"));
    }

    #[test]
    fn key_equals_value() {
        let a = Args::parse(&sv(&["--seed=7"]), &spec()).unwrap();
        assert_eq!(a.get("seed"), Some("7"));
    }

    #[test]
    fn expression_values() {
        let a = Args::parse(&sv(&["--set", "2*1440"]), &spec()).unwrap();
        assert_eq!(a.get_f64("set").unwrap(), Some(2880.0));
    }

    #[test]
    fn unknown_and_missing() {
        assert!(Args::parse(&sv(&["--bogus"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--seed"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--trace=1"]), &spec()).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = render_help("run", "run one sim", &spec());
        assert!(h.contains("--seed <value>"));
        assert!(h.contains("--trace"));
    }
}
