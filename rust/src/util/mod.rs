//! Small substrates the offline environment lacks crates for.

pub mod cli;
pub mod err;
