//! Minimal error plumbing (the offline vendor set carries neither
//! `anyhow` nor `thiserror`): a boxed dynamic error alias, a `Context`
//! extension trait, and `anyhow!`/`bail!`-shaped macros exported at the
//! crate root.
//!
//! ```
//! use airesim::util::err::{Context, Result};
//! use airesim::{anyhow, bail};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     if s.is_empty() {
//!         bail!("empty input");
//!     }
//!     s.parse::<u32>().context("parsing count")
//! }
//! assert!(parse("").is_err());
//! assert_eq!(parse("7").unwrap(), 7);
//! ```

use std::fmt::Display;

/// The crate-wide dynamic error type.
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// The crate-wide result alias (error defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a message (the `anyhow!` macro's back end).
pub fn msg(m: String) -> Error {
    m.into()
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let e = e.into();
            msg(format!("{ctx}: {e}"))
        })
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e = e.into();
            msg(format!("{}: {e}", f()))
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| msg(ctx.to_string()))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::err::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::err::msg(::std::fmt::format(::std::format_args!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`](crate::util::err::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Boom;
    impl std::fmt::Display for Boom {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "boom")
        }
    }
    impl std::error::Error for Boom {}

    fn io_fail() -> Result<(), Boom> {
        Err(Boom)
    }

    #[test]
    fn context_wraps_any_error() {
        let e = io_fail().context("reading config").unwrap_err();
        let s = e.to_string();
        assert!(s.contains("reading config"), "{s}");
        assert!(s.contains("boom"), "{s}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, Boom> = Ok(5);
        let v = ok
            .with_context(|| -> String { panic!("must not be called on Ok") })
            .unwrap();
        assert_eq!(v, 5);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_produce_errors() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 3);
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 3");
        assert_eq!(f(false).unwrap(), 1);
        let e: Error = anyhow!("x = {}", 9);
        assert_eq!(e.to_string(), "x = 9");
    }
}
