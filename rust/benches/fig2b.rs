//! Bench: regenerate **Figure 2(b)** — total training time (hours) vs
//! waiting time {10, 20, 30} × working pool {4112, 4128, 4160, 4192}.
//!
//! The paper's claim: the waiting-time effect is weaker than the
//! recovery-time effect and concentrates where the working pool has zero
//! slack beyond the warm standbys (pool 4112).
//!
//! ```bash
//! cargo bench --bench fig2b
//! AIRESIM_BENCH_REPS=30 cargo bench --bench fig2b
//! ```

mod common;

use airesim::config::Params;
use airesim::report;
use airesim::sweep::{run_sweep, Sweep};
use common::{bench_reps, header, timed};
// (stress variant below builds its own Params)

fn main() {
    let reps = bench_reps(5);
    header(&format!("Figure 2(b): waiting time × working pool ({reps} reps/point)"));

    let base = Params::table1_defaults();
    let sweep = Sweep::two_way(
        "Fig 2(b)",
        "waiting_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &[4112.0, 4128.0, 4160.0, 4192.0],
        reps,
        42,
    );
    let (result, secs) = timed(|| run_sweep(&base, &sweep, 0));
    print!("{}", report::figure_series(&result, "makespan_hours"));
    print!("{}", report::csv(&result, "makespan_hours"));

    // Shape verdicts: (1) waiting-time slope at pool 4112 is the largest
    // of the four pools; (2) the overall waiting spread is much smaller
    // than Fig 2(a)'s recovery spread.
    let mean = |x: usize, y: usize| result.points[4 * x + y].summary("makespan_hours").unwrap().mean;
    let slope = |y: usize| mean(2, y) - mean(0, y); // wait 30 minus wait 10
    let slopes: Vec<f64> = (0..4).map(slope).collect();
    println!(
        "waiting-time slope by pool: 4112:{:+.0}h 4128:{:+.0}h 4160:{:+.0}h 4192:{:+.0}h",
        slopes[0], slopes[1], slopes[2], slopes[3]
    );
    let max_other = slopes[1..].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "shape: effect concentrated at minimum-slack pool 4112: {}",
        if slopes[0] >= max_other - 1.0 { "OK" } else { "WEAK (noise-dominated at these reps)" }
    );
    let runs = sweep.points.len() * reps;
    println!(
        "timing: {runs} runs in {secs:.1}s ({:.0} ms/run)",
        secs * 1000.0 / runs as f64
    );

    // ---- Stress variant ------------------------------------------------ //
    // At Table-I defaults, repaired servers rejoin the job within minutes,
    // so stalls resolve before the preemption wait elapses and the
    // waiting-time effect sits inside the replication CI. Under repair
    // pressure (manual-heavy, slow repairs) the spare pool is on the
    // critical path and the paper's Fig-2(b) concentration appears clearly.
    header(&format!("Fig 2(b) stress variant: manual-only repairs ({reps} reps/point)"));
    let mut stress = Params::table1_defaults();
    stress.auto_repair_prob = 0.0; // everything escalates to manual
    stress.manual_repair_time = 1440.0; // ~44 servers out on average:
                                        // above 4112's slack (16), below 4192's (96)
    let sweep2 = Sweep::two_way(
        "Fig 2(b) stress",
        "waiting_time",
        &[10.0, 30.0],
        "working_pool",
        &[4112.0, 4192.0],
        reps,
        43,
    )
    .with_crn(); // common random numbers: the difference is the signal
    let (r2, _) = timed(|| run_sweep(&stress, &sweep2, 0));
    print!("{}", report::figure_series(&r2, "makespan_hours"));
    let m2 = |x: usize, y: usize| r2.points[2 * x + y].summary("makespan_hours").unwrap().mean;
    let s_min = m2(1, 0) - m2(0, 0);
    let s_max = m2(1, 1) - m2(0, 1);
    let verdict = if s_max.abs() < 2.0 && s_min > s_max {
        "concentrated at zero slack: OK (slack pool exactly flat)"
    } else if s_min.abs() < 6.0 && s_max.abs() < 6.0 {
        "both ≈0: repair returns rescue stalls before the preempt wait binds \
         (expected effect ~5h ≈ 0.05%, below replication resolution — see \
         EXPERIMENTS.md Fig 2(b) discussion)"
    } else if s_min > s_max {
        "concentrated at zero slack: OK"
    } else {
        "MISMATCH"
    };
    println!(
        "stress slopes (wait 10→30): pool 4112 {s_min:+.0} h, pool 4192 {s_max:+.0} h — {verdict}"
    );
}
